"""AOT path: lowering produces valid HLO text with the expected ABI."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot, model


@pytest.mark.parametrize("algo", model.ALGORITHMS)
def test_lower_tiny_produces_hlo_text(algo):
    text, entry = aot.lower_one(algo, "tiny")
    # HLO text module header + one computation per module at minimum
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    assert entry["n"], entry["m"] == aot.BUCKETS["tiny"]
    assert len(entry["inputs"]) == len(model.arg_specs(algo, 1, 1))
    assert len(entry["outputs"]) == len(model.out_specs(algo, 1))
    # every input must appear as a parameter in the entry computation
    assert text.count("parameter(") >= len(entry["inputs"])


def test_lower_without_pallas_also_valid():
    text, entry = aot.lower_one("bfs", "tiny", use_pallas=False)
    assert text.startswith("HloModule")
    assert entry["use_pallas"] is False


def test_bucket_block_policy():
    # §Perf: blocks grow to min(m, cap); explicit --block overrides
    from compile.aot import bucket_block, BLOCK_CAP
    assert bucket_block(4096) == 4096
    assert bucket_block(1_048_576) == BLOCK_CAP
    assert bucket_block(1_048_576, requested=8192) == 8192
    for _, (n, m) in aot.BUCKETS.items():
        assert m % bucket_block(m) == 0, "grid must divide evenly"


def test_buckets_cover_paper_graphs():
    n_s, m_s = aot.BUCKETS["small"]
    n_l, m_l = aot.BUCKETS["large"]
    assert n_s >= 1_005 and m_s >= 25_571  # email-Eu-core
    assert n_l >= 82_168 and m_l >= 948_464  # soc-Slashdot0922
    for n, m in aot.BUCKETS.values():
        assert m % 4096 == 0, "edge pad must be a block multiple"


def test_cli_writes_manifest_and_sentinel():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "model.hlo.txt")
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", out,
             "--algos", "wcc", "--buckets", "tiny"],
            check=True, cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), env=env)
        man = json.load(open(os.path.join(d, "manifest.json")))
        assert man["artifacts"][0]["algo"] == "wcc"
        assert os.path.exists(os.path.join(d, man["artifacts"][0]["file"]))
        assert os.path.exists(out)
