"""L1 correctness: the Pallas edge-program kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts: if the
kernel matches ref.py for every op across shapes/dtypes/edge cases, and the
supersteps match their oracles (test_model.py), the HLO the rust runtime
executes is trustworthy.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.edge_program import (
    OPS,
    make_edge_program,
    vmem_footprint_bytes,
)
from .conftest import make_graph

RNG = np.random.default_rng(7)


def _state_for(op, n_pad, rng):
    if op == "bfs":
        s = (rng.random(n_pad) < 0.3).astype(np.int32)
        s[0] = 1  # never an empty frontier
        return s
    if op == "wcc":
        return rng.integers(0, n_pad, size=n_pad, dtype=np.int32)
    # float-state ops
    return rng.uniform(0.0, 5.0, size=n_pad).astype(np.float32)


def _run_both(op, g, state, cur_level=3):
    """Run pallas kernel and jnp oracle, return (kernel_out, ref_out)."""
    n, m = g["n_pad"], g["m_pad"]
    block = min(m, 1024)
    kern = make_edge_program(op, n, m, block=block)
    ne = np.array([g["num_edges"]], dtype=np.int32)
    lvl = np.array([cur_level], dtype=np.int32)
    if op == "bfs":
        out = kern(state, g["edge_src"], ne, lvl)
        exp = ref.edge_program_bfs(state, g["edge_src"], g["num_edges"],
                                   cur_level)
    elif op == "sssp":
        out = kern(state, g["edge_src"], g["edge_w"], ne)
        exp = ref.edge_program_sssp(state, g["edge_src"], g["edge_w"],
                                    g["num_edges"])
    elif op == "wcc":
        out = kern(state, g["edge_src"], ne)
        exp = ref.edge_program_wcc(state, g["edge_src"], g["num_edges"])
    elif op == "pr":
        out = kern(state, g["edge_src"], ne)
        exp = ref.edge_program_pr(state, g["edge_src"], g["num_edges"])
    elif op == "spmv":
        out = kern(state, g["edge_src"], g["edge_w"], ne)
        exp = ref.edge_program_spmv(state, g["edge_src"], g["edge_w"],
                                    g["num_edges"])
    else:
        raise AssertionError(op)
    return np.asarray(out), np.asarray(exp)


@pytest.mark.parametrize("op", sorted(OPS))
def test_kernel_matches_ref_basic(op):
    g = make_graph(RNG, 100, 900, 128, 1024)
    state = _state_for(op, g["n_pad"], RNG)
    out, exp = _run_both(op, g, state)
    np.testing.assert_allclose(out, exp, rtol=1e-6)


@pytest.mark.parametrize("op", sorted(OPS))
def test_kernel_multiblock_grid(op):
    """M spanning several grid blocks must agree with the unblocked oracle."""
    g = make_graph(RNG, 500, 3000, 512, 4096)
    state = _state_for(op, g["n_pad"], RNG)
    out, exp = _run_both(op, g, state)
    np.testing.assert_allclose(out, exp, rtol=1e-6)


@pytest.mark.parametrize("op", sorted(OPS))
def test_kernel_all_edges_padding(op):
    """num_edges == 0: every slot must be the op's neutral message."""
    g = make_graph(RNG, 10, 0, 64, 256)
    state = _state_for(op, g["n_pad"], RNG)
    out, _ = _run_both(op, g, state)
    _, _, _, _ = OPS[op]
    if op in ("bfs", "wcc"):
        assert (out == int(ref.INF_I32)).all()
    elif op == "sssp":
        assert (out == np.float32(ref.INF_F32)).all()
    else:
        assert (out == 0.0).all()


@pytest.mark.parametrize("op", sorted(OPS))
def test_kernel_no_padding(op):
    """num_edges == M exactly (mask never trims anything)."""
    g = make_graph(RNG, 64, 256, 64, 256)
    state = _state_for(op, g["n_pad"], RNG)
    out, exp = _run_both(op, g, state)
    np.testing.assert_allclose(out, exp, rtol=1e-6)


def test_unknown_op_rejected():
    with pytest.raises(ValueError, match="unknown edge op"):
        make_edge_program("dfs", 64, 256)


def test_block_must_divide_m():
    with pytest.raises(ValueError, match="multiple of"):
        make_edge_program("bfs", 64, 1000, block=512)


def test_vmem_footprint_monotone():
    """Footprint grows with N (resident state) and with block size."""
    a = vmem_footprint_bytes("bfs", 1024, 32768, 1024)
    b = vmem_footprint_bytes("bfs", 131072, 32768, 1024)
    c = vmem_footprint_bytes("bfs", 1024, 32768, 4096)
    assert b > a and c > a
    # weighted ops stream one more operand
    assert (vmem_footprint_bytes("sssp", 1024, 32768, 1024)
            > vmem_footprint_bytes("wcc", 1024, 32768, 1024))


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, degenerate graphs, extreme values
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    op=st.sampled_from(sorted(OPS)),
    nv=st.integers(min_value=1, max_value=96),
    ne_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(op, nv, ne_frac, seed):
    rng = np.random.default_rng(seed)
    m_pad = 512
    n_pad = 128
    num_edges = int(ne_frac * m_pad)
    g = make_graph(rng, nv, num_edges, n_pad, m_pad)
    state = _state_for(op, n_pad, rng)
    out, exp = _run_both(op, g, state, cur_level=int(seed % 100))
    np.testing.assert_allclose(out, exp, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    block_log=st.integers(min_value=6, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_block_size_invariance(block_log, seed):
    """The blocked schedule must not change the numbers (BFS op)."""
    rng = np.random.default_rng(seed)
    m_pad, n_pad = 2048, 256
    g = make_graph(rng, 200, 1500, n_pad, m_pad)
    state = _state_for("bfs", n_pad, rng)
    ne = np.array([g["num_edges"]], dtype=np.int32)
    lvl = np.array([5], dtype=np.int32)
    k = make_edge_program("bfs", n_pad, m_pad, block=2 ** block_log)
    out = np.asarray(k(state, g["edge_src"], ne, lvl))
    exp = np.asarray(ref.edge_program_bfs(state, g["edge_src"],
                                          g["num_edges"], 5))
    np.testing.assert_array_equal(out, exp)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_sssp_kernel_extreme_weights(seed):
    """Huge-but-finite weights must not poison masked lanes."""
    rng = np.random.default_rng(seed)
    g = make_graph(rng, 50, 400, 64, 512)
    g["edge_w"][: g["num_edges"]] = rng.uniform(1e30, 1e32, g["num_edges"]) \
        .astype(np.float32)
    state = rng.uniform(0.0, 1e30, 64).astype(np.float32)
    out, exp = _run_both("sssp", g, state)
    np.testing.assert_allclose(out, exp, rtol=1e-6)
