"""Shared fixtures/helpers for the python test-suite.

Random padded COO graphs in the artifact ABI (see compile/kernels/ref.py for
the conventions: padding edges carry src=dst=0 and weight 0).
"""

import numpy as np
import pytest


def make_graph(rng, num_vertices, num_edges, n_pad, m_pad, weighted=True):
    """Random directed multigraph in padded COO form.

    Returns dict of numpy arrays matching the artifact ABI.
    """
    assert num_vertices <= n_pad and num_edges <= m_pad
    src = rng.integers(0, num_vertices, size=num_edges, dtype=np.int32)
    dst = rng.integers(0, num_vertices, size=num_edges, dtype=np.int32)
    w = rng.uniform(0.1, 10.0, size=num_edges).astype(np.float32)
    edge_src = np.zeros(m_pad, dtype=np.int32)
    edge_dst = np.zeros(m_pad, dtype=np.int32)
    edge_w = np.zeros(m_pad, dtype=np.float32)
    edge_src[:num_edges] = src
    edge_dst[:num_edges] = dst
    edge_w[:num_edges] = w
    out_deg = np.zeros(n_pad, dtype=np.int32)
    np.add.at(out_deg, src, 1)
    return {
        "num_vertices": num_vertices,
        "num_edges": num_edges,
        "n_pad": n_pad,
        "m_pad": m_pad,
        "edge_src": edge_src,
        "edge_dst": edge_dst,
        "edge_w": edge_w,
        "out_deg": out_deg,
    }


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
