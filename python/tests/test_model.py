"""L2 correctness: supersteps (pallas path) vs ref.py oracles and vs
plain-python graph algorithms run to convergence.
"""

import collections

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .conftest import make_graph

RNG = np.random.default_rng(42)
N_PAD, M_PAD = 128, 1024
BLOCK = 256


def scalars(*vals):
    return [np.array([v], dtype=np.int32) for v in vals]


# ---------------------------------------------------------------------------
# superstep (pallas) == superstep (pure jnp ref)
# ---------------------------------------------------------------------------

def test_bfs_step_matches_ref():
    g = make_graph(RNG, 100, 800, N_PAD, M_PAD)
    levels = np.full(N_PAD, -1, dtype=np.int32)
    levels[0] = 0
    frontier = np.zeros(N_PAD, dtype=np.int32)
    frontier[0] = 1
    ne, lvl = scalars(g["num_edges"], 0)
    step = model.build_bfs_step(N_PAD, M_PAD, block=BLOCK)
    got = step(levels, frontier, g["edge_src"], g["edge_dst"], ne, lvl)
    exp = ref.bfs_step(levels, frontier, g["edge_src"], g["edge_dst"],
                       np.int32(g["num_edges"]), np.int32(0))
    for a, b in zip(got, exp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sssp_step_matches_ref():
    g = make_graph(RNG, 100, 800, N_PAD, M_PAD)
    dist = np.full(N_PAD, float(ref.INF_F32), dtype=np.float32)
    dist[0] = 0.0
    (ne,) = scalars(g["num_edges"])
    step = model.build_sssp_step(N_PAD, M_PAD, block=BLOCK)
    got = step(dist, g["edge_src"], g["edge_dst"], g["edge_w"], ne)
    exp = ref.sssp_step(dist, g["edge_src"], g["edge_dst"], g["edge_w"],
                        np.int32(g["num_edges"]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(exp[0]),
                               rtol=1e-6)
    assert int(got[1]) == int(exp[1])


def test_wcc_step_matches_ref():
    g = make_graph(RNG, 100, 800, N_PAD, M_PAD)
    label = np.arange(N_PAD, dtype=np.int32)
    (ne,) = scalars(g["num_edges"])
    step = model.build_wcc_step(N_PAD, M_PAD, block=BLOCK)
    got = step(label, g["edge_src"], g["edge_dst"], ne)
    exp = ref.wcc_step(label, g["edge_src"], g["edge_dst"],
                       np.int32(g["num_edges"]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]))


def test_pr_step_matches_ref():
    g = make_graph(RNG, 100, 800, N_PAD, M_PAD)
    rank = np.zeros(N_PAD, dtype=np.float32)
    rank[:100] = 1.0 / 100
    ne, nv = scalars(g["num_edges"], 100)
    step = model.build_pr_step(N_PAD, M_PAD, block=BLOCK)
    got = step(rank, g["out_deg"], g["edge_src"], g["edge_dst"], ne, nv)
    exp = ref.pr_step(rank, g["out_deg"], g["edge_src"], g["edge_dst"],
                      np.int32(g["num_edges"]), np.int32(100))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(exp[0]),
                               rtol=1e-5)


def test_spmv_step_matches_ref():
    g = make_graph(RNG, 100, 800, N_PAD, M_PAD)
    x = RNG.uniform(-1, 1, N_PAD).astype(np.float32)
    (ne,) = scalars(g["num_edges"])
    step = model.build_spmv_step(N_PAD, M_PAD, block=BLOCK)
    got = step(x, g["edge_src"], g["edge_dst"], g["edge_w"], ne)
    exp = ref.spmv_step(x, g["edge_src"], g["edge_dst"], g["edge_w"],
                        np.int32(g["num_edges"]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(exp),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# full-algorithm convergence vs plain-python references
# ---------------------------------------------------------------------------

def py_bfs(num_v, src, dst, root):
    adj = collections.defaultdict(list)
    for s, d in zip(src, dst):
        adj[int(s)].append(int(d))
    levels = {root: 0}
    q = collections.deque([root])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if v not in levels:
                levels[v] = levels[u] + 1
                q.append(v)
    out = np.full(num_v, -1, dtype=np.int32)
    for k, v in levels.items():
        out[k] = v
    return out


def py_sssp(num_v, src, dst, w, root):
    dist = np.full(num_v, np.inf)
    dist[root] = 0.0
    for _ in range(num_v):
        changed = False
        for s, d, ww in zip(src, dst, w):
            nd = dist[int(s)] + ww
            if nd < dist[int(d)]:
                dist[int(d)] = nd
                changed = True
        if not changed:
            break
    return dist


def drive_bfs(g, root, max_iters=64):
    """Run bfs_step to fixpoint, like engine/xla_engine.rs does."""
    step = model.build_bfs_step(g["n_pad"], g["m_pad"], block=BLOCK)
    levels = np.full(g["n_pad"], -1, dtype=np.int32)
    levels[root] = 0
    frontier = np.zeros(g["n_pad"], dtype=np.int32)
    frontier[root] = 1
    (ne,) = scalars(g["num_edges"])
    for it in range(max_iters):
        (lvl,) = scalars(it)
        levels, frontier, fsize, _ = step(levels, frontier, g["edge_src"],
                                          g["edge_dst"], ne, lvl)
        levels = np.asarray(levels)
        frontier = np.asarray(frontier)
        if int(fsize) == 0:
            break
    return levels


def test_bfs_converges_to_python_reference():
    g = make_graph(RNG, 80, 600, N_PAD, M_PAD)
    ne_real = g["num_edges"]
    got = drive_bfs(g, root=0)
    exp = py_bfs(80, g["edge_src"][:ne_real], g["edge_dst"][:ne_real], 0)
    np.testing.assert_array_equal(got[:80], exp)


def test_sssp_converges_to_python_reference():
    g = make_graph(RNG, 60, 400, N_PAD, M_PAD)
    ne_real = g["num_edges"]
    step = model.build_sssp_step(g["n_pad"], g["m_pad"], block=BLOCK)
    dist = np.full(g["n_pad"], float(ref.INF_F32), dtype=np.float32)
    dist[0] = 0.0
    (ne,) = scalars(ne_real)
    for _ in range(70):
        dist, changed = step(dist, g["edge_src"], g["edge_dst"],
                             g["edge_w"], ne)
        dist = np.asarray(dist)
        if int(changed) == 0:
            break
    exp = py_sssp(60, g["edge_src"][:ne_real], g["edge_dst"][:ne_real],
                  g["edge_w"][:ne_real], 0)
    reach = np.isfinite(exp)
    np.testing.assert_allclose(dist[:60][reach], exp[reach], rtol=1e-5)
    assert (dist[:60][~reach] >= 1e38).all()


def test_pr_ranks_sum_to_one():
    g = make_graph(RNG, 100, 900, N_PAD, M_PAD)
    step = model.build_pr_step(N_PAD, M_PAD, block=BLOCK)
    rank = np.zeros(N_PAD, dtype=np.float32)
    rank[:100] = 1.0 / 100
    ne, nv = scalars(g["num_edges"], 100)
    for _ in range(30):
        rank, delta = step(rank, g["out_deg"], g["edge_src"], g["edge_dst"],
                           ne, nv)
        rank = np.asarray(rank)
    assert abs(rank.sum() - 1.0) < 1e-3
    assert float(delta) < 1e-3


def test_wcc_finds_components():
    # two disjoint cliques: {0..4}, {5..9}
    edges = [(i, j) for i in range(5) for j in range(5) if i != j]
    edges += [(i, j) for i in range(5, 10) for j in range(5, 10) if i != j]
    m = len(edges)
    g = make_graph(RNG, 10, 0, 64, 256)
    g["num_edges"] = m
    g["edge_src"][:m] = [e[0] for e in edges]
    g["edge_dst"][:m] = [e[1] for e in edges]
    step = model.build_wcc_step(64, 256, block=64)
    label = np.arange(64, dtype=np.int32)
    (ne,) = scalars(m)
    for _ in range(12):
        label, changed = step(label, g["edge_src"], g["edge_dst"], ne)
        label = np.asarray(label)
        if int(changed) == 0:
            break
    assert (label[:5] == 0).all()
    assert (label[5:10] == 5).all()


# ---------------------------------------------------------------------------
# hypothesis: pallas path == jnp path for every algorithm on random graphs
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    algo=st.sampled_from(model.ALGORITHMS),
    nv=st.integers(min_value=2, max_value=100),
    ne=st.integers(min_value=0, max_value=800),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_path_equals_jnp_path(algo, nv, ne, seed):
    rng = np.random.default_rng(seed)
    g = make_graph(rng, nv, ne, N_PAD, M_PAD)
    sp = model.BUILDERS[algo](N_PAD, M_PAD, block=BLOCK, use_pallas=True)
    sj = model.BUILDERS[algo](N_PAD, M_PAD, block=BLOCK, use_pallas=False)
    if algo == "bfs":
        levels = np.full(N_PAD, -1, dtype=np.int32)
        levels[0] = 0
        frontier = np.zeros(N_PAD, dtype=np.int32)
        frontier[0] = 1
        args = (levels, frontier, g["edge_src"], g["edge_dst"],
                *scalars(ne, 0))
    elif algo == "pr":
        rank = np.zeros(N_PAD, dtype=np.float32)
        rank[:nv] = 1.0 / nv
        args = (rank, g["out_deg"], g["edge_src"], g["edge_dst"],
                *scalars(ne, nv))
    elif algo == "sssp":
        dist = np.full(N_PAD, float(ref.INF_F32), dtype=np.float32)
        dist[0] = 0.0
        args = (dist, g["edge_src"], g["edge_dst"], g["edge_w"],
                *scalars(ne))
    elif algo == "wcc":
        args = (np.arange(N_PAD, dtype=np.int32), g["edge_src"],
                g["edge_dst"], *scalars(ne))
    else:  # spmv
        x = rng.uniform(-1, 1, N_PAD).astype(np.float32)
        args = (x, g["edge_src"], g["edge_dst"], g["edge_w"], *scalars(ne))
    got, exp = sp(*args), sj(*args)
    for a, b in zip(got, exp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_arg_specs_cover_all_algorithms():
    for algo in model.ALGORITHMS:
        ins = model.arg_specs(algo, 64, 256)
        outs = model.out_specs(algo, 64)
        assert ins and outs
        names = [n for n, _, _ in ins]
        assert "edge_src" in names and "num_edges" in names
