"""L1 — the edge-processing pipeline stage as a Pallas kernel.

Hardware correspondence (see DESIGN.md §Hardware-Adaptation): the paper's
FPGA datapath streams edges from DDR4 through a fixed-function "edge program"
module while vertex state sits in BRAM. Here:

  - the **edge arrays are blocked** over the Pallas grid (the BlockSpec is the
    HBM->VMEM streaming schedule the paper expressed with pipeline lanes);
  - the **vertex state is a whole-array operand** (the BRAM analogue — it is
    resident for every grid step; <=512 KiB for our largest bucket);
  - the per-edge operator (the DSL's ``Apply``) is selected at *build* time,
    exactly like the translator wires a different Apply module per algorithm.

``interpret=True`` is mandatory: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO that the rust
runtime executes. Real-TPU performance is estimated analytically in
DESIGN.md/EXPERIMENTS.md §Perf from the VMEM footprint, not measured here.

Every op here has a pure-jnp oracle in :mod:`compile.kernels.ref`; pytest +
hypothesis compare them across shapes and dtypes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Python-scalar sentinels (pallas kernel bodies must not capture traced
# jnp constants — scalars bake into the HLO as literals). Numerically equal
# to ref.INF_I32 / ref.INF_F32.
INF_I32 = 2**30
INF_F32 = 3.0e38

# Default edge-block size. 4096 edges x 4 B = 16 KiB per streamed operand —
# large enough to amortize DMA, small enough to double-buffer. Swept in the
# §Perf pass (see EXPERIMENTS.md).
DEFAULT_BLOCK = 4096

# op name -> (state dtype, message dtype, needs edge weights, needs cur_level)
OPS = {
    "bfs": (jnp.int32, jnp.int32, False, True),
    "sssp": (jnp.float32, jnp.float32, True, False),
    "wcc": (jnp.int32, jnp.int32, False, False),
    "pr": (jnp.float32, jnp.float32, False, False),
    "spmv": (jnp.float32, jnp.float32, True, False),
}


def _apply_op(op, gathered, weights, mask, cur_level):
    """The DSL ``Apply`` stage: per-edge message from gathered source state.

    Mirrors rust/src/dsl/apply.rs lowering and ref.py's edge_program_*.
    """
    if op == "bfs":
        active = (gathered > 0) & mask
        return jnp.where(active, cur_level + 1, INF_I32).astype(jnp.int32)
    if op == "sssp":
        return jnp.where(mask, gathered + weights, INF_F32).astype(jnp.float32)
    if op == "wcc":
        return jnp.where(mask, gathered, INF_I32).astype(jnp.int32)
    if op == "pr":
        return jnp.where(mask, gathered, 0.0).astype(jnp.float32)
    if op == "spmv":
        return jnp.where(mask, gathered * weights, 0.0).astype(jnp.float32)
    raise ValueError(f"unknown edge op {op!r}")


def _kernel(op, block, state_ref, src_ref, w_ref, ne_ref, lvl_ref, out_ref):
    """Pallas kernel body for one edge block.

    Refs (by BlockSpec):
      state_ref : [N]    whole-array vertex state (BRAM analogue)
      src_ref   : [B]    this block's source-vertex ids
      w_ref     : [B]    this block's edge weights (None for unweighted ops)
      ne_ref    : [1]    num_edges scalar
      lvl_ref   : [1]    cur_level scalar (None unless op needs it)
      out_ref   : [B]    per-edge messages out
    """
    pid = pl.program_id(0)
    # Global edge indices covered by this block, for the padding mask.
    idx = pid * block + jax.lax.iota(jnp.int32, block)
    mask = idx < ne_ref[0]
    state = state_ref[...]  # resident vertex state
    src = src_ref[...]
    gathered = state[src]  # the Gather/Receive stage
    weights = w_ref[...] if w_ref is not None else None
    cur_level = lvl_ref[0] if lvl_ref is not None else None
    out_ref[...] = _apply_op(op, gathered, weights, mask, cur_level)


@functools.lru_cache(maxsize=None)
def make_edge_program(op, n, m, block=DEFAULT_BLOCK):
    """Build the blocked edge-program callable for (op, N, M).

    Returns a function with the op-specific positional signature:
      bfs : (state[N]i32, src[M]i32, num_edges[1]i32, cur_level[1]i32)
      sssp: (state[N]f32, src[M]i32, w[M]f32, num_edges[1]i32)
      wcc : (state[N]i32, src[M]i32, num_edges[1]i32)
      pr  : (state[N]f32, src[M]i32, num_edges[1]i32)
      spmv: (state[N]f32, src[M]i32, w[M]f32, num_edges[1]i32)
    producing per-edge messages [M].
    """
    if op not in OPS:
        raise ValueError(f"unknown edge op {op!r}; have {sorted(OPS)}")
    if m % block != 0:
        raise ValueError(f"padded edge count {m} must be a multiple of "
                         f"block {block}")
    state_dt, msg_dt, needs_w, needs_lvl = OPS[op]
    grid = (m // block,)

    whole_state = pl.BlockSpec((n,), lambda i: (0,))
    edge_block = pl.BlockSpec((block,), lambda i: (i,))
    scalar1 = pl.BlockSpec((1,), lambda i: (0,))

    in_specs = [whole_state, edge_block]
    if needs_w:
        in_specs.append(edge_block)
    in_specs.append(scalar1)
    if needs_lvl:
        in_specs.append(scalar1)

    def body(*refs):
        state_ref, src_ref = refs[0], refs[1]
        k = 2
        w_ref = None
        if needs_w:
            w_ref = refs[k]
            k += 1
        ne_ref = refs[k]
        k += 1
        lvl_ref = None
        if needs_lvl:
            lvl_ref = refs[k]
            k += 1
        out_ref = refs[k]
        _kernel(op, block, state_ref, src_ref, w_ref, ne_ref, lvl_ref,
                out_ref)

    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=edge_block,
        out_shape=jax.ShapeDtypeStruct((m,), msg_dt),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )
    return call


def vmem_footprint_bytes(op, n, m, block=DEFAULT_BLOCK):
    """Analytic per-grid-step VMEM footprint of the kernel (perf model).

    state (resident) + src block + optional weight block + output block +
    scalars. Used by DESIGN.md §Perf to justify the block size and by
    `jgraph report --fig 5` annotations.
    """
    _, _, needs_w, needs_lvl = OPS[op]
    state_b = n * 4
    blocks = 2 + (1 if needs_w else 0)  # src + out (+ w)
    scalars = 4 + (4 if needs_lvl else 0)
    return state_b + blocks * block * 4 + scalars
