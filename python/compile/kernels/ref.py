"""Pure-jnp oracles for the Pallas edge-program kernel and the GAS supersteps.

These are the CORE correctness references: every Pallas kernel and every
lowered superstep in :mod:`compile.model` is pytest-compared against the
functions here (see ``python/tests/``), and the rust engine cross-checks the
AOT artifacts against its own software GAS oracle.

Conventions (shared with model.py, aot.py, and rust/src/runtime/):
  - Graphs arrive as padded COO: ``edge_src[M] i32``, ``edge_dst[M] i32``,
    ``edge_w[M] f32``; the first ``num_edges`` entries are real, the rest are
    padding. Padding edges carry ``src = dst = 0`` and must be masked out.
  - Vertex state arrays have padded length ``N``; the first ``num_vertices``
    entries are real.
  - BFS levels use ``-1`` for "unvisited"; distances use ``INF_F32``.
"""

import jax.numpy as jnp

# Sentinel "infinity" used for i32 min-reductions (large but safely away from
# i32 overflow when incremented).
INF_I32 = jnp.int32(2**30)
INF_F32 = jnp.float32(3.0e38)

# The edge-program operators the DSL's Apply stage supports. Mirrors
# rust/src/dsl/apply.rs::ApplyOp and kernels/edge_program.py::OPS.
EDGE_OPS = ("bfs", "sssp", "wcc", "pr", "spmv")


def edge_mask(M, num_edges):
    """Valid-edge mask: the first ``num_edges`` of ``M`` slots are real."""
    return jnp.arange(M, dtype=jnp.int32) < num_edges


# ---------------------------------------------------------------------------
# Edge programs (the L1 Pallas kernel's contract)
# ---------------------------------------------------------------------------

def edge_program_bfs(frontier, edge_src, num_edges, cur_level):
    """Per-edge BFS candidate levels.

    An edge proposes ``cur_level + 1`` for its destination iff its source is
    in the current frontier; inactive/padding edges propose INF_I32.
    """
    m = edge_mask(edge_src.shape[0], num_edges)
    active = (frontier[edge_src] > 0) & m
    return jnp.where(active, cur_level + 1, INF_I32).astype(jnp.int32)


def edge_program_sssp(dist, edge_src, edge_w, num_edges):
    """Per-edge relaxation candidates: dist[src] + w (INF when masked)."""
    m = edge_mask(edge_src.shape[0], num_edges)
    cand = dist[edge_src] + edge_w
    return jnp.where(m, cand, INF_F32).astype(jnp.float32)


def edge_program_wcc(label, edge_src, num_edges):
    """Per-edge label proposals: label[src] (INF when masked)."""
    m = edge_mask(edge_src.shape[0], num_edges)
    return jnp.where(m, label[edge_src], INF_I32).astype(jnp.int32)


def edge_program_pr(contrib, edge_src, num_edges):
    """Per-edge PageRank contributions: rank[src]/outdeg[src], pre-divided.

    ``contrib`` is the per-vertex contribution vector; the edge program
    gathers it per edge. Masked edges contribute 0.
    """
    m = edge_mask(edge_src.shape[0], num_edges)
    return jnp.where(m, contrib[edge_src], 0.0).astype(jnp.float32)


def edge_program_spmv(x, edge_src, edge_w, num_edges):
    """Per-edge products A[dst,src] * x[src] for CSR-as-COO SpMV."""
    m = edge_mask(edge_src.shape[0], num_edges)
    return jnp.where(m, x[edge_src] * edge_w, 0.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Full supersteps (the L2 contract: edge program + Reduce + Apply-to-state)
# ---------------------------------------------------------------------------

def bfs_step(levels, frontier, edge_src, edge_dst, num_edges, cur_level):
    """One BFS frontier expansion.

    Returns (new_levels, new_frontier, frontier_size, edges_traversed).
    """
    N = levels.shape[0]
    cand = edge_program_bfs(frontier, edge_src, num_edges, cur_level)
    # Reduce: min over messages per destination vertex.
    best = jnp.full((N,), INF_I32, dtype=jnp.int32).at[edge_dst].min(cand)
    newly = (levels < 0) & (best < INF_I32)
    new_levels = jnp.where(newly, best, levels).astype(jnp.int32)
    new_frontier = newly.astype(jnp.int32)
    m = edge_mask(edge_src.shape[0], num_edges)
    traversed = jnp.sum(((frontier[edge_src] > 0) & m).astype(jnp.int32))
    return new_levels, new_frontier, jnp.sum(new_frontier), traversed


def sssp_step(dist, edge_src, edge_dst, edge_w, num_edges):
    """One Bellman-Ford relaxation sweep. Returns (new_dist, changed)."""
    N = dist.shape[0]
    cand = edge_program_sssp(dist, edge_src, edge_w, num_edges)
    best = jnp.full((N,), INF_F32, dtype=jnp.float32).at[edge_dst].min(cand)
    new_dist = jnp.minimum(dist, best).astype(jnp.float32)
    changed = jnp.sum((new_dist < dist).astype(jnp.int32))
    return new_dist, changed


def wcc_step(label, edge_src, edge_dst, num_edges):
    """One label-propagation sweep (min label wins). Returns (new, changed)."""
    N = label.shape[0]
    cand = edge_program_wcc(label, edge_src, num_edges)
    best = jnp.full((N,), INF_I32, dtype=jnp.int32).at[edge_dst].min(cand)
    new_label = jnp.minimum(label, best).astype(jnp.int32)
    changed = jnp.sum((new_label < label).astype(jnp.int32))
    return new_label, changed


def pr_step(rank, out_deg, edge_src, edge_dst, num_edges, num_vertices,
            damping=0.85):
    """One PageRank power iteration (damping d, uniform teleport).

    Dangling vertices' mass is redistributed uniformly, matching the rust
    oracle. Returns (new_rank, l1_delta).
    """
    N = rank.shape[0]
    vmask = jnp.arange(N, dtype=jnp.int32) < num_vertices
    nv = num_vertices.astype(jnp.float32)
    safe_deg = jnp.maximum(out_deg, 1).astype(jnp.float32)
    contrib = jnp.where(vmask, rank / safe_deg, 0.0)
    msgs = edge_program_pr(contrib, edge_src, num_edges)
    sums = jnp.zeros((N,), dtype=jnp.float32).at[edge_dst].add(msgs)
    dangling = jnp.sum(jnp.where(vmask & (out_deg == 0), rank, 0.0))
    base = (1.0 - damping) / nv + damping * dangling / nv
    new_rank = jnp.where(vmask, base + damping * sums, 0.0).astype(jnp.float32)
    delta = jnp.sum(jnp.abs(new_rank - rank))
    return new_rank, delta


def spmv_step(x, edge_src, edge_dst, edge_w, num_edges):
    """y = A @ x with A given as COO (dst row, src col). Returns y."""
    N = x.shape[0]
    prod = edge_program_spmv(x, edge_src, edge_w, num_edges)
    return jnp.zeros((N,), dtype=jnp.float32).at[edge_dst].add(prod)
