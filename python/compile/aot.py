"""AOT compile path: lower every (algorithm x size-bucket) superstep to HLO
*text* and write ``artifacts/manifest.json``.

This is the only place Python touches the system: ``make artifacts`` runs it
once; afterwards the rust binary is self-contained (runtime/registry.rs reads
the manifest, PJRT-compiles the HLO text at startup, and executes supersteps
on the request path with zero Python).

Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lower with ``return_tuple=True`` and
unwrap with ``to_tuple*()`` on the rust side. See
/opt/xla-example/load_hlo and aot_recipe.md.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.edge_program import DEFAULT_BLOCK, vmem_footprint_bytes

# Size buckets (padded N vertices, M edges). M must be a multiple of the
# Pallas block. Chosen to cover the paper's two evaluation graphs plus a
# tiny bucket for tests/quickstart and a mid bucket for the examples:
#   email-Eu-core      1,005 v /   25,571 e -> small
#   soc-Slashdot0922  82,168 v /  948,464 e -> large
BUCKETS = {
    "tiny": (256, 4_096),
    "small": (1_024, 32_768),
    "medium": (8_192, 131_072),
    "large": (131_072, 1_048_576),
}


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (the rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Per-bucket Pallas edge-block cap. §Perf (EXPERIMENTS.md): under
# interpret=True on CPU-PJRT, each grid step pays a full interpreter
# dispatch + a copy of the resident state operand, so larger blocks win
# (4096 -> 262144 = 13x on the large bucket, 0.69x of the pure-jnp
# roofline). On a real TPU we would pick 4-16K blocks for double
# buffering; the cap keeps per-step VMEM (state + 3 edge operands)
# within a ~2.5 MB budget either way.
BLOCK_CAP = 262_144


def bucket_block(m, requested=None):
    """Block size for a bucket: the requested override or min(m, cap)."""
    if requested and requested != DEFAULT_BLOCK:
        return requested
    return min(m, BLOCK_CAP)


def lower_one(algo, bucket, block=DEFAULT_BLOCK, use_pallas=True):
    """Lower one superstep; returns (hlo_text, manifest entry)."""
    n, m = BUCKETS[bucket]
    block = bucket_block(m, block)
    step = model.BUILDERS[algo](n, m, block=block, use_pallas=use_pallas)
    specs = model.arg_specs(algo, n, m)
    dt = {"i32": jax.numpy.int32, "f32": jax.numpy.float32}
    avals = [jax.ShapeDtypeStruct(shape, dt[d]) for _, shape, d in specs]
    t0 = time.perf_counter()
    lowered = jax.jit(step).lower(*avals)
    text = to_hlo_text(lowered)
    lower_s = time.perf_counter() - t0
    entry = {
        "algo": algo,
        "bucket": bucket,
        "n": n,
        "m": m,
        "block": block,
        "use_pallas": use_pallas,
        "file": f"{algo}_{bucket}.hlo.txt",
        "inputs": [
            {"name": name, "shape": list(shape), "dtype": d}
            for name, shape, d in specs
        ],
        "outputs": [
            {"name": name, "shape": list(shape), "dtype": d}
            for name, shape, d in model.out_specs(algo, n)
        ],
        "vmem_bytes": vmem_footprint_bytes(algo, n, m, block)
        if algo in ("bfs", "sssp", "wcc", "pr", "spmv") else None,
        "lower_seconds": round(lower_s, 3),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the sentinel artifact (Makefile stamp); "
                         "all artifacts land in its directory")
    ap.add_argument("--algos", default=",".join(model.ALGORITHMS))
    ap.add_argument("--buckets", default=",".join(BUCKETS))
    ap.add_argument("--block", type=int, default=DEFAULT_BLOCK)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead of the "
                         "Pallas kernel (debug/ablation)")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)
    algos = [a for a in args.algos.split(",") if a]
    buckets = [b for b in args.buckets.split(",") if b]

    manifest = {"block": args.block, "buckets": {b: list(BUCKETS[b])
                                                 for b in buckets},
                "artifacts": []}
    total = 0
    for algo in algos:
        for bucket in buckets:
            text, entry = lower_one(algo, bucket, block=args.block,
                                    use_pallas=not args.no_pallas)
            path = os.path.join(out_dir, entry["file"])
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(entry)
            total += len(text)
            print(f"  lowered {algo:5s} {bucket:7s} "
                  f"(N={entry['n']:>7} M={entry['m']:>9}) "
                  f"-> {entry['file']} [{len(text)} chars, "
                  f"{entry['lower_seconds']}s]", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV twin for the (dependency-free, offline) rust manifest parser:
    # algo bucket n m block use_pallas file sha256 inputs outputs, where
    # inputs/outputs are `name:dtype:elements` joined by `;` (scalar -> 0).
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("# jgraph artifact manifest (see rust/src/runtime/artifact.rs)\n")
        for e in manifest["artifacts"]:
            def specs(key):
                return ";".join(
                    f"{t['name']}:{t['dtype']}:"
                    f"{0 if not t['shape'] else t['shape'][0]}"
                    for t in e[key])
            f.write("\t".join([
                e["algo"], e["bucket"], str(e["n"]), str(e["m"]),
                str(e["block"]), "1" if e["use_pallas"] else "0",
                e["file"], e["sha256"], specs("inputs"), specs("outputs"),
            ]) + "\n")
    # The Makefile sentinel: last so a partial run never looks complete.
    with open(args.out, "w") as f:
        f.write(f"# jgraph artifact sentinel: {len(manifest['artifacts'])} "
                f"artifacts, {total} HLO chars\n")
    print(f"wrote {len(manifest['artifacts'])} artifacts "
          f"({total} HLO chars) to {out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
