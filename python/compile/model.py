"""L2 — GAS supersteps as JAX functions, calling the L1 Pallas edge kernel.

Each function is one hardware "iteration" of the paper's GAS pipeline
(Fig. 4): the edge program (L1 Pallas, the Receive+Apply stages) produces
per-edge messages; the Reduce stage is a segment min/sum scatter; the final
Apply-to-state updates the vertex arrays. All of it traces into a single
fused HLO module per (algorithm, size bucket), so the rust coordinator makes
exactly one PJRT call per superstep.

Shapes are static per bucket (see aot.py); ``num_edges`` / ``num_vertices`` /
``cur_level`` travel as [1]-shaped i32 operands so the same artifact serves
any graph that fits the bucket (the rust registry pads).

Every superstep has a pure-jnp twin in kernels/ref.py; pytest asserts
equality, and hypothesis sweeps shapes. The rust engine additionally
cross-checks the compiled artifacts against its own software GAS oracle.
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels.edge_program import DEFAULT_BLOCK, make_edge_program

# Algorithm registry: name -> builder of the superstep function. Used by
# aot.py to enumerate artifacts and by tests to sweep all algorithms.
ALGORITHMS = ("bfs", "pr", "sssp", "wcc", "spmv")


def build_bfs_step(n, m, block=DEFAULT_BLOCK, use_pallas=True):
    """BFS frontier expansion.

    Args (positional, the artifact ABI):
      levels[N]i32, frontier[N]i32, edge_src[M]i32, edge_dst[M]i32,
      num_edges[1]i32, cur_level[1]i32
    Returns: (new_levels[N]i32, new_frontier[N]i32, frontier_size i32,
              edges_traversed i32)
    """
    edge_prog = make_edge_program("bfs", n, m, block) if use_pallas else None

    def step(levels, frontier, edge_src, edge_dst, num_edges, cur_level):
        ne = num_edges[0]
        if use_pallas:
            cand = edge_prog(frontier, edge_src, num_edges, cur_level)
        else:
            cand = ref.edge_program_bfs(frontier, edge_src, ne, cur_level[0])
        best = (jnp.full((n,), ref.INF_I32, dtype=jnp.int32)
                .at[edge_dst].min(cand))
        newly = (levels < 0) & (best < ref.INF_I32)
        new_levels = jnp.where(newly, best, levels).astype(jnp.int32)
        new_frontier = newly.astype(jnp.int32)
        mask = ref.edge_mask(m, ne)
        traversed = jnp.sum(((frontier[edge_src] > 0) & mask)
                            .astype(jnp.int32))
        return new_levels, new_frontier, jnp.sum(new_frontier), traversed

    return step


def build_sssp_step(n, m, block=DEFAULT_BLOCK, use_pallas=True):
    """Bellman-Ford relaxation sweep.

    ABI: dist[N]f32, edge_src[M]i32, edge_dst[M]i32, edge_w[M]f32,
         num_edges[1]i32 -> (new_dist[N]f32, changed i32)
    """
    edge_prog = make_edge_program("sssp", n, m, block) if use_pallas else None

    def step(dist, edge_src, edge_dst, edge_w, num_edges):
        if use_pallas:
            cand = edge_prog(dist, edge_src, edge_w, num_edges)
        else:
            cand = ref.edge_program_sssp(dist, edge_src, edge_w, num_edges[0])
        best = (jnp.full((n,), ref.INF_F32, dtype=jnp.float32)
                .at[edge_dst].min(cand))
        new_dist = jnp.minimum(dist, best).astype(jnp.float32)
        changed = jnp.sum((new_dist < dist).astype(jnp.int32))
        return new_dist, changed

    return step


def build_wcc_step(n, m, block=DEFAULT_BLOCK, use_pallas=True):
    """Label-propagation sweep (min label wins).

    ABI: label[N]i32, edge_src[M]i32, edge_dst[M]i32, num_edges[1]i32
         -> (new_label[N]i32, changed i32)
    """
    edge_prog = make_edge_program("wcc", n, m, block) if use_pallas else None

    def step(label, edge_src, edge_dst, num_edges):
        if use_pallas:
            cand = edge_prog(label, edge_src, num_edges)
        else:
            cand = ref.edge_program_wcc(label, edge_src, num_edges[0])
        best = (jnp.full((n,), ref.INF_I32, dtype=jnp.int32)
                .at[edge_dst].min(cand))
        new_label = jnp.minimum(label, best).astype(jnp.int32)
        changed = jnp.sum((new_label < label).astype(jnp.int32))
        return new_label, changed

    return step


def build_pr_step(n, m, block=DEFAULT_BLOCK, use_pallas=True, damping=0.85):
    """PageRank power iteration with uniform dangling redistribution.

    ABI: rank[N]f32, out_deg[N]i32, edge_src[M]i32, edge_dst[M]i32,
         num_edges[1]i32, num_vertices[1]i32 -> (new_rank[N]f32, delta f32)
    """
    edge_prog = make_edge_program("pr", n, m, block) if use_pallas else None

    def step(rank, out_deg, edge_src, edge_dst, num_edges, num_vertices):
        nv_i = num_vertices[0]
        vmask = jnp.arange(n, dtype=jnp.int32) < nv_i
        nv = nv_i.astype(jnp.float32)
        safe_deg = jnp.maximum(out_deg, 1).astype(jnp.float32)
        contrib = jnp.where(vmask, rank / safe_deg, 0.0)
        if use_pallas:
            msgs = edge_prog(contrib, edge_src, num_edges)
        else:
            msgs = ref.edge_program_pr(contrib, edge_src, num_edges[0])
        sums = jnp.zeros((n,), dtype=jnp.float32).at[edge_dst].add(msgs)
        dangling = jnp.sum(jnp.where(vmask & (out_deg == 0), rank, 0.0))
        base = (1.0 - damping) / nv + damping * dangling / nv
        new_rank = jnp.where(vmask, base + damping * sums, 0.0) \
            .astype(jnp.float32)
        delta = jnp.sum(jnp.abs(new_rank - rank))
        return new_rank, delta

    return step


def build_spmv_step(n, m, block=DEFAULT_BLOCK, use_pallas=True):
    """Sparse matrix-vector product, A in COO (dst=row, src=col).

    ABI: x[N]f32, edge_src[M]i32, edge_dst[M]i32, edge_w[M]f32,
         num_edges[1]i32 -> (y[N]f32,)
    """
    edge_prog = make_edge_program("spmv", n, m, block) if use_pallas else None

    def step(x, edge_src, edge_dst, edge_w, num_edges):
        if use_pallas:
            prod = edge_prog(x, edge_src, edge_w, num_edges)
        else:
            prod = ref.edge_program_spmv(x, edge_src, edge_w, num_edges[0])
        y = jnp.zeros((n,), dtype=jnp.float32).at[edge_dst].add(prod)
        return (y,)

    return step


BUILDERS = {
    "bfs": build_bfs_step,
    "pr": build_pr_step,
    "sssp": build_sssp_step,
    "wcc": build_wcc_step,
    "spmv": build_spmv_step,
}


def arg_specs(algo, n, m):
    """The artifact ABI: ordered (name, shape, dtype) for each input.

    Mirrored by rust/src/runtime/registry.rs — keep in sync with
    manifest.json (aot.py embeds this spec there).
    """
    i32, f32 = "i32", "f32"
    specs = {
        "bfs": [("levels", (n,), i32), ("frontier", (n,), i32),
                ("edge_src", (m,), i32), ("edge_dst", (m,), i32),
                ("num_edges", (1,), i32), ("cur_level", (1,), i32)],
        "pr": [("rank", (n,), f32), ("out_deg", (n,), i32),
               ("edge_src", (m,), i32), ("edge_dst", (m,), i32),
               ("num_edges", (1,), i32), ("num_vertices", (1,), i32)],
        "sssp": [("dist", (n,), f32), ("edge_src", (m,), i32),
                 ("edge_dst", (m,), i32), ("edge_w", (m,), f32),
                 ("num_edges", (1,), i32)],
        "wcc": [("label", (n,), i32), ("edge_src", (m,), i32),
                ("edge_dst", (m,), i32), ("num_edges", (1,), i32)],
        "spmv": [("x", (n,), f32), ("edge_src", (m,), i32),
                 ("edge_dst", (m,), i32), ("edge_w", (m,), f32),
                 ("num_edges", (1,), i32)],
    }
    return specs[algo]


def out_specs(algo, n):
    """Ordered (name, shape, dtype) for each output of the tuple."""
    i32, f32 = "i32", "f32"
    specs = {
        "bfs": [("new_levels", (n,), i32), ("new_frontier", (n,), i32),
                ("frontier_size", (), i32), ("edges_traversed", (), i32)],
        "pr": [("new_rank", (n,), f32), ("delta", (), f32)],
        "sssp": [("new_dist", (n,), f32), ("changed", (), i32)],
        "wcc": [("new_label", (n,), i32), ("changed", (), i32)],
        "spmv": [("y", (n,), f32)],
    }
    return specs[algo]
