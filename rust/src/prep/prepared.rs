//! **PreparedGraph** — the cached product of the per-graph preprocessing
//! stages (Read → Layout → Reorder/Partition of the paper's Algorithm 1),
//! plus derived quantities the simulator consumes (edge-gap locality).
//!
//! Preparing a graph is a one-time cost in the paper's economics: queries
//! against the same graph reuse the CSR, the reorder permutation, the
//! partitioning, and the locality statistics. The engine's
//! [`crate::engine::CompiledPipeline::load`] builds one of these and binds
//! it to a compiled design; [`PreparedGraph::prepare`] can also be called
//! directly to share one prepared graph across several pipelines.

use std::sync::OnceLock;
use std::time::Instant;

use anyhow::Result;

use crate::engine::gas::EngineGraph;
use crate::graph::csr::Csr;
use crate::graph::edgelist::EdgeList;
use crate::graph::VertexId;

use super::calibrate::Calibration;
use super::partition::{destination_ranges, partition, PartitionStrategy, Partitioning};
use super::reorder::{reorder, ReorderStrategy};
use super::shard::ShardedGraph;

/// Per-graph deployment knobs: everything that shapes how a graph is laid
/// out on the device, decided once per graph (not per query). This is the
/// new home of the old `ExecutorConfig::{graph_name, reorder, partition}`
/// fields.
#[derive(Debug, Clone)]
pub struct PrepOptions {
    /// Label for reports.
    pub graph_name: String,
    /// Optional Reorder preprocessing.
    pub reorder: Option<ReorderStrategy>,
    /// Optional Partition preprocessing (parts, strategy) for multi-PE
    /// placement.
    pub partition: Option<(usize, PartitionStrategy)>,
    /// Auto-shard count for intra-superstep parallelism on an
    /// *un-partitioned* binding. `None` (the default) sizes it
    /// automatically from the worker budget with a cost gate
    /// ([`PreparedGraph::AUTO_SHARD_MIN_EDGES`]); `Some(k)` pins `k`
    /// shards regardless of the gate; `Some(1)` disables auto-sharding —
    /// the pre-PR-8 single-thread monolithic sweep. Ignored when an
    /// explicit `partition` is set (user shards win).
    pub auto_shards: Option<usize>,
}

impl Default for PrepOptions {
    fn default() -> Self {
        Self { graph_name: "graph".into(), reorder: None, partition: None, auto_shards: None }
    }
}

impl PrepOptions {
    /// Default options with a report label.
    pub fn named(graph_name: impl Into<String>) -> Self {
        Self { graph_name: graph_name.into(), ..Self::default() }
    }

    pub fn with_reorder(mut self, strategy: ReorderStrategy) -> Self {
        self.reorder = Some(strategy);
        self
    }

    pub fn with_partition(mut self, parts: usize, strategy: PartitionStrategy) -> Self {
        self.partition = Some((parts, strategy));
        self
    }

    /// Pin the auto-shard count (see [`PrepOptions::auto_shards`]);
    /// `with_auto_shards(1)` disables auto-sharding.
    pub fn with_auto_shards(mut self, k: usize) -> Self {
        self.auto_shards = Some(k);
        self
    }
}

/// A graph after preprocessing: the layout decisions (CSR + optional
/// reorder/partition) and the derived statistics, computed exactly once
/// and reused by every query.
#[derive(Debug, Clone)]
pub struct PreparedGraph {
    /// Report label (from [`PrepOptions::graph_name`]).
    pub name: String,
    /// The on-device layout (out-edge CSR of the working graph).
    pub csr: Csr,
    /// The transposed layout (in-edge CSC), built **lazily, once** by the
    /// stable counting-sort [`Csr::transpose`] on the first pull-capable
    /// query and shared by every query thereafter (including across
    /// threads in `run_batch_parallel`). The stability is load-bearing:
    /// it is what makes pull execution bit-identical to push (see the
    /// engine docs). Push-only-pinned workloads never pay the transpose
    /// time or the 2x adjacency memory.
    csc: OnceLock<Csr>,
    /// Out-degree of every vertex (`csr.degree(v)`), cached lazily with
    /// the CSC so PageRank contribution scaling and the push/pull
    /// frontier heuristic never rebuild it per query.
    out_deg: OnceLock<Vec<u32>>,
    /// CSC-order destination stream (`v` repeated in-degree(`v`) times):
    /// the trace every full-sweep pull superstep streams, cached lazily
    /// so PageRank queries don't rebuild an O(E) array each.
    pull_stream: OnceLock<Vec<u32>>,
    /// Per-partition CSR/CSC shards ([`ShardedGraph`]), built **lazily,
    /// once** from the partitioning (and the CSC, which it forces) on the
    /// first sharded query. Unpartitioned graphs never build shards.
    sharded: OnceLock<ShardedGraph>,
    /// Auto-sharding for un-partitioned bindings: degree-balanced
    /// destination ranges ([`destination_ranges`]), built **lazily, once**
    /// on the first query that can use them. `None` inside when the graph
    /// is below the cost gate or the resolved shard count is 1.
    auto_sharded: OnceLock<Option<ShardedGraph>>,
    /// Requested auto-shard count ([`PrepOptions::auto_shards`]).
    auto_shards: Option<usize>,
    /// Fitted calibration constants (`jgraph calibrate`), set at most
    /// once; queries read [`PreparedGraph::calibration`] which falls back
    /// to the hand-set defaults.
    calibration: OnceLock<Calibration>,
    /// `(strategy, perm)` with `perm[old] = new` when reordering was
    /// applied. Roots passed to queries address the *reordered* id space,
    /// matching the old executor's semantics.
    pub reorder: Option<(ReorderStrategy, Vec<VertexId>)>,
    /// Partitioning for multi-PE placement (cut stats land in reports).
    pub partitioning: Option<Partitioning>,
    /// Mean |src-dst| id gap (simulator locality input), cached so queries
    /// do not rescan the edge array.
    pub avg_edge_gap: f64,
    /// Wall time of preparation (the Fig. 5 preparation period, paid once).
    pub prep_seconds: f64,
}

impl PreparedGraph {
    /// Run the preprocessing stages once: Reorder (optional) → Partition
    /// (optional) → Layout (CSR) → locality scan.
    pub fn prepare(graph: &EdgeList, opts: &PrepOptions) -> Result<Self> {
        let t0 = Instant::now();
        let reordered = opts.reorder.map(|strategy| {
            let (el, perm) = reorder(graph, strategy);
            (strategy, el, perm)
        });
        let working: &EdgeList = match &reordered {
            Some((_, el, _)) => el,
            None => graph,
        };
        let partitioning = match opts.partition {
            Some((parts, strategy)) => Some(partition(working, parts, strategy)?),
            None => None,
        };
        let csr = Csr::from_edgelist(working);
        let avg_edge_gap = crate::engine::gas::avg_edge_gap(&csr);
        Ok(Self {
            name: opts.graph_name.clone(),
            csr,
            csc: OnceLock::new(),
            out_deg: OnceLock::new(),
            pull_stream: OnceLock::new(),
            sharded: OnceLock::new(),
            auto_sharded: OnceLock::new(),
            auto_shards: opts.auto_shards,
            calibration: OnceLock::new(),
            reorder: reordered.map(|(strategy, _, perm)| (strategy, perm)),
            partitioning,
            avg_edge_gap,
            prep_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// The cached transpose (in-edge CSC), built on first use.
    pub fn csc(&self) -> &Csr {
        self.csc.get_or_init(|| self.csr.transpose())
    }

    /// Cached out-degrees, built on first use.
    pub fn out_deg(&self) -> &[u32] {
        self.out_deg.get_or_init(|| self.csr.out_degrees())
    }

    /// Cached CSC-order destination stream (the full-sweep pull trace),
    /// built on first use.
    pub fn pull_stream(&self) -> &[u32] {
        self.pull_stream.get_or_init(|| self.csc().row_run_stream())
    }

    /// The cached [`ShardedGraph`], built on first use from the
    /// partitioning; `None` when the graph was prepared without one.
    /// Forces the CSC (the pull slices copy its rows).
    pub fn sharded(&self) -> Option<&ShardedGraph> {
        self.partitioning
            .as_ref()
            .map(|p| self.sharded.get_or_init(|| ShardedGraph::build(&self.csr, self.csc(), p)))
    }

    /// Minimum edge count before *automatic* auto-sharding engages: below
    /// this, one superstep finishes faster than the shard-merge machinery
    /// costs, so tiny graphs keep the monolithic sweep. An explicit
    /// [`PrepOptions::with_auto_shards`] bypasses the gate.
    pub const AUTO_SHARD_MIN_EDGES: usize = 32_768;

    /// Ceiling on the automatically-chosen shard count: beyond this the
    /// per-superstep merge overhead outgrows what extra workers return.
    pub const AUTO_SHARD_MAX: usize = 16;

    /// The auto-sharding for an *un-partitioned* binding: degree-balanced
    /// contiguous destination ranges (see [`destination_ranges`]), built
    /// lazily once and shared by every query. Returns `None` when the
    /// graph has a user partitioning (use [`PreparedGraph::sharded`]),
    /// when automatic sizing is below the
    /// [`PreparedGraph::AUTO_SHARD_MIN_EDGES`] cost gate or resolves to
    /// fewer than 2 shards (single-core budget), or when
    /// [`PrepOptions::auto_shards`] pinned the count to 1.
    ///
    /// The decision is **static** per prepared graph — it never depends
    /// on momentary budget contention — so every query on a binding takes
    /// the same execution path and reports stay bit-identical between
    /// sequential and batch-parallel runs.
    pub fn auto_sharded(&self) -> Option<&ShardedGraph> {
        if self.partitioning.is_some() {
            return None;
        }
        self.auto_sharded
            .get_or_init(|| {
                let k = self.auto_shard_count();
                if k < 2 {
                    return None;
                }
                let p = destination_ranges(&self.csr, self.csc(), k);
                Some(ShardedGraph::build(&self.csr, self.csc(), &p))
            })
            .as_ref()
    }

    /// [`PreparedGraph::auto_sharded`] filtered by the query's direction
    /// policy: *automatic* auto-sharding never engages for a
    /// push-only-pinned query — those queries keep the promise of never
    /// paying the transpose (the shard build forces the CSC) — while an
    /// explicit [`PrepOptions::with_auto_shards`] engages regardless (the
    /// user asked for shards; the shard slices carry their own CSC rows).
    pub fn auto_sharded_for(&self, push_only: bool) -> Option<&ShardedGraph> {
        if push_only && self.auto_shards.is_none() {
            return None;
        }
        self.auto_sharded()
    }

    /// Resolve the auto-shard count: the pinned
    /// [`PrepOptions::auto_shards`] verbatim; else a calibrated count
    /// (trusted over the edge-count gate — it was *measured* on this
    /// graph); else, past the cost gate, the machine's worker budget,
    /// capped.
    fn auto_shard_count(&self) -> usize {
        let k = match (self.auto_shards, self.calibration().auto_shards) {
            (Some(k), _) => k.max(1),
            (None, Some(k)) => k.clamp(1, Self::AUTO_SHARD_MAX),
            (None, None) => {
                if self.num_edges() < Self::AUTO_SHARD_MIN_EDGES {
                    return 1;
                }
                crate::sched::available_workers().min(Self::AUTO_SHARD_MAX)
            }
        };
        k.min(self.num_vertices().max(1))
    }

    /// The constants queries on this graph tune themselves with: fitted
    /// values when [`PreparedGraph::set_calibration`] ran, defaults
    /// otherwise.
    pub fn calibration(&self) -> Calibration {
        self.calibration.get().copied().unwrap_or_default()
    }

    /// Store fitted calibration constants (at most once per prepared
    /// graph; returns `false` if already set). Call **before** the first
    /// query: the auto-shard layout is itself built once on first use, so
    /// a calibrated shard count only takes effect if it arrives first.
    pub fn set_calibration(&self, calibration: Calibration) -> bool {
        self.calibration.set(calibration).is_ok()
    }

    /// The engine's view of the cached arrays — what every pull-capable
    /// query on a binding executes over (CSR + CSC + out-degrees, all
    /// shared; those lazy caches materialize here), carrying the graph's
    /// [`PreparedGraph::calibration`] crossover for the adaptive policy.
    /// The O(E) [`PreparedGraph::pull_stream`] is **not** attached: only
    /// full-sweep PageRank runs read it, so the query layer chains
    /// `.with_pull_stream(..)` for exactly those programs. Push-only
    /// callers should use [`crate::engine::gas::EngineGraph::push_only`]
    /// instead, which touches none of the caches.
    pub fn engine_view(&self) -> EngineGraph<'_> {
        EngineGraph::with_csc(&self.csr, self.csc(), Some(self.out_deg()))
            .with_crossover(self.calibration().crossover())
    }

    /// The CSR edge stream (destination per edge, row-major) — exactly
    /// the order the accelerator streams `Edges` and the order every
    /// push-direction trace uses. This **is** `csr.targets`: cached by
    /// construction, never re-derived per query.
    pub fn edge_stream(&self) -> &[VertexId] {
        &self.csr.targets
    }

    pub fn num_vertices(&self) -> usize {
        self.csr.num_vertices()
    }

    pub fn num_edges(&self) -> usize {
        self.csr.num_edges()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn prepare_plain_builds_csr_and_gap() {
        let g = generate::chain(50);
        let p = PreparedGraph::prepare(&g, &PrepOptions::named("chain")).unwrap();
        assert_eq!(p.name, "chain");
        assert_eq!(p.num_vertices(), 50);
        assert_eq!(p.num_edges(), 49);
        assert!((p.avg_edge_gap - 1.0).abs() < 1e-9, "chain gap is 1");
        assert!(p.reorder.is_none() && p.partitioning.is_none());
        assert!(p.prep_seconds >= 0.0);
    }

    #[test]
    fn prepare_applies_reorder_and_partition() {
        let g = generate::rmat(8, 2_000, 0.57, 0.19, 0.19, 5);
        let opts = PrepOptions::named("rmat")
            .with_reorder(ReorderStrategy::DegreeSort)
            .with_partition(4, PartitionStrategy::Hash);
        let p = PreparedGraph::prepare(&g, &opts).unwrap();
        let (strategy, perm) = p.reorder.as_ref().unwrap();
        assert_eq!(*strategy, ReorderStrategy::DegreeSort);
        assert_eq!(perm.len(), g.num_vertices);
        let part = p.partitioning.as_ref().unwrap();
        assert_eq!(part.num_parts, 4);
        assert_eq!(part.assignment.len(), g.num_vertices);
        // reordering preserves the edge multiset size
        assert_eq!(p.num_edges(), g.num_edges());
    }

    #[test]
    fn lazy_caches_agree_with_direct_derivation() {
        let g = generate::rmat(8, 2_500, 0.57, 0.19, 0.19, 13);
        let p = PreparedGraph::prepare(&g, &PrepOptions::named("rmat")).unwrap();
        assert_eq!(p.csc(), &p.csr.transpose(), "cached CSC is the stable transpose");
        assert_eq!(p.out_deg().len(), p.num_vertices());
        for v in 0..p.num_vertices() as u32 {
            assert_eq!(p.out_deg()[v as usize], p.csr.degree(v));
        }
        assert_eq!(p.edge_stream(), &p.csr.targets[..]);
        let expect: Vec<u32> = (0..p.num_vertices() as u32)
            .flat_map(|v| std::iter::repeat(v).take(p.csc().degree(v) as usize))
            .collect();
        assert_eq!(p.pull_stream(), &expect[..]);
        // the engine view exposes the same cached arrays; the O(E) pull
        // stream stays detached until a PageRank query asks for it
        let view = p.engine_view();
        assert_eq!(view.csr.num_edges(), p.num_edges());
        assert!(view.csc.is_some() && view.out_deg.is_some());
        assert!(view.pull_dsts.is_none(), "pull stream is opt-in per program");
        assert!(view.with_pull_stream(p.pull_stream()).pull_dsts.is_some());
    }

    #[test]
    fn auto_sharding_gates_and_pins() {
        // below the cost gate, automatic sizing declines to shard
        let g = generate::rmat(8, 2_000, 0.57, 0.19, 0.19, 5);
        let p = PreparedGraph::prepare(&g, &PrepOptions::named("small")).unwrap();
        assert!(p.auto_sharded().is_none(), "2k edges is below the gate");
        // an explicit count bypasses the gate
        let p =
            PreparedGraph::prepare(&g, &PrepOptions::named("small").with_auto_shards(4)).unwrap();
        let sg = p.auto_sharded().expect("pinned auto-shards");
        assert_eq!(sg.num_shards, 4);
        assert!(std::ptr::eq(sg, p.auto_sharded().unwrap()), "built once, cached");
        // auto_shards == 1 pins the monolithic sweep
        let p =
            PreparedGraph::prepare(&g, &PrepOptions::named("small").with_auto_shards(1)).unwrap();
        assert!(p.auto_sharded().is_none());
        // a user partitioning wins over auto-sharding
        let opts = PrepOptions::named("small")
            .with_partition(2, PartitionStrategy::Hash)
            .with_auto_shards(4);
        let p = PreparedGraph::prepare(&g, &opts).unwrap();
        assert!(p.auto_sharded().is_none());
        assert!(p.sharded().is_some());
        // pinned counts clamp to the vertex count
        let tiny = generate::chain(3);
        let p =
            PreparedGraph::prepare(&tiny, &PrepOptions::named("tiny").with_auto_shards(8)).unwrap();
        if let Some(sg) = p.auto_sharded() {
            assert!(sg.num_shards <= 3);
        }
    }

    #[test]
    fn calibration_defaults_and_sets_once() {
        let g = generate::chain(10);
        let p = PreparedGraph::prepare(&g, &PrepOptions::named("chain")).unwrap();
        let def = p.calibration();
        assert_eq!(def, Calibration::default());
        assert_eq!(p.engine_view().crossover, def.crossover());
        let fitted = Calibration {
            pull_alpha_early_exit: 16,
            pull_alpha_full_scan: 3,
            auto_shards: Some(2),
        };
        assert!(p.set_calibration(fitted));
        assert!(!p.set_calibration(Calibration::default()), "set-once");
        assert_eq!(p.calibration(), fitted);
        assert_eq!(p.engine_view().crossover.alpha_early_exit, 16);
        assert_eq!(p.engine_view().crossover.alpha_full_scan, 3);
    }

    #[test]
    fn prepare_matches_manual_pipeline() {
        // PreparedGraph must equal reorder -> Csr done by hand
        let g = generate::erdos_renyi(100, 600, 9);
        let opts = PrepOptions::named("er").with_reorder(ReorderStrategy::BfsLocality);
        let p = PreparedGraph::prepare(&g, &opts).unwrap();
        let (manual, _) = reorder(&g, ReorderStrategy::BfsLocality);
        assert_eq!(p.csr, Csr::from_edgelist(&manual));
    }
}
