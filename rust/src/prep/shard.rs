//! **ShardedGraph** — per-partition CSR/CSC slices with an owned/halo
//! split and a precomputed boundary-exchange plan, built once from a
//! [`Partitioning`] and cached on
//! [`crate::prep::prepared::PreparedGraph`] the same lazy `OnceLock` way
//! as the CSC and the pull stream.
//!
//! ## Ownership layout
//!
//! Sharding is by **destination**: shard `s` owns exactly the vertices
//! the partitioning assigned to part `s`, and every edge belongs to the
//! shard that owns its *destination*. Each shard therefore holds
//!
//! * a **push slice** — for every global source row `u`, the sub-row of
//!   `u`'s out-edges whose destination this shard owns, in CSR stream
//!   order (`push_offsets` is indexed by *global* source id so a worker
//!   can walk any frontier without translation);
//! * a **pull slice** — for every *owned* destination (local index), its
//!   full in-edge row in CSC order, plus the CSC-order destination
//!   stream (`pull_dst_stream`) that is the shard's full-sweep pull
//!   trace;
//! * the **halo** — the sorted, deduplicated set of foreign source
//!   vertices this shard reads during a pull sweep (boundary vertices
//!   whose values must be visible before the superstep), and
//!   `crossing_in`, the number of cut edges entering the shard — the
//!   per-superstep boundary-exchange volume of a dense sweep.
//!
//! ## Why destination ownership makes sharding bit-exact
//!
//! The engine's exactness contract (see [`crate::engine::gas`]) is that
//! per-destination reductions accumulate messages in CSR-stream order.
//! Destination ownership preserves exactly that order inside one shard:
//! a push worker walks frontier sources ascending and each filtered
//! sub-row keeps CSR order, so the message sequence arriving at any
//! owned vertex `v` is identical to the monolithic engine's; a pull
//! worker reads `v`'s CSC row, which [`Csr::transpose`] keeps in the
//! same delivery order. Because owned sets are disjoint, workers write
//! only private accumulators and **no cross-shard merge ever combines
//! two partial reductions for the same vertex** — the merge-order rule
//! is that ordering only matters *within* a destination row, and the
//! layout confines every row to one shard. Boundary exchange is
//! therefore pure message traffic (reads of foreign source values),
//! never a float reassociation, which is what lets the sharded engine
//! honor any [`crate::analysis::ParallelSafety`] certificate while
//! staying bit-identical even for `OrderSensitive` float sums.

use crate::graph::csr::Csr;
use crate::graph::VertexId;

use super::partition::Partitioning;

/// One shard: the edges destined to its owned vertices, sliced both ways.
#[derive(Debug, Clone, Default)]
pub struct Shard {
    /// Owned global vertex ids, ascending. `owned[local] = global`.
    pub owned: Vec<u32>,
    /// Push slice row pointers, indexed by **global** source id
    /// (`len == n + 1`): `push_offsets[u]..push_offsets[u+1]` is `u`'s
    /// sub-row of out-edges destined to this shard.
    pub push_offsets: Vec<u32>,
    /// Global destination ids of the push slice, CSR stream order.
    pub push_dsts: Vec<u32>,
    /// Weights parallel to `push_dsts`.
    pub push_weights: Vec<f32>,
    /// Pull slice row pointers, indexed by **local** owned index
    /// (`len == owned.len() + 1`).
    pub pull_offsets: Vec<u32>,
    /// Global source ids of the pull slice, CSC (= delivery) order.
    pub pull_srcs: Vec<u32>,
    /// Weights parallel to `pull_srcs`.
    pub pull_weights: Vec<f32>,
    /// Each owned destination repeated in-degree times, ascending runs —
    /// the shard's full-sweep pull trace stream.
    pub pull_dst_stream: Vec<u32>,
    /// Distinct foreign (boundary) source vertices read by this shard's
    /// pull slice, sorted ascending.
    pub halo: Vec<u32>,
    /// Cut edges entering this shard (foreign source, owned destination):
    /// the shard's per-dense-superstep exchange volume.
    pub crossing_in: u64,
}

impl Shard {
    pub fn num_owned(&self) -> usize {
        self.owned.len()
    }

    /// Edges destined to this shard in the push slice.
    pub fn num_push_edges(&self) -> usize {
        self.push_dsts.len()
    }

    /// Length of global source `u`'s sub-row.
    #[inline]
    pub fn push_row_len(&self, u: VertexId) -> u32 {
        self.push_offsets[u as usize + 1] - self.push_offsets[u as usize]
    }

    /// `(dst, weight)` pairs of global source `u`'s sub-row, CSR order.
    #[inline]
    pub fn push_row(&self, u: VertexId) -> impl Iterator<Item = (u32, f32)> + '_ {
        let a = self.push_offsets[u as usize] as usize;
        let b = self.push_offsets[u as usize + 1] as usize;
        self.push_dsts[a..b].iter().copied().zip(self.push_weights[a..b].iter().copied())
    }

    /// `(src, weight)` pairs of local destination `local`'s in-row, CSC
    /// (= delivery) order.
    #[inline]
    pub fn pull_row(&self, local: u32) -> impl Iterator<Item = (u32, f32)> + '_ {
        let a = self.pull_offsets[local as usize] as usize;
        let b = self.pull_offsets[local as usize + 1] as usize;
        self.pull_srcs[a..b].iter().copied().zip(self.pull_weights[a..b].iter().copied())
    }
}

/// A prepared graph split into per-partition shards (see module docs).
#[derive(Debug, Clone)]
pub struct ShardedGraph {
    pub num_shards: usize,
    /// `owner[v]` = shard owning global vertex `v` (the partitioning's
    /// assignment).
    pub owner: Vec<u32>,
    /// `local_id[v]` = `v`'s index in its owner's `owned` list.
    pub local_id: Vec<u32>,
    pub shards: Vec<Shard>,
    /// Total cut edges (= `Σ shards[s].crossing_in` =
    /// `Partitioning::cut_edges`).
    pub total_crossing: u64,
}

impl ShardedGraph {
    /// Slice `csr`/`csc` along `partitioning`. `csc` must be
    /// `csr.transpose()` — the pull slices inherit its stable delivery
    /// order.
    pub fn build(csr: &Csr, csc: &Csr, partitioning: &Partitioning) -> Self {
        let n = csr.num_vertices();
        let k = partitioning.num_parts.max(1);
        debug_assert_eq!(partitioning.assignment.len(), n, "partitioning matches graph");
        debug_assert_eq!(csc.num_edges(), csr.num_edges(), "csc must transpose csr");
        let owner = partitioning.assignment.clone();
        let mut local_id = vec![0u32; n];
        let mut shards: Vec<Shard> = (0..k).map(|_| Shard::default()).collect();
        for (v, &s) in owner.iter().enumerate() {
            local_id[v] = shards[s as usize].owned.len() as u32;
            shards[s as usize].owned.push(v as u32);
        }
        // Push slices: one pass over the CSR stream, scattering each edge
        // to its destination's shard and closing every shard's row after
        // each source — O(E + k·n), and each sub-row keeps CSR order.
        for shard in shards.iter_mut() {
            shard.push_offsets.reserve(n + 1);
            shard.push_offsets.push(0);
        }
        for u in 0..n as VertexId {
            for (_, v, w) in csr.row_edges(u) {
                let s = &mut shards[owner[v as usize] as usize];
                s.push_dsts.push(v);
                s.push_weights.push(w);
            }
            for shard in shards.iter_mut() {
                shard.push_offsets.push(shard.push_dsts.len() as u32);
            }
        }
        // Pull slices + halo + exchange plan: each shard copies its owned
        // vertices' CSC rows verbatim (delivery order preserved).
        let mut total_crossing = 0u64;
        for (s, shard) in shards.iter_mut().enumerate() {
            shard.pull_offsets.push(0);
            let mut halo = Vec::new();
            // borrow `owned` out of the shard we're mutating
            let owned = std::mem::take(&mut shard.owned);
            for &v in &owned {
                for (_, u, w) in csc.row_edges(v) {
                    shard.pull_srcs.push(u);
                    shard.pull_weights.push(w);
                    shard.pull_dst_stream.push(v);
                    if owner[u as usize] as usize != s {
                        shard.crossing_in += 1;
                        halo.push(u);
                    }
                }
                shard.pull_offsets.push(shard.pull_srcs.len() as u32);
            }
            shard.owned = owned;
            halo.sort_unstable();
            halo.dedup();
            shard.halo = halo;
            total_crossing += shard.crossing_in;
        }
        Self { num_shards: k, owner, local_id, shards, total_crossing }
    }

    /// Total edges across all shards' push slices (must equal the graph's
    /// edge count: every edge lands in exactly one shard).
    pub fn num_edges(&self) -> usize {
        self.shards.iter().map(|s| s.push_dsts.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::prep::partition::{partition, PartitionStrategy};

    const STRATS: [PartitionStrategy; 4] = [
        PartitionStrategy::Range,
        PartitionStrategy::Hash,
        PartitionStrategy::DegreeBalanced,
        PartitionStrategy::BfsGrow,
    ];

    fn build(el: &crate::graph::edgelist::EdgeList, k: usize, s: PartitionStrategy) -> ShardedGraph {
        let csr = Csr::from_edgelist(el);
        let csc = csr.transpose();
        let p = partition(el, k, s).unwrap();
        ShardedGraph::build(&csr, &csc, &p)
    }

    #[test]
    fn shards_partition_vertices_and_edges_exactly() {
        let el = generate::rmat(8, 2_500, 0.57, 0.19, 0.19, 11);
        for strat in STRATS {
            let sg = build(&el, 4, strat);
            let mut seen = vec![false; el.num_vertices];
            for (s, shard) in sg.shards.iter().enumerate() {
                for (local, &v) in shard.owned.iter().enumerate() {
                    assert!(!seen[v as usize], "{strat:?}: vertex owned twice");
                    seen[v as usize] = true;
                    assert_eq!(sg.owner[v as usize] as usize, s, "{strat:?}");
                    assert_eq!(sg.local_id[v as usize] as usize, local, "{strat:?}");
                }
                // both slices carry the same edge set (destination-owned)
                assert_eq!(shard.push_dsts.len(), shard.pull_srcs.len(), "{strat:?}");
                assert_eq!(shard.pull_dst_stream.len(), shard.pull_srcs.len(), "{strat:?}");
            }
            assert!(seen.iter().all(|&b| b), "{strat:?}: uncovered vertex");
            assert_eq!(sg.num_edges(), el.num_edges(), "{strat:?}");
        }
    }

    #[test]
    fn crossing_sums_to_the_partition_cut() {
        let el = generate::rmat(8, 3_000, 0.57, 0.19, 0.19, 5);
        for strat in STRATS {
            let csr = Csr::from_edgelist(&el);
            let csc = csr.transpose();
            let p = partition(&el, 4, strat).unwrap();
            let sg = ShardedGraph::build(&csr, &csc, &p);
            let sum: u64 = sg.shards.iter().map(|s| s.crossing_in).sum();
            assert_eq!(sum, p.cut_edges as u64, "{strat:?}");
            assert_eq!(sg.total_crossing, p.cut_edges as u64, "{strat:?}");
            // halo vertices are foreign, sorted, and deduplicated
            for (s, shard) in sg.shards.iter().enumerate() {
                assert!(shard.halo.windows(2).all(|w| w[0] < w[1]), "{strat:?} shard {s}");
                assert!(
                    shard.halo.iter().all(|&u| sg.owner[u as usize] as usize != s),
                    "{strat:?} shard {s}: owned vertex in halo"
                );
            }
        }
    }

    #[test]
    fn pull_rows_preserve_monolithic_delivery_order() {
        // the bit-exactness invariant: the (src, weight) sequence a shard
        // gathers for any owned vertex equals the monolithic CSC row
        let el = generate::rmat(7, 1_500, 0.57, 0.19, 0.19, 23);
        let csr = Csr::from_edgelist(&el);
        let csc = csr.transpose();
        let p = partition(&el, 3, PartitionStrategy::Hash).unwrap();
        let sg = ShardedGraph::build(&csr, &csc, &p);
        for v in 0..csr.num_vertices() as u32 {
            let shard = &sg.shards[sg.owner[v as usize] as usize];
            let got: Vec<(u32, f32)> = shard.pull_row(sg.local_id[v as usize]).collect();
            let want: Vec<(u32, f32)> =
                csc.row_edges(v).map(|(_, u, w)| (u, w)).collect();
            assert_eq!(got, want, "vertex {v}");
        }
        // and every push sub-row is exactly the CSR row filtered to the
        // shard's owned destinations, in CSR order
        for u in 0..csr.num_vertices() as u32 {
            for (s, shard) in sg.shards.iter().enumerate() {
                let got: Vec<(u32, f32)> = shard.push_row(u).collect();
                let want: Vec<(u32, f32)> = csr
                    .row_edges(u)
                    .filter(|&(_, v, _)| sg.owner[v as usize] as usize == s)
                    .map(|(_, v, w)| (v, w))
                    .collect();
                assert_eq!(got, want, "source {u} shard {s}");
            }
        }
    }

    #[test]
    fn single_shard_is_the_whole_graph_with_no_crossing() {
        let el = generate::erdos_renyi(120, 900, 3);
        let sg = build(&el, 1, PartitionStrategy::Range);
        assert_eq!(sg.num_shards, 1);
        assert_eq!(sg.shards[0].num_owned(), el.num_vertices);
        assert_eq!(sg.shards[0].num_push_edges(), el.num_edges());
        assert_eq!(sg.total_crossing, 0);
        assert!(sg.shards[0].halo.is_empty());
    }

    #[test]
    fn more_shards_than_vertices_leaves_empty_shards_wellformed() {
        let el = generate::chain(3);
        let sg = build(&el, 8, PartitionStrategy::Range);
        assert_eq!(sg.num_shards, 8);
        let nonempty = sg.shards.iter().filter(|s| s.num_owned() > 0).count();
        assert!(nonempty <= 3);
        for shard in &sg.shards {
            assert_eq!(shard.push_offsets.len(), el.num_vertices + 1);
            assert_eq!(shard.pull_offsets.len(), shard.num_owned() + 1);
        }
        assert_eq!(sg.num_edges(), el.num_edges());
    }
}
