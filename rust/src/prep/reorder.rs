//! **Reorder** — vertex relabeling to improve locality (paper §IV-C4): "We
//! can sort nodes in descending order by degree because higher degree nodes
//! will be accessed more often. We can also use DFS to find several closed
//! neighbors for the certain node." Strategies follow the lightweight
//! reorderings of Balaji & Lucia [34] the paper cites.

use anyhow::{bail, Result};

use crate::graph::edgelist::EdgeList;
use crate::graph::VertexId;

/// Available reorder strategies. Each produces a permutation
/// `perm[old_id] = new_id`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReorderStrategy {
    /// Identity (baseline for ablations).
    None,
    /// Descending out-degree: hubs get small ids → they share cache/BRAM
    /// lines ("hub sorting").
    DegreeSort,
    /// DFS pre-order from the highest-degree vertex: neighbors get nearby
    /// ids (the paper's "use DFS to find several closed neighbors").
    DfsLocality,
    /// BFS order from the highest-degree vertex: frontier neighbors adjacent.
    BfsLocality,
    /// Hub clustering: hubs first (sorted by degree), then the rest in
    /// original order — preserves tail locality while packing hubs.
    HubCluster,
}

impl std::str::FromStr for ReorderStrategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "identity" => Self::None,
            "degree" | "degree-sort" => Self::DegreeSort,
            "dfs" | "dfs-locality" => Self::DfsLocality,
            "bfs" | "bfs-locality" => Self::BfsLocality,
            "hub" | "hub-cluster" => Self::HubCluster,
            other => bail!("unknown reorder strategy {other:?}"),
        })
    }
}

/// Compute the permutation for `strategy` and return the relabeled graph
/// together with the permutation (`perm[old] = new`).
pub fn reorder(el: &EdgeList, strategy: ReorderStrategy) -> (EdgeList, Vec<VertexId>) {
    let perm = permutation(el, strategy);
    (el.permute(&perm), perm)
}

/// The permutation only (`perm[old] = new`).
pub fn permutation(el: &EdgeList, strategy: ReorderStrategy) -> Vec<VertexId> {
    let n = el.num_vertices;
    match strategy {
        ReorderStrategy::None => (0..n as u32).collect(),
        ReorderStrategy::DegreeSort => {
            let deg = el.out_degrees();
            let mut order: Vec<VertexId> = (0..n as u32).collect();
            // stable sort: ties keep original id order (deterministic)
            order.sort_by_key(|&v| std::cmp::Reverse(deg[v as usize]));
            invert_order(&order)
        }
        ReorderStrategy::DfsLocality => invert_order(&dfs_order(el)),
        ReorderStrategy::BfsLocality => invert_order(&bfs_order(el)),
        ReorderStrategy::HubCluster => {
            let deg = el.out_degrees();
            let avg = if n == 0 { 0.0 } else { el.num_edges() as f64 / n as f64 };
            let mut hubs: Vec<VertexId> =
                (0..n as u32).filter(|&v| deg[v as usize] as f64 > 2.0 * avg).collect();
            hubs.sort_by_key(|&v| std::cmp::Reverse(deg[v as usize]));
            let hubset: std::collections::HashSet<_> = hubs.iter().copied().collect();
            let mut order = hubs;
            order.extend((0..n as u32).filter(|v| !hubset.contains(v)));
            invert_order(&order)
        }
    }
}

/// `order[new] = old` → `perm[old] = new`.
fn invert_order(order: &[VertexId]) -> Vec<VertexId> {
    let mut perm = vec![0 as VertexId; order.len()];
    for (new, &old) in order.iter().enumerate() {
        perm[old as usize] = new as VertexId;
    }
    perm
}

fn highest_degree_root(el: &EdgeList) -> VertexId {
    let deg = el.out_degrees();
    (0..el.num_vertices as u32).max_by_key(|&v| deg[v as usize]).unwrap_or(0)
}

fn adjacency(el: &EdgeList) -> Vec<Vec<VertexId>> {
    let mut adj = vec![Vec::new(); el.num_vertices];
    for e in &el.edges {
        adj[e.src as usize].push(e.dst);
    }
    // deterministic neighbor order
    for a in &mut adj {
        a.sort_unstable();
    }
    adj
}

/// DFS pre-order from the hub; remaining vertices appended in id order.
fn dfs_order(el: &EdgeList) -> Vec<VertexId> {
    let n = el.num_vertices;
    if n == 0 {
        return Vec::new();
    }
    let adj = adjacency(el);
    let mut seen = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut stack = vec![highest_degree_root(el)];
    while let Some(v) = stack.pop() {
        if seen[v as usize] {
            continue;
        }
        seen[v as usize] = true;
        order.push(v);
        // push reversed so the smallest neighbor is visited first
        for &u in adj[v as usize].iter().rev() {
            if !seen[u as usize] {
                stack.push(u);
            }
        }
    }
    for v in 0..n as u32 {
        if !seen[v as usize] {
            order.push(v);
        }
    }
    order
}

/// BFS order from the hub; remaining vertices appended in id order.
fn bfs_order(el: &EdgeList) -> Vec<VertexId> {
    let n = el.num_vertices;
    if n == 0 {
        return Vec::new();
    }
    let adj = adjacency(el);
    let mut seen = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut q = std::collections::VecDeque::new();
    let root = highest_degree_root(el);
    q.push_back(root);
    seen[root as usize] = true;
    while let Some(v) = q.pop_front() {
        order.push(v);
        for &u in &adj[v as usize] {
            if !seen[u as usize] {
                seen[u as usize] = true;
                q.push_back(u);
            }
        }
    }
    for v in 0..n as u32 {
        if !seen[v as usize] {
            order.push(v);
        }
    }
    order
}

/// Average |new_src - new_dst| gap across edges — the locality proxy the
/// simulator's row-buffer model consumes (smaller = more sequential DRAM).
pub fn avg_edge_gap(el: &EdgeList) -> f64 {
    if el.num_edges() == 0 {
        return 0.0;
    }
    let total: u64 = el.edges.iter().map(|e| (e.src as i64 - e.dst as i64).unsigned_abs()).sum();
    total as f64 / el.num_edges() as f64
}

const ALL: [ReorderStrategy; 5] = [
    ReorderStrategy::None,
    ReorderStrategy::DegreeSort,
    ReorderStrategy::DfsLocality,
    ReorderStrategy::BfsLocality,
    ReorderStrategy::HubCluster,
];

/// All strategies, for ablation sweeps.
pub fn all_strategies() -> &'static [ReorderStrategy] {
    &ALL
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn is_permutation(perm: &[VertexId]) -> bool {
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p as usize >= perm.len() || seen[p as usize] {
                return false;
            }
            seen[p as usize] = true;
        }
        true
    }

    #[test]
    fn every_strategy_yields_a_permutation() {
        let g = generate::rmat(8, 1500, 0.57, 0.19, 0.19, 5);
        for &s in all_strategies() {
            let perm = permutation(&g, s);
            assert!(is_permutation(&perm), "{s:?}");
        }
    }

    #[test]
    fn reorder_preserves_degree_multiset() {
        let g = generate::rmat(8, 1500, 0.57, 0.19, 0.19, 5);
        let mut want = g.out_degrees();
        want.sort_unstable();
        for &s in all_strategies() {
            let (rg, _) = reorder(&g, s);
            let mut got = rg.out_degrees();
            got.sort_unstable();
            assert_eq!(got, want, "{s:?}");
        }
    }

    #[test]
    fn degree_sort_puts_hub_first() {
        let g = generate::star(64);
        let perm = permutation(&g, ReorderStrategy::DegreeSort);
        assert_eq!(perm[0], 0, "hub keeps id 0 after degree sort");
    }

    #[test]
    fn bfs_locality_shrinks_edge_gap_on_shuffled_grid() {
        // shuffle a grid, then check BFS reorder restores locality
        let g = generate::grid2d(24, 24, 3);
        let mut rng = crate::graph::SplitMix64::new(17);
        let mut shuffle: Vec<VertexId> = (0..g.num_vertices as u32).collect();
        for i in (1..shuffle.len()).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            shuffle.swap(i, j);
        }
        let shuffled = g.permute(&shuffle);
        let before = avg_edge_gap(&shuffled);
        let (r, _) = reorder(&shuffled, ReorderStrategy::BfsLocality);
        let after = avg_edge_gap(&r);
        assert!(after < before, "bfs reorder: gap {before:.1} -> {after:.1}");
    }

    #[test]
    fn identity_is_identity() {
        let g = generate::chain(10);
        let (r, perm) = reorder(&g, ReorderStrategy::None);
        assert_eq!(perm, (0..10).collect::<Vec<_>>());
        assert_eq!(r.sorted().edges.len(), g.edges.len());
    }

    #[test]
    fn empty_graph_ok() {
        let g = crate::graph::edgelist::EdgeList::default();
        for &s in all_strategies() {
            let perm = permutation(&g, s);
            assert!(perm.is_empty());
        }
    }

    #[test]
    fn strategy_parses() {
        assert_eq!("dfs".parse::<ReorderStrategy>().unwrap(), ReorderStrategy::DfsLocality);
        assert!("zzz".parse::<ReorderStrategy>().is_err());
    }
}
