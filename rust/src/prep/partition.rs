//! **Partition** — split large graphs across pipeline lanes / PEs (paper
//! §IV-C3): "the basic partition is to divide graph into several parts
//! without optimization. We can also separate graph with graph algorithms,
//! such as graph coloring and community detection." The strategies here are
//! the paper's basic split plus the skew-aware splits of PowerLyra/PathGraph
//! it cites [32, 33].

use anyhow::{bail, Result};

use crate::graph::csr::Csr;
use crate::graph::edgelist::EdgeList;
use crate::graph::VertexId;

/// Available partition strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionStrategy {
    /// Contiguous vertex ranges of equal size (the paper's "basic" split).
    Range,
    /// Vertex id modulo k — destroys locality, balances counts.
    Hash,
    /// Greedy bin-packing by out-degree so each part owns a similar edge
    /// count (PowerLyra-style skew handling).
    DegreeBalanced,
    /// BFS-grown parts: community-detection-flavored — each part is a
    /// connected-ish region, improving intra-part locality (PathGraph-style).
    BfsGrow,
}

impl std::str::FromStr for PartitionStrategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "range" => Self::Range,
            "hash" => Self::Hash,
            "degree" | "degree-balanced" => Self::DegreeBalanced,
            "bfs" | "bfs-grow" | "community" => Self::BfsGrow,
            other => bail!("unknown partition strategy {other:?}"),
        })
    }
}

/// The result: `assignment[v] = part id`, plus per-part summaries.
#[derive(Debug, Clone)]
pub struct Partitioning {
    pub strategy: PartitionStrategy,
    pub num_parts: usize,
    pub assignment: Vec<u32>,
    /// Vertices per part.
    pub part_sizes: Vec<usize>,
    /// Edges whose source lives in the part.
    pub part_edges: Vec<usize>,
    /// Edges crossing parts (communication volume between PEs).
    pub cut_edges: usize,
}

impl Partitioning {
    /// Edge balance: max part edges / mean part edges (1.0 = perfect).
    pub fn edge_imbalance(&self) -> f64 {
        let max = self.part_edges.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.part_edges.iter().sum::<usize>() as f64 / self.num_parts.max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Fraction of edges crossing part boundaries.
    pub fn cut_fraction(&self, total_edges: usize) -> f64 {
        if total_edges == 0 {
            0.0
        } else {
            self.cut_edges as f64 / total_edges as f64
        }
    }
}

/// Partition `el` into `k` parts with the chosen strategy.
pub fn partition(el: &EdgeList, k: usize, strategy: PartitionStrategy) -> Result<Partitioning> {
    if k == 0 {
        bail!("cannot partition into 0 parts");
    }
    let n = el.num_vertices;
    let assignment = match strategy {
        PartitionStrategy::Range => {
            let per = n.div_ceil(k.min(n.max(1)));
            (0..n).map(|v| ((v / per.max(1)).min(k - 1)) as u32).collect()
        }
        PartitionStrategy::Hash => (0..n).map(|v| (v % k) as u32).collect(),
        PartitionStrategy::DegreeBalanced => degree_balanced(el, k),
        PartitionStrategy::BfsGrow => bfs_grow(el, k),
    };
    Ok(summarize(el, k, strategy, assignment))
}

/// Degree-balanced contiguous destination ranges — the auto-sharding
/// split [`crate::prep::prepared::PreparedGraph`] builds when a binding
/// has no user-requested partitioning. Vertices `[0, n)` are chunked
/// into `k` contiguous ranges by walking the in-edge prefix sum and
/// cutting at ~equal edge mass — **not** at equal vertex counts: a shard
/// worker's per-superstep cost is proportional to the in-edges it
/// gathers, so equal-count ranges leave skewed graphs serialized behind
/// their heaviest range. Each vertex weighs `in_degree + 1` so
/// zero-degree tails still spread instead of piling onto the last range.
/// Destination ownership makes the resulting sharded execution
/// bit-identical to the monolithic engine for free (see
/// [`crate::engine::sharded`]).
///
/// `csc` must be `csr.transpose()`. Labeled [`PartitionStrategy::Range`]
/// (it is one — the ranges are just edge-balanced).
pub fn destination_ranges(csr: &Csr, csc: &Csr, k: usize) -> Partitioning {
    debug_assert_eq!(csr.num_vertices(), csc.num_vertices(), "csc must transpose csr");
    debug_assert_eq!(csr.num_edges(), csc.num_edges(), "csc must transpose csr");
    let n = csc.num_vertices();
    let k = k.max(1);
    let total = csc.num_edges() as u64 + n as u64;
    let mut assignment = vec![0u32; n];
    let mut cum = 0u64;
    let mut part = 0usize;
    for v in 0..n {
        // Advance to the next range once the running mass crosses this
        // part's quota of `total / k` (kept in integer cross-multiplied
        // form so the boundaries are exact and deterministic).
        while part + 1 < k && cum * k as u64 >= (part as u64 + 1) * total {
            part += 1;
        }
        assignment[v] = part as u32;
        cum += csc.degree(v as VertexId) as u64 + 1;
    }
    let mut part_sizes = vec![0usize; k];
    for &a in &assignment {
        part_sizes[a as usize] += 1;
    }
    // Same summary semantics as `summarize`: part_edges counts src-side
    // edges, cut_edges the src/dst-straddling ones.
    let mut part_edges = vec![0usize; k];
    let mut cut_edges = 0usize;
    for u in 0..n as VertexId {
        let pu = assignment[u as usize];
        part_edges[pu as usize] += csr.degree(u) as usize;
        for &v in csr.neighbors(u) {
            if assignment[v as usize] != pu {
                cut_edges += 1;
            }
        }
    }
    Partitioning {
        strategy: PartitionStrategy::Range,
        num_parts: k,
        assignment,
        part_sizes,
        part_edges,
        cut_edges,
    }
}

fn summarize(
    el: &EdgeList,
    k: usize,
    strategy: PartitionStrategy,
    assignment: Vec<u32>,
) -> Partitioning {
    let mut part_sizes = vec![0usize; k];
    for &p in &assignment {
        part_sizes[p as usize] += 1;
    }
    let mut part_edges = vec![0usize; k];
    let mut cut_edges = 0usize;
    for e in &el.edges {
        let ps = assignment[e.src as usize];
        part_edges[ps as usize] += 1;
        if ps != assignment[e.dst as usize] {
            cut_edges += 1;
        }
    }
    Partitioning { strategy, num_parts: k, assignment, part_sizes, part_edges, cut_edges }
}

/// Greedy: sort vertices by out-degree descending, place each in the part
/// with the fewest edges so far.
fn degree_balanced(el: &EdgeList, k: usize) -> Vec<u32> {
    let deg = el.out_degrees();
    let mut order: Vec<VertexId> = (0..el.num_vertices as u32).collect();
    order.sort_unstable_by_key(|&v| std::cmp::Reverse(deg[v as usize]));
    let mut load = vec![0u64; k];
    let mut assignment = vec![0u32; el.num_vertices];
    for v in order {
        let best = (0..k).min_by_key(|&p| load[p]).unwrap();
        assignment[v as usize] = best as u32;
        load[best] += deg[v as usize] as u64 + 1; // +1 so zero-degree spreads
    }
    assignment
}

/// Grow parts by BFS from evenly-spaced seeds over the symmetrized
/// adjacency; unreached vertices round-robin.
fn bfs_grow(el: &EdgeList, k: usize) -> Vec<u32> {
    let n = el.num_vertices;
    let mut adj = vec![Vec::new(); n];
    for e in &el.edges {
        adj[e.src as usize].push(e.dst);
        adj[e.dst as usize].push(e.src);
    }
    let target = n.div_ceil(k);
    let mut assignment = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    let mut next_seed = 0usize;
    for p in 0..k {
        // find an unassigned seed
        while next_seed < n && assignment[next_seed] != u32::MAX {
            next_seed += 1;
        }
        if next_seed >= n {
            break;
        }
        queue.clear();
        queue.push_back(next_seed as u32);
        assignment[next_seed] = p as u32;
        let mut grown = 1usize;
        while let Some(u) = queue.pop_front() {
            if grown >= target {
                break;
            }
            for &v in &adj[u as usize] {
                if assignment[v as usize] == u32::MAX {
                    assignment[v as usize] = p as u32;
                    grown += 1;
                    queue.push_back(v);
                    if grown >= target {
                        break;
                    }
                }
            }
        }
    }
    // Leftovers (disconnected tails): least-loaded part. A blind
    // round-robin starting at part 0 piles isolated vertices onto parts
    // that already grew to their target, so graphs with many disconnected
    // vertices came out badly imbalanced.
    let mut load = vec![0usize; k];
    for &a in assignment.iter() {
        if a != u32::MAX {
            load[a as usize] += 1;
        }
    }
    for a in assignment.iter_mut() {
        if *a == u32::MAX {
            let best = (0..k).min_by_key(|&p| load[p]).unwrap();
            *a = best as u32;
            load[best] += 1;
        }
    }
    assignment
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    const STRATS: [PartitionStrategy; 4] = [
        PartitionStrategy::Range,
        PartitionStrategy::Hash,
        PartitionStrategy::DegreeBalanced,
        PartitionStrategy::BfsGrow,
    ];

    #[test]
    fn every_strategy_covers_every_vertex() {
        let g = generate::rmat(8, 2000, 0.57, 0.19, 0.19, 4);
        for s in STRATS {
            let p = partition(&g, 4, s).unwrap();
            assert_eq!(p.assignment.len(), g.num_vertices);
            assert!(p.assignment.iter().all(|&a| a < 4), "{s:?}");
            assert_eq!(p.part_sizes.iter().sum::<usize>(), g.num_vertices);
            assert_eq!(p.part_edges.iter().sum::<usize>(), g.num_edges());
        }
    }

    #[test]
    fn degree_balanced_beats_range_on_skew() {
        let g = generate::rmat(10, 30_000, 0.57, 0.19, 0.19, 7);
        let r = partition(&g, 8, PartitionStrategy::Range).unwrap();
        let d = partition(&g, 8, PartitionStrategy::DegreeBalanced).unwrap();
        assert!(
            d.edge_imbalance() < r.edge_imbalance(),
            "degree {:.3} vs range {:.3}",
            d.edge_imbalance(),
            r.edge_imbalance()
        );
    }

    #[test]
    fn bfs_grow_cuts_fewer_edges_than_hash_on_grid() {
        let g = generate::grid2d(32, 32, 1);
        let h = partition(&g, 4, PartitionStrategy::Hash).unwrap();
        let b = partition(&g, 4, PartitionStrategy::BfsGrow).unwrap();
        assert!(
            b.cut_edges < h.cut_edges,
            "bfs-grow {} vs hash {}",
            b.cut_edges,
            h.cut_edges
        );
    }

    #[test]
    fn single_part_has_no_cut() {
        let g = generate::erdos_renyi(100, 500, 2);
        for s in STRATS {
            let p = partition(&g, 1, s).unwrap();
            assert_eq!(p.cut_edges, 0);
            assert_eq!(p.cut_fraction(g.num_edges()), 0.0);
        }
    }

    #[test]
    fn zero_parts_rejected() {
        let g = generate::chain(4);
        assert!(partition(&g, 0, PartitionStrategy::Range).is_err());
    }

    #[test]
    fn more_parts_than_vertices() {
        let g = generate::chain(3);
        for s in STRATS {
            let p = partition(&g, 8, s).unwrap();
            assert_eq!(p.assignment.len(), 3);
            assert!(p.assignment.iter().all(|&a| a < 8));
        }
    }

    #[test]
    fn bfs_grow_spreads_isolated_leftovers_to_least_loaded_parts() {
        // 20-vertex chain + 80 isolated vertices: the BFS growth fills
        // parts from the chain, then the isolated tail must level the
        // loads instead of piling onto the parts the chain already filled.
        let mut g = generate::chain(20);
        g.num_vertices = 100;
        let p = partition(&g, 4, PartitionStrategy::BfsGrow).unwrap();
        let max = p.part_sizes.iter().copied().max().unwrap();
        let min = p.part_sizes.iter().copied().min().unwrap();
        assert!(
            max - min <= 1,
            "leftover assignment must level part sizes, got {:?}",
            p.part_sizes
        );
    }

    #[test]
    fn destination_ranges_are_contiguous_and_edge_balanced() {
        let g = generate::rmat(10, 30_000, 0.57, 0.19, 0.19, 7);
        let csr = crate::graph::csr::Csr::from_edgelist(&g);
        let csc = csr.transpose();
        let p = destination_ranges(&csr, &csc, 4);
        assert_eq!(p.num_parts, 4);
        assert_eq!(p.assignment.len(), g.num_vertices);
        // contiguous ranges: part ids never decrease along the vertex axis
        assert!(p.assignment.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(p.part_sizes.iter().sum::<usize>(), g.num_vertices);
        assert_eq!(p.part_edges.iter().sum::<usize>(), g.num_edges());
        // balance target is in-edge mass per range: every range's mass
        // stays within one quota plus the heaviest single vertex
        let in_deg = csc.out_degrees();
        let mut mass = vec![0u64; 4];
        for (v, &a) in p.assignment.iter().enumerate() {
            mass[a as usize] += in_deg[v] as u64 + 1;
        }
        let total: u64 = mass.iter().sum();
        let heaviest = in_deg.iter().map(|&d| d as u64 + 1).max().unwrap();
        for (i, &m) in mass.iter().enumerate() {
            assert!(
                m <= total / 4 + heaviest,
                "range {i} mass {m} exceeds quota {} + heaviest {heaviest}",
                total / 4
            );
        }
        // the plain Range split ignores edge mass; on a skewed rmat the
        // prefix-sum cut must balance it strictly better
        let r = partition(&g, 4, PartitionStrategy::Range).unwrap();
        let mut range_mass = vec![0u64; 4];
        for (v, &a) in r.assignment.iter().enumerate() {
            range_mass[a as usize] += in_deg[v] as u64 + 1;
        }
        assert!(
            mass.iter().max().unwrap() < range_mass.iter().max().unwrap(),
            "edge-balanced {mass:?} vs equal-count {range_mass:?}"
        );
    }

    #[test]
    fn destination_ranges_edge_cases() {
        // more parts than vertices: all parts present, trailing ones empty
        let g = generate::chain(3);
        let csr = crate::graph::csr::Csr::from_edgelist(&g);
        let csc = csr.transpose();
        let p = destination_ranges(&csr, &csc, 8);
        assert_eq!(p.num_parts, 8);
        assert_eq!(p.assignment.len(), 3);
        assert!(p.assignment.iter().all(|&a| a < 8));
        assert_eq!(p.part_sizes.iter().sum::<usize>(), 3);
        // empty graph
        let g = crate::graph::edgelist::EdgeList { num_vertices: 0, edges: Vec::new() };
        let csr = crate::graph::csr::Csr::from_edgelist(&g);
        let csc = csr.transpose();
        let p = destination_ranges(&csr, &csc, 4);
        assert_eq!(p.num_parts, 4);
        assert!(p.assignment.is_empty());
        assert_eq!(p.cut_edges, 0);
        // k == 0 clamps to one part
        let g = generate::chain(5);
        let csr = crate::graph::csr::Csr::from_edgelist(&g);
        let csc = csr.transpose();
        let p = destination_ranges(&csr, &csc, 0);
        assert_eq!(p.num_parts, 1);
        assert!(p.assignment.iter().all(|&a| a == 0));
    }

    #[test]
    fn strategy_parses() {
        assert_eq!("hash".parse::<PartitionStrategy>().unwrap(), PartitionStrategy::Hash);
        assert!("x".parse::<PartitionStrategy>().is_err());
    }
}
