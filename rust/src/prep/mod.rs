//! Preprocessing — the DSL's third interface family (paper §IV-C):
//! **FIFO** (file I/O, provided by [`crate::graph::io`]), **Layout**
//! (format conversion), **Partition**, and **Reorder**.

pub mod calibrate;
pub mod layout;
pub mod partition;
pub mod prepared;
pub mod reorder;
pub mod shard;

pub use calibrate::{calibrate, CalibrateOptions, Calibration, CalibrationReport};
pub use layout::{convert, Layout};
pub use partition::{destination_ranges, partition, PartitionStrategy, Partitioning};
pub use prepared::{PrepOptions, PreparedGraph};
pub use reorder::{reorder, ReorderStrategy};
pub use shard::{Shard, ShardedGraph};
