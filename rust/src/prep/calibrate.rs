//! Measurement calibration (`jgraph calibrate`): replace the hand-set
//! push↔pull crossover constants and the heuristic auto-shard count with
//! values *measured on the actual graph shape*. This is the first slice
//! of the ROADMAP's design-space-exploration item, in the spirit of
//! GNNBuilder's performance-model-driven DSE (PAPERS.md): sweep the
//! candidate space with `engine_mteps`-style wall timings, fit the
//! argmin, and store the result on the [`PreparedGraph`] so every
//! subsequent query's adaptive policy reads fitted constants instead of
//! defaults.
//!
//! Three independent sweeps:
//! * `alpha_early_exit` — adaptive BFS (early-exit-capable pull), the
//!   program family most sensitive to switching too early/late;
//! * `alpha_full_scan` — adaptive WCC (full-scan pull: every in-edge of
//!   every swept vertex), where pulling pays off much later;
//! * `auto_shards` — auto-sharded PageRank across candidate shard
//!   counts, including 1 (monolithic), so a machine or graph where
//!   sharding loses fits back to the single-thread sweep.
//!
//! Every candidate executes the same program to the same fixpoint —
//! crossover and shard count change *wall time only*, never values — so
//! the sweep is safe to run on a live binding's graph.

use std::time::Instant;

use anyhow::Result;

use crate::dsl::algorithms;
use crate::dsl::params::ParamSet;
use crate::engine::gas::{self, Crossover, DirectionPolicy};
use crate::engine::run_sharded;
use crate::graph::VertexId;

use super::partition::destination_ranges;
use super::prepared::PreparedGraph;
use super::shard::ShardedGraph;

/// Fitted per-graph tuning constants, stored on
/// [`PreparedGraph::set_calibration`] and read by every query on the
/// binding. The default is exactly the engine's hand-set behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Calibration {
    /// Fitted [`Crossover::alpha_early_exit`].
    pub pull_alpha_early_exit: u64,
    /// Fitted [`Crossover::alpha_full_scan`].
    pub pull_alpha_full_scan: u64,
    /// Fitted auto-shard count; `None` defers to the worker-budget
    /// heuristic, `Some(1)` pins the monolithic sweep.
    pub auto_shards: Option<usize>,
}

impl Default for Calibration {
    fn default() -> Self {
        let c = Crossover::default();
        Calibration {
            pull_alpha_early_exit: c.alpha_early_exit,
            pull_alpha_full_scan: c.alpha_full_scan,
            auto_shards: None,
        }
    }
}

impl Calibration {
    /// The crossover constants the engine view carries.
    pub fn crossover(&self) -> Crossover {
        Crossover {
            alpha_early_exit: self.pull_alpha_early_exit,
            alpha_full_scan: self.pull_alpha_full_scan,
        }
    }
}

/// Candidate alphas for the early-exit (BFS-shaped) crossover sweep.
pub const ALPHA_EARLY_EXIT_CANDIDATES: [u64; 5] = [2, 4, 8, 16, 32];
/// Candidate alphas for the full-scan crossover sweep.
pub const ALPHA_FULL_SCAN_CANDIDATES: [u64; 4] = [1, 2, 4, 8];

/// Knobs for [`calibrate`].
#[derive(Debug, Clone)]
pub struct CalibrateOptions {
    /// Timing repetitions per candidate; best-of is fitted (the minimum
    /// is the right statistic for a deterministic workload under noise).
    pub iters: usize,
    /// Root for the rooted sweeps; `None` picks the highest-out-degree
    /// vertex (guaranteed inside the dense core).
    pub root: Option<VertexId>,
    /// PageRank tolerance for the shard-count sweep — loose by default so
    /// a sweep costs a handful of supersteps per candidate.
    pub tolerance: f64,
}

impl Default for CalibrateOptions {
    fn default() -> Self {
        CalibrateOptions { iters: 3, root: None, tolerance: 1e-3 }
    }
}

/// The full sweep record: every candidate with its measured seconds,
/// plus the fitted argmin constants.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub graph: String,
    pub vertices: usize,
    pub edges: usize,
    /// `(alpha_early_exit, seconds)` per candidate, adaptive BFS.
    pub early_exit_sweep: Vec<(u64, f64)>,
    /// `(alpha_full_scan, seconds)` per candidate, adaptive WCC.
    pub full_scan_sweep: Vec<(u64, f64)>,
    /// `(shard_count, seconds)` per candidate, PageRank to fixpoint.
    pub shard_sweep: Vec<(usize, f64)>,
    pub fitted: Calibration,
}

impl CalibrationReport {
    /// Machine-readable form for `jgraph calibrate --emit json` (the CI
    /// smoke parses this schema).
    pub fn to_json(&self) -> String {
        let sweep_u64 = |s: &[(u64, f64)]| {
            s.iter()
                .map(|(a, t)| format!("{{ \"candidate\": {a}, \"seconds\": {t:.6} }}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        let shards = self
            .shard_sweep
            .iter()
            .map(|(k, t)| format!("{{ \"candidate\": {k}, \"seconds\": {t:.6} }}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{{\n  \"graph\": \"{}\",\n  \"vertices\": {},\n  \"edges\": {},\n  \
             \"early_exit_sweep\": [{}],\n  \"full_scan_sweep\": [{}],\n  \
             \"shard_sweep\": [{}],\n  \"fitted\": {{\n    \
             \"pull_alpha_early_exit\": {},\n    \"pull_alpha_full_scan\": {},\n    \
             \"auto_shards\": {}\n  }}\n}}\n",
            self.graph,
            self.vertices,
            self.edges,
            sweep_u64(&self.early_exit_sweep),
            sweep_u64(&self.full_scan_sweep),
            shards,
            self.fitted.pull_alpha_early_exit,
            self.fitted.pull_alpha_full_scan,
            match self.fitted.auto_shards {
                Some(k) => k.to_string(),
                None => "null".into(),
            },
        )
    }
}

fn time_best<T>(iters: usize, mut f: impl FnMut() -> Result<T>) -> Result<f64> {
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok(best)
}

fn argmin<K: Copy>(sweep: &[(K, f64)]) -> K {
    sweep
        .iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|&(k, _)| k)
        .expect("sweep is never empty")
}

/// Sweep the crossover alphas and the auto-shard count on `prepared`'s
/// actual graph and fit the argmin of each. Pure measurement: the
/// prepared graph is not mutated — callers decide whether to
/// [`PreparedGraph::set_calibration`] the result.
pub fn calibrate(prepared: &PreparedGraph, opts: &CalibrateOptions) -> Result<CalibrationReport> {
    let iters = opts.iters.max(1);
    let n = prepared.num_vertices();
    let root = opts.root.unwrap_or_else(|| {
        (0..n as VertexId).max_by_key(|&v| prepared.csr.degree(v)).unwrap_or(0)
    });
    // Force the lazy CSC/out-degree caches before any timer starts.
    let base = prepared.engine_view();

    let bfs = algorithms::bfs();
    let mut early_exit_sweep = Vec::new();
    for &alpha in &ALPHA_EARLY_EXIT_CANDIDATES {
        let view = base.with_crossover(Crossover {
            alpha_early_exit: alpha,
            ..Crossover::default()
        });
        let secs = time_best(iters, || {
            gas::run_with_policy(&bfs, &view, root, DirectionPolicy::Adaptive, |_| Ok(()))
        })?;
        early_exit_sweep.push((alpha, secs));
    }

    let wcc = algorithms::wcc();
    let mut full_scan_sweep = Vec::new();
    for &alpha in &ALPHA_FULL_SCAN_CANDIDATES {
        let view = base.with_crossover(Crossover {
            alpha_full_scan: alpha,
            ..Crossover::default()
        });
        let secs = time_best(iters, || {
            gas::run_with_policy(&wcc, &view, root, DirectionPolicy::Adaptive, |_| Ok(()))
        })?;
        full_scan_sweep.push((alpha, secs));
    }

    let pr = algorithms::pagerank()
        .instantiate(&ParamSet::new().bind("tolerance", opts.tolerance))?;
    let pr_view = base.with_pull_stream(prepared.pull_stream());
    let budget = crate::sched::available_workers();
    let mut ks: Vec<usize> = [1usize, 2, 4, 8, 16]
        .into_iter()
        .filter(|&k| k <= PreparedGraph::AUTO_SHARD_MAX && (k == 1 || k <= 2 * budget))
        .filter(|&k| k <= n.max(1))
        .collect();
    if ks.is_empty() {
        ks.push(1);
    }
    let mut shard_sweep = Vec::new();
    for &k in &ks {
        let secs = if k == 1 {
            time_best(iters, || {
                gas::run_with_policy(&pr, &pr_view, root, DirectionPolicy::Adaptive, |_| Ok(()))
            })?
        } else {
            let p = destination_ranges(&prepared.csr, prepared.csc(), k);
            let sg = ShardedGraph::build(&prepared.csr, prepared.csc(), &p);
            time_best(iters, || {
                run_sharded(&pr, &base, &sg, root, DirectionPolicy::Adaptive, k, |_| Ok(()))
            })?
        };
        shard_sweep.push((k, secs));
    }

    let fitted = Calibration {
        pull_alpha_early_exit: argmin(&early_exit_sweep),
        pull_alpha_full_scan: argmin(&full_scan_sweep),
        auto_shards: Some(argmin(&shard_sweep)),
    };
    Ok(CalibrationReport {
        graph: prepared.name.clone(),
        vertices: n,
        edges: prepared.num_edges(),
        early_exit_sweep,
        full_scan_sweep,
        shard_sweep,
        fitted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::prep::prepared::PrepOptions;

    #[test]
    fn calibrate_fits_candidates_and_applies() {
        let g = generate::rmat(9, 6_000, 0.57, 0.19, 0.19, 11);
        let p = PreparedGraph::prepare(&g, &PrepOptions::named("rmat9")).unwrap();
        let opts = CalibrateOptions { iters: 1, root: None, tolerance: 1e-2 };
        let report = calibrate(&p, &opts).unwrap();
        assert_eq!(report.early_exit_sweep.len(), ALPHA_EARLY_EXIT_CANDIDATES.len());
        assert_eq!(report.full_scan_sweep.len(), ALPHA_FULL_SCAN_CANDIDATES.len());
        assert!(!report.shard_sweep.is_empty());
        assert!(report.shard_sweep.iter().any(|&(k, _)| k == 1), "monolithic is a candidate");
        assert!(ALPHA_EARLY_EXIT_CANDIDATES.contains(&report.fitted.pull_alpha_early_exit));
        assert!(ALPHA_FULL_SCAN_CANDIDATES.contains(&report.fitted.pull_alpha_full_scan));
        let fitted_k = report.fitted.auto_shards.unwrap();
        assert!(report.shard_sweep.iter().any(|&(k, _)| k == fitted_k));
        // applying the fit changes what every subsequent view reads
        assert!(p.set_calibration(report.fitted));
        assert_eq!(p.engine_view().crossover, report.fitted.crossover());
        // the JSON schema the CI smoke step greps
        let json = report.to_json();
        let keys = [
            "early_exit_sweep",
            "full_scan_sweep",
            "shard_sweep",
            "fitted",
            "pull_alpha_early_exit",
        ];
        for key in keys {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    #[test]
    fn calibrate_handles_degenerate_graphs() {
        let g = crate::graph::edgelist::EdgeList { num_vertices: 1, edges: Vec::new() };
        let p = PreparedGraph::prepare(&g, &PrepOptions::named("lonely")).unwrap();
        let report =
            calibrate(&p, &CalibrateOptions { iters: 1, root: None, tolerance: 1e-2 }).unwrap();
        assert_eq!(report.shard_sweep.len(), 1, "single vertex caps the shard candidates");
        assert_eq!(report.fitted.auto_shards, Some(1));
    }
}
