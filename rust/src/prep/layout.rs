//! **Layout** — graph data-structure conversion (paper §IV-C2): "There are
//! various graph data layouts, such as CSR, CSC, Adjacency matrix, linked
//! list... we provide several functions for data structure transmission."

use anyhow::{bail, Result};

use crate::graph::csr::Csr;
use crate::graph::edgelist::EdgeList;

/// The layouts the DSL's `Layout(graph, fmt)` call accepts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// Flat (src, dst, w) triples — the FIFO stage's native output.
    EdgeList,
    /// Compressed sparse row: out-edges grouped by source.
    Csr,
    /// Compressed sparse column: in-edges grouped by destination (the
    /// paper's BFS pseudocode uses CSC: pull from in-neighbors).
    Csc,
    /// Dense adjacency matrix (tiny graphs only; O(V^2)).
    AdjacencyMatrix,
}

impl std::str::FromStr for Layout {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "edgelist" | "el" => Layout::EdgeList,
            "csr" => Layout::Csr,
            "csc" => Layout::Csc,
            "adj" | "adjacency" | "matrix" => Layout::AdjacencyMatrix,
            other => bail!("unknown layout {other:?}"),
        })
    }
}

/// A graph in one of the supported layouts.
#[derive(Debug, Clone)]
pub enum LaidOut {
    EdgeList(EdgeList),
    Csr(Csr),
    Csc(Csr),
    /// Row-major n×n weights; 0.0 = absent. Parallel edges collapse to the
    /// last weight.
    AdjacencyMatrix { n: usize, dense: Vec<f32> },
}

impl LaidOut {
    pub fn layout(&self) -> Layout {
        match self {
            LaidOut::EdgeList(_) => Layout::EdgeList,
            LaidOut::Csr(_) => Layout::Csr,
            LaidOut::Csc(_) => Layout::Csc,
            LaidOut::AdjacencyMatrix { .. } => Layout::AdjacencyMatrix,
        }
    }

    /// Normalize back to an edge list (the hub format for conversions).
    pub fn to_edgelist(&self) -> EdgeList {
        match self {
            LaidOut::EdgeList(el) => el.clone(),
            LaidOut::Csr(c) => c.to_edgelist(),
            LaidOut::Csc(c) => {
                // rows are destinations: flip back
                let flipped = c.to_edgelist();
                let mut el = EdgeList::with_vertices(flipped.num_vertices);
                for e in flipped.edges {
                    el.edges.push(crate::graph::edgelist::Edge {
                        src: e.dst,
                        dst: e.src,
                        weight: e.weight,
                    });
                }
                el
            }
            LaidOut::AdjacencyMatrix { n, dense } => {
                let mut el = EdgeList::with_vertices(*n);
                for i in 0..*n {
                    for j in 0..*n {
                        let w = dense[i * n + j];
                        if w != 0.0 {
                            el.push(i as u32, j as u32, w);
                        }
                    }
                }
                el.num_vertices = *n;
                el
            }
        }
    }
}

/// Maximum vertex count for the dense adjacency layout.
pub const ADJ_MATRIX_LIMIT: usize = 4_096;

/// Convert an edge list into the requested layout.
pub fn convert(el: &EdgeList, to: Layout) -> Result<LaidOut> {
    Ok(match to {
        Layout::EdgeList => LaidOut::EdgeList(el.clone()),
        Layout::Csr => LaidOut::Csr(Csr::from_edgelist(el)),
        Layout::Csc => LaidOut::Csc(Csr::csc_from_edgelist(el)),
        Layout::AdjacencyMatrix => {
            let n = el.num_vertices;
            if n > ADJ_MATRIX_LIMIT {
                bail!("adjacency matrix layout limited to {ADJ_MATRIX_LIMIT} vertices, got {n}");
            }
            let mut dense = vec![0f32; n * n];
            for e in &el.edges {
                dense[e.src as usize * n + e.dst as usize] = e.weight;
            }
            LaidOut::AdjacencyMatrix { n, dense }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn canon(el: &EdgeList) -> Vec<(u32, u32)> {
        el.sorted().edges.iter().map(|e| (e.src, e.dst)).collect()
    }

    #[test]
    fn all_layouts_roundtrip() {
        let mut g = generate::erdos_renyi(40, 150, 11);
        g.dedup(); // adjacency matrix collapses parallel edges
        let want = canon(&g);
        for layout in [Layout::EdgeList, Layout::Csr, Layout::Csc, Layout::AdjacencyMatrix] {
            let lo = convert(&g, layout).unwrap();
            assert_eq!(lo.layout(), layout);
            assert_eq!(canon(&lo.to_edgelist()), want, "layout {layout:?}");
        }
    }

    #[test]
    fn csc_groups_by_destination() {
        let g = EdgeList::from_pairs([(0, 2), (1, 2), (0, 1)]);
        let LaidOut::Csc(c) = convert(&g, Layout::Csc).unwrap() else { panic!() };
        assert_eq!(c.neighbors(2), &[0, 1]); // in-neighbors of 2
    }

    #[test]
    fn adjacency_limit_enforced() {
        let g = generate::chain(ADJ_MATRIX_LIMIT + 1);
        assert!(convert(&g, Layout::AdjacencyMatrix).is_err());
    }

    #[test]
    fn layout_parses_from_str() {
        assert_eq!("csr".parse::<Layout>().unwrap(), Layout::Csr);
        assert_eq!("CSC".parse::<Layout>().unwrap(), Layout::Csc);
        assert!("blah".parse::<Layout>().is_err());
    }
}
