//! A small blocking client for the serve wire protocol, used by the
//! integration tests, the `serve_demo` example, and the
//! `serve_latency` bench. One request line out, one response line back;
//! pipelined use (several [`ServeClient::send_query`] calls before the
//! first recv) is fine — the daemon answers in request order per
//! connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use anyhow::{bail, Context, Result};

use super::wire::{Json, QueryRequest};

/// A connected client. Reads and writes share one socket; `recv` blocks
/// until the daemon's next response line.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ServeClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr).context("connecting to the serve daemon")?;
        let writer = stream.try_clone().context("cloning the client socket")?;
        Ok(ServeClient { reader: BufReader::new(stream), writer })
    }

    /// Send one raw request line (the newline is added here).
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    /// Receive the next response line, parsed.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("the daemon closed the connection");
        }
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response line: {e}"))
    }

    /// Send one raw line and wait for its response.
    pub fn request(&mut self, line: &str) -> Result<Json> {
        self.send_line(line)?;
        self.recv()
    }

    /// Send one query and wait for its response (success or typed
    /// reject — inspect `ok`).
    pub fn query(&mut self, q: &QueryRequest) -> Result<Json> {
        self.request(&q.encode())
    }

    /// Send a query without waiting — pair with [`Self::recv`] later.
    /// Pipelining is how a load generator keeps the batcher's window
    /// busy from one connection.
    pub fn send_query(&mut self, q: &QueryRequest) -> Result<()> {
        self.send_line(&q.encode())
    }

    /// Fetch the daemon's rolling stats.
    pub fn stats(&mut self) -> Result<Json> {
        self.request("{\"op\":\"stats\"}")
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<Json> {
        self.request("{\"op\":\"ping\"}")
    }

    /// Ask the daemon to drain and exit.
    pub fn shutdown(&mut self) -> Result<Json> {
        self.request("{\"op\":\"shutdown\"}")
    }
}
