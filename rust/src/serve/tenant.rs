//! Per-tenant admission control for the serve daemon.
//!
//! Each tenant (the `tenant` field on a wire query, defaulting to
//! `"default"`) gets its own [`ConcurrencyCap`]: a query is admitted only
//! if its tenant is under cap, otherwise it earns a typed
//! `tenant_over_cap` reject *immediately* — it never queues, so one
//! tenant flooding the daemon cannot grow another tenant's tail.
//!
//! Composition with the scheduler (see [`crate::sched::caps`]): the cap
//! rations *admission* (how many of a tenant's queries may be in flight),
//! the global [`WorkerBudget`](crate::sched::WorkerBudget) rations
//! *threads* once admitted. An admitted query holds its [`TenantPermit`]
//! from admission until its sweep completes and its outcome is handed to
//! the connection writer — the permit spans the batcher queue and the
//! sweep, so "in flight" means admitted-but-unanswered.
//!
//! Tenants also carry a **retry budget** (ISSUE 10): transient query
//! failures retry with backoff, but each retry spends one unit of the
//! tenant's process-lifetime budget — a tenant whose queries fault
//! persistently (or who aims at a fault-heavy chaos plan) runs dry and
//! gets its failures surfaced instead of amplifying load, without
//! dimming another tenant's retries.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sched::ConcurrencyCap;

use super::lock_recover;
use super::wire::Json;

/// One tenant's admission state: the in-flight cap plus the retries it
/// has spent so far.
struct TenantEntry {
    cap: Arc<ConcurrencyCap>,
    retries_used: AtomicU64,
}

/// Tenant → cap table. Tenants appear on first use with the default
/// cap unless an explicit cap was configured up front.
pub struct TenantTable {
    default_cap: usize,
    retry_budget: u64,
    tenants: Mutex<HashMap<String, Arc<TenantEntry>>>,
}

impl TenantTable {
    /// A table admitting up to `default_cap` in-flight queries per
    /// tenant (clamped ≥ 1), with `explicit` per-tenant overrides.
    /// Every tenant starts with an effectively unlimited retry budget;
    /// see [`Self::with_retry_budget`].
    pub fn new(default_cap: usize, explicit: &[(String, usize)]) -> Self {
        let mut tenants = HashMap::new();
        for (name, cap) in explicit {
            let entry = TenantEntry {
                cap: Arc::new(ConcurrencyCap::new(*cap)),
                retries_used: AtomicU64::new(0),
            };
            tenants.insert(name.clone(), Arc::new(entry));
        }
        TenantTable {
            default_cap: default_cap.max(1),
            retry_budget: u64::MAX,
            tenants: Mutex::new(tenants),
        }
    }

    /// Cap each tenant's process-lifetime retry spend at `budget`.
    pub fn with_retry_budget(mut self, budget: u64) -> Self {
        self.retry_budget = budget;
        self
    }

    fn entry_of(&self, tenant: &str) -> Arc<TenantEntry> {
        let mut tenants = lock_recover(&self.tenants);
        tenants
            .entry(tenant.to_string())
            .or_insert_with(|| {
                Arc::new(TenantEntry {
                    cap: Arc::new(ConcurrencyCap::new(self.default_cap)),
                    retries_used: AtomicU64::new(0),
                })
            })
            .clone()
    }

    /// Admit one query for `tenant`: a permit held until the response is
    /// written, or `Err(limit)` when the tenant is at its cap (the
    /// reject also bumps the tenant's rejected counter).
    pub fn admit(&self, tenant: &str) -> Result<TenantPermit, usize> {
        let entry = self.entry_of(tenant);
        if entry.cap.try_begin() {
            Ok(TenantPermit { cap: entry.cap.clone() })
        } else {
            Err(entry.cap.limit())
        }
    }

    /// Spend one retry from `tenant`'s budget: `true` (and the unit is
    /// spent) while under budget, `false` once dry — the caller answers
    /// with the underlying failure instead of re-running. Lock-free on
    /// the hot path; the CAS loop never over-spends under contention.
    pub fn try_spend_retry(&self, tenant: &str) -> bool {
        let entry = self.entry_of(tenant);
        let mut used = entry.retries_used.load(Ordering::Relaxed);
        loop {
            if used >= self.retry_budget {
                return false;
            }
            match entry.retries_used.compare_exchange_weak(
                used,
                used + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(actual) => used = actual,
            }
        }
    }

    /// The configured per-tenant retry budget.
    pub fn retry_budget(&self) -> u64 {
        self.retry_budget
    }

    /// Per-tenant counters for the `stats` op, sorted by tenant name:
    /// `{tenant: {cap, inflight, peak_inflight, rejected, retries_used}}`.
    pub fn snapshot(&self) -> Json {
        let tenants = lock_recover(&self.tenants);
        let mut rows: Vec<(String, Json)> = tenants
            .iter()
            .map(|(name, entry)| {
                let row = Json::Obj(vec![
                    ("cap".into(), Json::Num(entry.cap.limit() as f64)),
                    ("inflight".into(), Json::Num(entry.cap.inflight() as f64)),
                    ("peak_inflight".into(), Json::Num(entry.cap.peak_inflight() as f64)),
                    ("rejected".into(), Json::Num(entry.cap.rejected() as f64)),
                    (
                        "retries_used".into(),
                        Json::Num(entry.retries_used.load(Ordering::Relaxed) as f64),
                    ),
                ]);
                (name.clone(), row)
            })
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        Json::Obj(rows)
    }

    /// Total rejects across all tenants.
    pub fn total_rejected(&self) -> u64 {
        lock_recover(&self.tenants).values().map(|e| e.cap.rejected()).sum()
    }
}

/// An admitted query's slot under its tenant's cap; released on drop
/// (outcome delivered, or the query failing anywhere in between).
pub struct TenantPermit {
    cap: Arc<ConcurrencyCap>,
}

impl Drop for TenantPermit {
    fn drop(&mut self) {
        self.cap.release();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn over_cap_tenants_get_typed_rejects_not_queueing() {
        let table = TenantTable::new(2, &[]);
        let a = table.admit("alice").unwrap();
        let _b = table.admit("alice").unwrap();
        assert_eq!(table.admit("alice").unwrap_err(), 2);
        // another tenant is unaffected by alice being at cap
        let _c = table.admit("bob").unwrap();
        drop(a);
        assert!(table.admit("alice").is_ok(), "release frees a slot");
        assert_eq!(table.total_rejected(), 1);
    }

    #[test]
    fn explicit_caps_override_the_default() {
        let table = TenantTable::new(8, &[("metered".into(), 1)]);
        let _only = table.admit("metered").unwrap();
        assert_eq!(table.admit("metered").unwrap_err(), 1);
        let _free = table.admit("anyone-else").unwrap();
        assert!(table.admit("anyone-else").is_ok());
    }

    #[test]
    fn snapshot_reports_per_tenant_counters() {
        let table = TenantTable::new(1, &[]);
        let _held = table.admit("t1").unwrap();
        table.admit("t1").unwrap_err();
        let snap = table.snapshot();
        let t1 = snap.get("t1").unwrap();
        assert_eq!(t1.get("inflight").unwrap().as_u64(), Some(1));
        assert_eq!(t1.get("cap").unwrap().as_u64(), Some(1));
        assert_eq!(t1.get("rejected").unwrap().as_u64(), Some(1));
        assert_eq!(t1.get("retries_used").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn retry_budgets_are_per_tenant_and_run_dry() {
        let table = TenantTable::new(4, &[]).with_retry_budget(2);
        assert_eq!(table.retry_budget(), 2);
        assert!(table.try_spend_retry("alice"));
        assert!(table.try_spend_retry("alice"));
        assert!(!table.try_spend_retry("alice"), "the third retry is over budget");
        // bob's budget is his own
        assert!(table.try_spend_retry("bob"));
        let snap = table.snapshot();
        assert_eq!(snap.get("alice").unwrap().get("retries_used").unwrap().as_u64(), Some(2));
        assert_eq!(snap.get("bob").unwrap().get("retries_used").unwrap().as_u64(), Some(1));
    }
}
