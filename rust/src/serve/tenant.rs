//! Per-tenant admission control for the serve daemon.
//!
//! Each tenant (the `tenant` field on a wire query, defaulting to
//! `"default"`) gets its own [`ConcurrencyCap`]: a query is admitted only
//! if its tenant is under cap, otherwise it earns a typed
//! `tenant_over_cap` reject *immediately* — it never queues, so one
//! tenant flooding the daemon cannot grow another tenant's tail.
//!
//! Composition with the scheduler (see [`crate::sched::caps`]): the cap
//! rations *admission* (how many of a tenant's queries may be in flight),
//! the global [`WorkerBudget`](crate::sched::WorkerBudget) rations
//! *threads* once admitted. An admitted query holds its [`TenantPermit`]
//! from admission until its sweep completes and its outcome is handed to
//! the connection writer — the permit spans the batcher queue and the
//! sweep, so "in flight" means admitted-but-unanswered.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::sched::ConcurrencyCap;

use super::wire::Json;

/// Tenant → cap table. Tenants appear on first use with the default
/// cap unless an explicit cap was configured up front.
pub struct TenantTable {
    default_cap: usize,
    tenants: Mutex<HashMap<String, Arc<ConcurrencyCap>>>,
}

impl TenantTable {
    /// A table admitting up to `default_cap` in-flight queries per
    /// tenant (clamped ≥ 1), with `explicit` per-tenant overrides.
    pub fn new(default_cap: usize, explicit: &[(String, usize)]) -> Self {
        let mut tenants = HashMap::new();
        for (name, cap) in explicit {
            tenants.insert(name.clone(), Arc::new(ConcurrencyCap::new(*cap)));
        }
        TenantTable { default_cap: default_cap.max(1), tenants: Mutex::new(tenants) }
    }

    fn cap_of(&self, tenant: &str) -> Arc<ConcurrencyCap> {
        let mut tenants = self.tenants.lock().unwrap();
        tenants
            .entry(tenant.to_string())
            .or_insert_with(|| Arc::new(ConcurrencyCap::new(self.default_cap)))
            .clone()
    }

    /// Admit one query for `tenant`: a permit held until the response is
    /// written, or `Err(limit)` when the tenant is at its cap (the
    /// reject also bumps the tenant's rejected counter).
    pub fn admit(&self, tenant: &str) -> Result<TenantPermit, usize> {
        let cap = self.cap_of(tenant);
        if cap.try_begin() {
            Ok(TenantPermit { cap })
        } else {
            Err(cap.limit())
        }
    }

    /// Per-tenant counters for the `stats` op, sorted by tenant name:
    /// `{tenant: {cap, inflight, peak_inflight, rejected}}`.
    pub fn snapshot(&self) -> Json {
        let tenants = self.tenants.lock().unwrap();
        let mut rows: Vec<(String, Json)> = tenants
            .iter()
            .map(|(name, cap)| {
                let row = Json::Obj(vec![
                    ("cap".into(), Json::Num(cap.limit() as f64)),
                    ("inflight".into(), Json::Num(cap.inflight() as f64)),
                    ("peak_inflight".into(), Json::Num(cap.peak_inflight() as f64)),
                    ("rejected".into(), Json::Num(cap.rejected() as f64)),
                ]);
                (name.clone(), row)
            })
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        Json::Obj(rows)
    }

    /// Total rejects across all tenants.
    pub fn total_rejected(&self) -> u64 {
        self.tenants.lock().unwrap().values().map(|c| c.rejected()).sum()
    }
}

/// An admitted query's slot under its tenant's cap; released on drop
/// (outcome delivered, or the query failing anywhere in between).
pub struct TenantPermit {
    cap: Arc<ConcurrencyCap>,
}

impl Drop for TenantPermit {
    fn drop(&mut self) {
        self.cap.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn over_cap_tenants_get_typed_rejects_not_queueing() {
        let table = TenantTable::new(2, &[]);
        let a = table.admit("alice").unwrap();
        let _b = table.admit("alice").unwrap();
        assert_eq!(table.admit("alice").unwrap_err(), 2);
        // another tenant is unaffected by alice being at cap
        let _c = table.admit("bob").unwrap();
        drop(a);
        assert!(table.admit("alice").is_ok(), "release frees a slot");
        assert_eq!(table.total_rejected(), 1);
    }

    #[test]
    fn explicit_caps_override_the_default() {
        let table = TenantTable::new(8, &[("metered".into(), 1)]);
        let _only = table.admit("metered").unwrap();
        assert_eq!(table.admit("metered").unwrap_err(), 1);
        let _free = table.admit("anyone-else").unwrap();
        assert!(table.admit("anyone-else").is_ok());
    }

    #[test]
    fn snapshot_reports_per_tenant_counters() {
        let table = TenantTable::new(1, &[]);
        let _held = table.admit("t1").unwrap();
        table.admit("t1").unwrap_err();
        let snap = table.snapshot();
        let t1 = snap.get("t1").unwrap();
        assert_eq!(t1.get("inflight").unwrap().as_u64(), Some(1));
        assert_eq!(t1.get("cap").unwrap().as_u64(), Some(1));
        assert_eq!(t1.get("rejected").unwrap().as_u64(), Some(1));
    }
}
