//! The `jgraph serve` daemon: a std-TCP front end over the registry,
//! batcher, tenant table, and stats — line-delimited JSON in, one
//! response line per request, in request order per connection.
//!
//! Threading: one accept loop (nonblocking + poll, so shutdown is
//! observed), one batch dispatcher driving [`Batcher::next_ready`], and
//! per connection a reader (decode + admission) and a writer (response
//! ordering). Admission work — pipeline compile, param preflight,
//! tenant cap — happens on the reader so a reject costs microseconds;
//! graph prep and the sweep happen on the dispatcher.
//!
//! Graceful drain: the wire `shutdown` op, [`Server::shutdown`], or
//! SIGTERM (via [`install_termination_handler`] + the serve CLI loop)
//! all set one flag and drain the batcher — queued queries finish and
//! get their responses, new queries earn a typed `draining` reject, and
//! [`Server::join`] returns once every thread is down.

use std::io::{BufRead, BufReader, ErrorKind, Write as _};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{QueryFailure, RunOptions, RunReport};
use crate::sched::faults::{self, retry_backoff, token_of_name};
use crate::sched::{available_workers, Deadline, FaultPlan, Seam};

use super::batcher::{BatchOutcome, Batcher, BindingKey, Pending};
use super::lock_recover;
use super::registry::ServeRegistry;
use super::stats::ServeStats;
use super::tenant::TenantTable;
use super::wire::{self, Json, QueryRequest, RejectKind, Request};

/// Base delay for the deterministic retry backoff: attempt `n` waits
/// `base * 2^n` plus a seeded jitter of up to one base (see
/// [`retry_backoff`]). Small on purpose — the sweeps being retried are
/// millisecond-scale and the dispatcher sleeps through the backoff.
const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(1);

/// Daemon knobs. The registry (and its resident-graph cap) is built by
/// the caller and passed to [`Server::start`] separately, so tests and
/// embedders can pre-register graphs.
#[derive(Clone)]
pub struct ServeConfig {
    /// Bind address; `127.0.0.1:0` picks an ephemeral port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// How long the first query of a batch waits for company.
    pub batch_window: Duration,
    /// In-flight cap for tenants without an explicit entry.
    pub default_tenant_cap: usize,
    /// Explicit per-tenant caps.
    pub tenant_caps: Vec<(String, usize)>,
    /// Worker-thread target per sweep (leased from the global
    /// [`WorkerBudget`](crate::sched::WorkerBudget) at dispatch).
    pub sweep_workers: usize,
    /// Socket read timeout per connection: how often an idle reader
    /// wakes to observe shutdown (and to advance its idle clock).
    pub read_timeout: Duration,
    /// Reap a connection after this much continuous silence — a client
    /// that died without closing its socket stops pinning a reader
    /// thread (ISSUE 10 satellite).
    pub idle_timeout: Duration,
    /// Retry attempts per query beyond the first run (transient
    /// failures only; each retry also spends tenant retry budget).
    pub retry_limit: u32,
    /// Process-lifetime retry budget per tenant.
    pub retry_budget_per_tenant: u64,
    /// Deterministic fault-injection schedule for chaos testing (the
    /// `--fault-plan` flag / `$JGRAPH_FAULT_PLAN`); `None` in
    /// production.
    pub fault_plan: Option<Arc<FaultPlan>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            batch_window: Duration::from_millis(2),
            default_tenant_cap: 64,
            tenant_caps: Vec::new(),
            sweep_workers: available_workers(),
            read_timeout: Duration::from_millis(250),
            idle_timeout: Duration::from_secs(300),
            retry_limit: 2,
            retry_budget_per_tenant: 256,
            fault_plan: None,
        }
    }
}

/// Everything the daemon's threads share.
struct Shared {
    registry: Arc<ServeRegistry>,
    batcher: Batcher,
    tenants: TenantTable,
    stats: ServeStats,
    shutdown: AtomicBool,
    sweep_workers: usize,
    read_timeout: Duration,
    idle_timeout: Duration,
    retry_limit: u32,
    fault_plan: Option<Arc<FaultPlan>>,
    /// Read-half clones of live connections, for EOF-ing idle readers at
    /// join time.
    conns: Mutex<Vec<TcpStream>>,
}

/// A running daemon. Drop order is irrelevant — call [`Server::join`]
/// for a clean exit.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    dispatch: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind, spawn the accept + dispatch threads, and return
    /// immediately.
    pub fn start(config: ServeConfig, registry: Arc<ServeRegistry>) -> Result<Server> {
        let listener =
            TcpListener::bind(&config.addr).with_context(|| format!("binding {}", config.addr))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            registry,
            batcher: Batcher::new(config.batch_window),
            tenants: TenantTable::new(config.default_tenant_cap, &config.tenant_caps)
                .with_retry_budget(config.retry_budget_per_tenant),
            stats: ServeStats::default(),
            shutdown: AtomicBool::new(false),
            sweep_workers: config.sweep_workers.max(1),
            read_timeout: config.read_timeout.max(Duration::from_millis(1)),
            idle_timeout: config.idle_timeout,
            retry_limit: config.retry_limit,
            fault_plan: config.fault_plan.clone(),
            conns: Mutex::new(Vec::new()),
        });
        let dispatch = {
            let shared = shared.clone();
            std::thread::spawn(move || {
                while let Some((key, items)) = shared.batcher.next_ready() {
                    // The dispatcher outlives any single batch: a panic
                    // escaping every inner fence drops that batch (its
                    // clients get typed dropped-query responses when the
                    // reply senders drop) but the daemon keeps serving.
                    let fenced = catch_unwind(AssertUnwindSafe(|| {
                        execute_batch(&shared, &key, items);
                    }));
                    if fenced.is_err() {
                        shared.stats.panics_caught.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        };
        let handlers = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let handlers = handlers.clone();
            std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let _ = stream.set_nonblocking(false);
                            if let Ok(clone) = stream.try_clone() {
                                lock_recover(&shared.conns).push(clone);
                            }
                            let shared = shared.clone();
                            let handler =
                                std::thread::spawn(move || handle_connection(shared, stream));
                            lock_recover(&handlers).push(handler);
                        }
                        // nonblocking accept: poll so the shutdown flag
                        // is observed within ~10ms
                        Err(_) => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
            })
        };
        Ok(Server { shared, addr, accept: Some(accept), dispatch: Some(dispatch), handlers })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin graceful drain: stop accepting and admitting, finish what
    /// is queued. Idempotent; also triggered by the wire `shutdown` op.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.batcher.drain();
    }

    /// Whether drain has begun (wire op, SIGTERM loop, or
    /// [`Self::shutdown`]).
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Drain and wait for every thread: accept loop, dispatcher (which
    /// flushes all queued sweeps first), then the connection handlers
    /// (their readers are EOF-ed; pending responses still get written).
    pub fn join(mut self) -> Result<()> {
        self.shutdown();
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        if let Some(h) = self.dispatch.take() {
            h.join().map_err(|_| anyhow::anyhow!("dispatch thread panicked"))?;
        }
        // every outcome is delivered; unblock readers idling in
        // read_line (writers flush their queues and follow)
        for conn in lock_recover(&self.shared.conns).drain(..) {
            let _ = conn.shutdown(Shutdown::Read);
        }
        let handlers = std::mem::take(&mut *lock_recover(&self.handlers));
        for h in handlers {
            h.join().map_err(|_| anyhow::anyhow!("connection handler panicked"))?;
        }
        Ok(())
    }
}

/// What the reader hands the writer for one request, preserving request
/// order on the connection.
enum Deliver {
    /// A response that is already known (acks, stats, rejects).
    Now(String),
    /// A query waiting on its sweep.
    Wait {
        request: Box<QueryRequest>,
        enqueued: Instant,
        outcome_rx: mpsc::Receiver<BatchOutcome>,
    },
}

fn handle_connection(shared: Arc<Shared>, stream: TcpStream) {
    let Ok(write_half) = stream.try_clone() else { return };
    // Bounded reads: a silent or dead client wakes the reader every
    // `read_timeout` so it can observe shutdown, and after `idle_timeout`
    // of continuous silence the connection is reaped — a client that
    // died without closing its socket no longer pins a reader thread
    // forever.
    let _ = stream.set_read_timeout(Some(shared.read_timeout));
    let (tx, rx) = mpsc::channel::<Deliver>();
    let writer_shared = shared.clone();
    let writer = std::thread::spawn(move || write_responses(&writer_shared, write_half, rx));
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut idle = Duration::ZERO;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                idle = Duration::ZERO;
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    // Fence the request: an injected panic in admission
                    // (e.g. a `panic@compile` fault rule) becomes a typed
                    // response instead of a dead connection.
                    let deliver =
                        match catch_unwind(AssertUnwindSafe(|| dispatch_request(&shared, trimmed)))
                        {
                            Ok(deliver) => deliver,
                            Err(payload) => {
                                shared.stats.panics_caught.fetch_add(1, Ordering::Relaxed);
                                let msg = format!(
                                    "request handling panicked: {}",
                                    faults::panic_message(payload.as_ref())
                                );
                                Deliver::Now(wire::encode_error(&RejectKind::ExecFailed, &msg))
                            }
                        };
                    if tx.send(deliver).is_err() {
                        break;
                    }
                }
                line.clear();
            }
            // A timed-out read is an idle tick, not an error. Any bytes
            // of a partial line already read stay accumulated in `line`.
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                idle += shared.read_timeout;
                if idle >= shared.idle_timeout {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    drop(tx);
    let _ = writer.join();
}

/// Decode one request line and run admission; never blocks on the sweep.
fn dispatch_request(shared: &Arc<Shared>, line: &str) -> Deliver {
    let request = match Request::decode(line) {
        Ok(r) => r,
        Err(msg) => return Deliver::Now(wire::encode_error(&RejectKind::BadRequest, &msg)),
    };
    match request {
        Request::Ping => Deliver::Now(wire::encode_ack("ping")),
        Request::Stats => Deliver::Now(stats_response(shared)),
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.batcher.drain();
            Deliver::Now(wire::encode_ack("shutdown"))
        }
        Request::Query(q) => admit_query(shared, q),
    }
}

/// Admission: typed rejects for unknown names, bad params, tenants at
/// cap, and draining; otherwise queue the query and hand the writer a
/// receiver for its outcome.
fn admit_query(shared: &Arc<Shared>, q: Box<QueryRequest>) -> Deliver {
    let reject = |kind: RejectKind, msg: String| Deliver::Now(wire::encode_error(&kind, &msg));
    if shared.batcher.is_draining() {
        return reject(RejectKind::Draining, "daemon is draining".into());
    }
    if !shared.registry.is_registered(&q.graph) {
        return reject(RejectKind::UnknownGraph, format!("no graph registered as {:?}", q.graph));
    }
    // The compile fault seam, keyed by algorithm name — a
    // `compile_fail@compile#wcc` rule turns every wcc admission into a
    // typed compile reject while other algorithms stay clean.
    if let Some(plan) = &shared.fault_plan {
        if let Err(fault) = plan.trip(Seam::Compile, token_of_name(&q.algo)) {
            return reject(RejectKind::CompileFailed, format!("{fault} (algo {:?})", q.algo));
        }
    }
    let pipeline = match shared.registry.pipeline(&q.algo) {
        Ok(p) => p,
        Err(None) => {
            return reject(RejectKind::UnknownAlgo, format!("no algorithm named {:?}", q.algo))
        }
        Err(Some(msg)) => return reject(RejectKind::CompileFailed, msg),
    };
    let mut params = crate::dsl::ParamSet::new();
    for (name, value) in &q.params {
        params.set(name.clone(), *value);
    }
    if let Err(e) = pipeline.program().resolve_params(&params) {
        return reject(RejectKind::BadRequest, format!("params: {e}"));
    }
    let permit = match shared.tenants.admit(&q.tenant) {
        Ok(p) => p,
        Err(limit) => {
            let msg = format!("tenant {:?} is at its cap of {limit} in-flight queries", q.tenant);
            return reject(RejectKind::TenantOverCap, msg);
        }
    };
    let mut opts = RunOptions { root: q.root, params, ..Default::default() };
    if let Some(direction) = q.direction {
        opts.direction = direction;
    }
    opts.max_supersteps = q.max_supersteps;
    // The deadline clock starts at admission, so queue time spends the
    // budget too — a query that waited its whole budget out in the
    // batcher fails typed before a single superstep runs.
    if let Some(us) = q.deadline_us {
        opts = opts.with_deadline(Deadline::in_duration(Duration::from_micros(us)));
    }
    if let Some(plan) = &shared.fault_plan {
        opts = opts.with_faults(plan.clone());
    }
    let enqueued = Instant::now();
    let (outcome_tx, outcome_rx) = mpsc::channel();
    let pending =
        Pending { opts, tenant: q.tenant.clone(), permit, enqueued, reply: outcome_tx };
    let key = BindingKey { graph: q.graph.clone(), algo: q.algo.clone() };
    match shared.batcher.submit(key, pending) {
        Ok(()) => Deliver::Wait { request: q, enqueued, outcome_rx },
        Err(_rejected) => reject(RejectKind::Draining, "daemon is draining".into()),
    }
}

/// The dispatcher's body: resolve the binding, run one **isolated**
/// sweep for the whole batch (per-query panic fences — one poisoned
/// query fails alone, its siblings' reports stay bit-identical to a
/// fault-free sweep), retry transient failures with deterministic
/// backoff under the tenant's retry budget, and send every query its
/// own outcome.
fn execute_batch(shared: &Arc<Shared>, key: &BindingKey, items: Vec<Pending>) {
    let dispatch = Instant::now();
    let batch_size = items.len();
    shared.stats.record_batch(batch_size);
    let fail_all = |items: Vec<Pending>, failure: QueryFailure| {
        let service = dispatch.elapsed();
        for p in items {
            let outcome = BatchOutcome {
                result: Err(failure.clone()),
                queue: dispatch.duration_since(p.enqueued),
                service,
                batch_size,
            };
            let _ = p.reply.send(outcome);
        }
    };
    let batch_failure = |message: String| QueryFailure::Error { message, transient: false };
    let graph = match shared.registry.graph(&key.graph) {
        Ok(g) => g,
        Err(e) => {
            let msg = e.unwrap_or_else(|| format!("no graph registered as {:?}", key.graph));
            return fail_all(items, batch_failure(msg));
        }
    };
    let pipeline = match shared.registry.pipeline(&key.algo) {
        Ok(p) => p,
        Err(e) => {
            let msg = e.unwrap_or_else(|| format!("no algorithm named {:?}", key.algo));
            return fail_all(items, batch_failure(msg));
        }
    };
    let bound = match pipeline.bind(graph) {
        Ok(b) => b,
        Err(e) => return fail_all(items, batch_failure(format!("{e:#}"))),
    };
    let queries: Vec<RunOptions> = items.iter().map(|p| p.opts.clone()).collect();
    // The isolated sweep already fences each query; this outer fence
    // covers the sweep *scaffolding* (worker spawn, merge). If it trips,
    // fall back to one-by-one execution — and when a query's fallback
    // fails too, its response carries BOTH causes, the per-query error
    // and the original sweep failure, so neither is lost.
    let mut outcomes = match catch_unwind(AssertUnwindSafe(|| {
        bound.run_batch_isolated(&queries, shared.sweep_workers)
    })) {
        Ok(outcomes) => outcomes,
        Err(payload) => {
            shared.stats.panics_caught.fetch_add(1, Ordering::Relaxed);
            let sweep_cause = faults::panic_message(payload.as_ref());
            queries
                .iter()
                .map(|opts| match catch_unwind(AssertUnwindSafe(|| bound.query(opts))) {
                    Ok(Ok(report)) => Ok(report),
                    Ok(Err(err)) => {
                        Err(attach_sweep_cause(QueryFailure::classify(err), &sweep_cause))
                    }
                    Err(p) => {
                        shared.stats.panics_caught.fetch_add(1, Ordering::Relaxed);
                        Err(attach_sweep_cause(
                            QueryFailure::Panicked(faults::panic_message(p.as_ref())),
                            &sweep_cause,
                        ))
                    }
                })
                .collect()
        }
    };
    // Deterministic retry: transient failures re-run attempt-keyed (so
    // injected attempt-0 faults clear on the retry) after a seeded
    // exponential backoff, each retry spending one unit of the tenant's
    // budget. Deadline expiries are never retried — the budget is spent.
    let seed = shared.fault_plan.as_ref().map(|p| p.seed()).unwrap_or(0);
    for (i, outcome) in outcomes.iter_mut().enumerate() {
        let mut attempt: u32 = 1;
        loop {
            let failure = match outcome {
                Ok(_) => break,
                Err(f) => f.clone(),
            };
            observe_failure(&shared.stats, &failure);
            if !failure.transient() || attempt > shared.retry_limit {
                if failure.transient() {
                    shared.stats.retries_exhausted.fetch_add(1, Ordering::Relaxed);
                }
                break;
            }
            if !shared.tenants.try_spend_retry(&items[i].tenant) {
                shared.stats.retries_exhausted.fetch_add(1, Ordering::Relaxed);
                break;
            }
            shared.stats.retries_attempted.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(retry_backoff(seed, queries[i].root, attempt, RETRY_BACKOFF_BASE));
            let retried =
                bound.run_batch_isolated(&[queries[i].clone().with_attempt(attempt)], 1);
            *outcome = retried.into_iter().next().unwrap_or_else(|| {
                Err(QueryFailure::Error {
                    message: "retry produced no outcome".into(),
                    transient: false,
                })
            });
            attempt += 1;
        }
    }
    let service = dispatch.elapsed();
    for (p, result) in items.into_iter().zip(outcomes) {
        let outcome = BatchOutcome {
            result,
            queue: dispatch.duration_since(p.enqueued),
            service,
            batch_size,
        };
        let _ = p.reply.send(outcome);
    }
}

/// Keep the original whole-sweep failure attached when a query's serial
/// fallback fails as well — losing the first cause made the old
/// fallback undiagnosable.
fn attach_sweep_cause(failure: QueryFailure, sweep_cause: &str) -> QueryFailure {
    let join = |message: String| format!("{message}; batch sweep also failed: {sweep_cause}");
    match failure {
        QueryFailure::Error { message, transient } => {
            QueryFailure::Error { message: join(message), transient }
        }
        QueryFailure::Panicked(message) => QueryFailure::Panicked(join(message)),
        other => other,
    }
}

/// Bump the fault-tolerance counters for one observed failure (each
/// attempt's failure is observed exactly once, retried or not).
fn observe_failure(stats: &ServeStats, failure: &QueryFailure) {
    match failure {
        QueryFailure::Panicked(_) => {
            stats.panics_caught.fetch_add(1, Ordering::Relaxed);
        }
        QueryFailure::DeadlineExceeded(_) => {
            stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
        }
        QueryFailure::Error { .. } => {}
    }
}

/// The writer: one response line per Deliver, in order. Exits when the
/// reader drops the channel (EOF) or the socket dies.
fn write_responses(shared: &Shared, mut stream: TcpStream, rx: mpsc::Receiver<Deliver>) {
    for deliver in rx {
        let line = match deliver {
            Deliver::Now(line) => line,
            Deliver::Wait { request, enqueued, outcome_rx } => match outcome_rx.recv() {
                Ok(outcome) => finish_query(shared, &request, enqueued, outcome),
                Err(_) => {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                    wire::encode_error(&RejectKind::Draining, "query dropped during shutdown")
                }
            },
        };
        if stream.write_all(line.as_bytes()).is_err() || stream.write_all(b"\n").is_err() {
            break;
        }
        let _ = stream.flush();
    }
}

/// Record one finished query's latencies and render its response line.
fn finish_query(
    shared: &Shared,
    req: &QueryRequest,
    enqueued: Instant,
    outcome: BatchOutcome,
) -> String {
    let total = enqueued.elapsed();
    shared.stats.queue.record(outcome.queue);
    shared.stats.service.record(outcome.service);
    shared.stats.total.record(total);
    match &outcome.result {
        Ok(report) => {
            shared.stats.served.fetch_add(1, Ordering::Relaxed);
            Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("op".into(), Json::Str("query".into())),
                ("graph".into(), Json::Str(req.graph.clone())),
                ("algo".into(), Json::Str(req.algo.clone())),
                ("root".into(), Json::Num(req.root as f64)),
                ("tenant".into(), Json::Str(req.tenant.clone())),
                ("report".into(), report_json(report)),
                ("timing".into(), timing_json(&outcome, total)),
            ])
            .render()
        }
        Err(failure) => {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            let kind = match failure {
                QueryFailure::Panicked(_) => RejectKind::WorkerPanicked,
                QueryFailure::DeadlineExceeded(_) => RejectKind::DeadlineExceeded,
                QueryFailure::Error { .. } => RejectKind::ExecFailed,
            };
            Json::Obj(vec![
                ("ok".into(), Json::Bool(false)),
                (
                    "error".into(),
                    Json::Obj(vec![
                        ("kind".into(), Json::Str(kind.code().into())),
                        ("message".into(), Json::Str(failure.to_string())),
                    ]),
                ),
                ("timing".into(), timing_json(&outcome, total)),
            ])
            .render()
        }
    }
}

fn timing_json(outcome: &BatchOutcome, total: Duration) -> Json {
    Json::Obj(vec![
        ("queue_us".into(), Json::Num(outcome.queue.as_micros() as f64)),
        ("service_us".into(), Json::Num(outcome.service.as_micros() as f64)),
        ("total_us".into(), Json::Num(total.as_micros() as f64)),
        ("batch_size".into(), Json::Num(outcome.batch_size as f64)),
    ])
}

/// The full [`RunReport`] as a wire object. Finite floats render
/// shortest-round-trip, so every modeled field survives the wire
/// bit-identically (the serve integration test's contract).
pub fn report_json(report: &RunReport) -> Json {
    let bound: Vec<(String, Json)> =
        report.bound_params.iter().map(|(n, v)| (n.clone(), Json::Num(*v))).collect();
    let deviation = match report.oracle_deviation {
        Some(d) => Json::Num(d),
        None => Json::Null,
    };
    Json::Obj(vec![
        ("program".into(), Json::Str(report.program.clone())),
        ("translator".into(), Json::Str(report.translator.into())),
        ("graph_name".into(), Json::Str(report.graph_name.clone())),
        ("num_vertices".into(), Json::Num(report.num_vertices as f64)),
        ("num_edges".into(), Json::Num(report.num_edges as f64)),
        ("supersteps".into(), Json::Num(report.supersteps as f64)),
        ("push_supersteps".into(), Json::Num(report.push_supersteps as f64)),
        ("pull_supersteps".into(), Json::Num(report.pull_supersteps as f64)),
        ("edges_traversed".into(), Json::Num(report.edges_traversed as f64)),
        ("shards".into(), Json::Num(report.shards as f64)),
        ("auto_shards".into(), Json::Num(report.auto_shards as f64)),
        ("crossing_msgs".into(), Json::Num(report.crossing_msgs as f64)),
        ("exchange_seconds".into(), Json::Num(report.exchange_seconds)),
        ("prep_seconds".into(), Json::Num(report.prep_seconds)),
        ("compile_seconds".into(), Json::Num(report.compile_seconds)),
        ("deploy_seconds".into(), Json::Num(report.deploy_seconds)),
        ("setup_seconds".into(), Json::Num(report.setup_seconds)),
        ("sim_exec_seconds".into(), Json::Num(report.sim_exec_seconds)),
        ("functional_exec_seconds".into(), Json::Num(report.functional_exec_seconds)),
        ("transfer_seconds".into(), Json::Num(report.transfer_seconds)),
        ("query_seconds".into(), Json::Num(report.query_seconds)),
        ("rt_seconds".into(), Json::Num(report.rt_seconds)),
        ("simulated_mteps".into(), Json::Num(report.simulated_mteps)),
        ("hdl_lines".into(), Json::Num(report.hdl_lines as f64)),
        ("total_cycles".into(), Json::Num(report.sim.cycles.total() as f64)),
        ("oracle_deviation".into(), deviation),
        ("bound_params".into(), Json::Obj(bound)),
    ])
}

/// The `stats` response: rolling latency histograms, batch occupancy,
/// registry residency/evictions, and per-tenant counters.
fn stats_response(shared: &Shared) -> String {
    // mirror the fault plan's injection counter into the stats gauge
    // before rendering, so `faults_injected` is current at snapshot time
    if let Some(plan) = &shared.fault_plan {
        shared.stats.faults_injected.store(plan.injected_total(), Ordering::Relaxed);
    }
    let mut fields = vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("stats".into())),
    ];
    fields.extend(shared.stats.to_json_fields());
    let registry = &shared.registry;
    let resident: Vec<Json> = registry.resident_names().into_iter().map(Json::Str).collect();
    let pipelines: Vec<Json> = registry.pipeline_names().into_iter().map(Json::Str).collect();
    fields.push(("resident_graphs".into(), Json::Num(registry.resident_count() as f64)));
    fields.push(("max_resident_graphs".into(), Json::Num(registry.max_resident() as f64)));
    fields.push(("resident".into(), Json::Arr(resident)));
    fields.push(("evictions".into(), Json::Num(registry.evictions() as f64)));
    fields.push(("pipelines".into(), Json::Arr(pipelines)));
    fields.push(("tenants".into(), shared.tenants.snapshot()));
    fields.push(("tenant_rejects".into(), Json::Num(shared.tenants.total_rejected() as f64)));
    fields.push(("retry_budget_per_tenant".into(), Json::Num(shared.tenants.retry_budget() as f64)));
    fields.push((
        "fault_plan".into(),
        match &shared.fault_plan {
            Some(plan) => Json::Str(plan.source().into()),
            None => Json::Null,
        },
    ));
    fields.push(("draining".into(), Json::Bool(shared.batcher.is_draining())));
    Json::Obj(fields).render()
}

static TERMINATION: AtomicBool = AtomicBool::new(false);

/// Route SIGTERM/SIGINT to a graceful drain without a signal-handling
/// dependency: a hand-declared `signal(2)` binding flips one atomic that
/// the serve CLI loop polls (async-signal-safe — the handler only
/// stores). No-op off Unix.
#[cfg(unix)]
pub fn install_termination_handler() {
    use std::os::raw::c_int;
    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;
    extern "C" fn on_term(_sig: c_int) {
        TERMINATION.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_term);
        signal(SIGINT, on_term);
    }
}

#[cfg(not(unix))]
pub fn install_termination_handler() {}

/// Whether a termination signal has arrived since
/// [`install_termination_handler`].
pub fn termination_requested() -> bool {
    TERMINATION.load(Ordering::SeqCst)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::engine::{Session, SessionConfig};
    use crate::graph::generate;
    use crate::serve::client::ServeClient;
    use crate::serve::wire::DEFAULT_TENANT;

    fn tiny_server(max_resident: usize, config: ServeConfig) -> Server {
        let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
        let registry = Arc::new(ServeRegistry::new(session, max_resident));
        registry.register_edges("er", generate::erdos_renyi(128, 1024, 5));
        registry.register_edges("grid", generate::grid2d(16, 16, 5));
        Server::start(config, registry).unwrap()
    }

    fn query(graph: &str, algo: &str, root: u32) -> QueryRequest {
        QueryRequest {
            graph: graph.into(),
            algo: algo.into(),
            root,
            params: Vec::new(),
            direction: None,
            tenant: DEFAULT_TENANT.into(),
            max_supersteps: None,
            deadline_us: None,
        }
    }

    #[test]
    fn ping_query_stats_shutdown_round_trip() {
        let server = tiny_server(4, ServeConfig::default());
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        let pong = c.request(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        let resp = c.query(&query("er", "bfs", 1)).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{}", resp.render());
        let report = resp.get("report").unwrap();
        assert!(report.get("supersteps").unwrap().as_u64().unwrap() > 0);
        assert!(report.get("edges_traversed").unwrap().as_u64().unwrap() > 0);
        let timing = resp.get("timing").unwrap();
        assert!(timing.get("batch_size").unwrap().as_u64().unwrap() >= 1);
        let stats = c.request(r#"{"op":"stats"}"#).unwrap();
        assert_eq!(stats.get("served").unwrap().as_u64(), Some(1));
        assert_eq!(stats.get("resident_graphs").unwrap().as_u64(), Some(1));
        let ack = c.request(r#"{"op":"shutdown"}"#).unwrap();
        assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn unknown_names_and_bad_lines_get_typed_rejects() {
        let server = tiny_server(4, ServeConfig::default());
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        let resp = c.query(&query("nope", "bfs", 0)).unwrap();
        assert_eq!(
            resp.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("unknown_graph")
        );
        let resp = c.query(&query("er", "quantum", 0)).unwrap();
        assert_eq!(
            resp.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("unknown_algo")
        );
        let resp = c.request("this is not json").unwrap();
        assert_eq!(resp.get("error").unwrap().get("kind").unwrap().as_str(), Some("bad_request"));
        // the connection survives every reject
        let resp = c.query(&query("er", "bfs", 0)).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn idle_connections_are_reaped_by_the_read_timeout() {
        let config = ServeConfig {
            read_timeout: Duration::from_millis(10),
            idle_timeout: Duration::from_millis(60),
            ..Default::default()
        };
        let server = tiny_server(4, config);
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        // an active request works normally and resets the idle clock
        let pong = c.ping().unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        // then go silent: the daemon reaps the connection (the reader
        // thread exits and the socket closes) instead of pinning a
        // thread on a client that will never speak again
        let reaped = c.recv();
        assert!(reaped.is_err(), "the reaped connection must read EOF, got {reaped:?}");
        // and join() does not hang on the long-dead connection
        server.join().unwrap();
    }

    #[test]
    fn expired_deadlines_reject_typed_with_partial_accounting() {
        let server = tiny_server(4, ServeConfig::default());
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        let mut q = query("er", "bfs", 1);
        q.deadline_us = Some(0);
        let resp = c.query(&q).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let error = resp.get("error").unwrap();
        assert_eq!(error.get("kind").unwrap().as_str(), Some("deadline_exceeded"));
        let msg = error.get("message").unwrap().as_str().unwrap();
        assert!(msg.contains("deadline exceeded after"), "{msg}");
        // a sane budget on the same connection still serves
        q.deadline_us = Some(60_000_000);
        let resp = c.query(&q).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{}", resp.render());
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("deadline_exceeded").unwrap().as_u64(), Some(1));
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn injected_faults_retry_to_success_and_count() {
        // roots 1 and 2 fault on attempt 0 (a panic and a transfer
        // error); the retry runs attempt 1, which the plan does not
        // match, so both queries ultimately succeed
        let plan = FaultPlan::parse("panic@exec#1;transfer_error@commit#2").unwrap();
        let config = ServeConfig { fault_plan: Some(Arc::new(plan)), ..Default::default() };
        let server = tiny_server(4, config);
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        for root in [1, 2] {
            let resp = c.query(&query("er", "bfs", root)).unwrap();
            assert_eq!(
                resp.get("ok").unwrap().as_bool(),
                Some(true),
                "root {root} must succeed after its retry: {}",
                resp.render()
            );
        }
        let stats = c.stats().unwrap();
        assert!(stats.get("retries_attempted").unwrap().as_u64().unwrap() >= 2);
        assert!(stats.get("panics_caught").unwrap().as_u64().unwrap() >= 1);
        assert!(stats.get("faults_injected").unwrap().as_u64().unwrap() >= 2);
        assert_eq!(stats.get("retries_exhausted").unwrap().as_u64(), Some(0));
        let tenants = stats.get("tenants").unwrap();
        let used = tenants.get(DEFAULT_TENANT).unwrap().get("retries_used").unwrap();
        assert!(used.as_u64().unwrap() >= 2, "retries must spend tenant budget");
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn injected_compile_failures_are_typed_and_keyed_by_algorithm() {
        let plan = FaultPlan::parse("compile_fail@compile#wcc").unwrap();
        let config = ServeConfig { fault_plan: Some(Arc::new(plan)), ..Default::default() };
        let server = tiny_server(4, config);
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        let resp = c.query(&query("er", "wcc", 0)).unwrap();
        assert_eq!(
            resp.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("compile_failed"),
            "{}",
            resp.render()
        );
        // other algorithms on the same daemon are untouched
        let resp = c.query(&query("er", "bfs", 0)).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn exhausted_retry_budgets_surface_the_failure() {
        // every exec attempt of root 3 faults (bare #3 matches attempt 0
        // only — use a modulus-free rule keyed to each attempt instead):
        // attempts 0..=2 are tokens 3, 3+2^32, 3+2^33 — key all three so
        // the query can never succeed, then give the tenant budget 1
        let plan = FaultPlan::parse(&format!(
            "exec_fail@exec#3;exec_fail@exec#{};exec_fail@exec#{}",
            3u64 + (1u64 << 32),
            3u64 + (2u64 << 32),
        ))
        .unwrap();
        let config = ServeConfig {
            fault_plan: Some(Arc::new(plan)),
            retry_budget_per_tenant: 1,
            ..Default::default()
        };
        let server = tiny_server(4, config);
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        let resp = c.query(&query("er", "bfs", 3)).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            resp.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("exec_failed")
        );
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("retries_attempted").unwrap().as_u64(), Some(1));
        assert!(stats.get("retries_exhausted").unwrap().as_u64().unwrap() >= 1);
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn draining_daemon_rejects_new_queries() {
        let server = tiny_server(4, ServeConfig::default());
        server.shutdown();
        let mut c = ServeClient::connect(server.local_addr());
        // the accept loop may already be down; if we got in, the reject
        // must be typed
        if let Ok(c) = c.as_mut() {
            if let Ok(resp) = c.query(&query("er", "bfs", 0)) {
                assert_eq!(
                    resp.get("error").unwrap().get("kind").unwrap().as_str(),
                    Some("draining")
                );
            }
        }
        drop(c);
        server.join().unwrap();
    }
}
