//! The `jgraph serve` daemon: a std-TCP front end over the registry,
//! batcher, tenant table, and stats — line-delimited JSON in, one
//! response line per request, in request order per connection.
//!
//! Threading: one accept loop (nonblocking + poll, so shutdown is
//! observed), one batch dispatcher driving [`Batcher::next_ready`], and
//! per connection a reader (decode + admission) and a writer (response
//! ordering). Admission work — pipeline compile, param preflight,
//! tenant cap — happens on the reader so a reject costs microseconds;
//! graph prep and the sweep happen on the dispatcher.
//!
//! Graceful drain: the wire `shutdown` op, [`Server::shutdown`], or
//! SIGTERM (via [`install_termination_handler`] + the serve CLI loop)
//! all set one flag and drain the batcher — queued queries finish and
//! get their responses, new queries earn a typed `draining` reject, and
//! [`Server::join`] returns once every thread is down.

use std::io::{BufRead, BufReader, Write as _};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{RunOptions, RunReport};
use crate::sched::available_workers;

use super::batcher::{BatchOutcome, Batcher, BindingKey, Pending};
use super::registry::ServeRegistry;
use super::stats::ServeStats;
use super::tenant::TenantTable;
use super::wire::{self, Json, QueryRequest, RejectKind, Request};

/// Daemon knobs. The registry (and its resident-graph cap) is built by
/// the caller and passed to [`Server::start`] separately, so tests and
/// embedders can pre-register graphs.
#[derive(Clone)]
pub struct ServeConfig {
    /// Bind address; `127.0.0.1:0` picks an ephemeral port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// How long the first query of a batch waits for company.
    pub batch_window: Duration,
    /// In-flight cap for tenants without an explicit entry.
    pub default_tenant_cap: usize,
    /// Explicit per-tenant caps.
    pub tenant_caps: Vec<(String, usize)>,
    /// Worker-thread target per sweep (leased from the global
    /// [`WorkerBudget`](crate::sched::WorkerBudget) at dispatch).
    pub sweep_workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            batch_window: Duration::from_millis(2),
            default_tenant_cap: 64,
            tenant_caps: Vec::new(),
            sweep_workers: available_workers(),
        }
    }
}

/// Everything the daemon's threads share.
struct Shared {
    registry: Arc<ServeRegistry>,
    batcher: Batcher,
    tenants: TenantTable,
    stats: ServeStats,
    shutdown: AtomicBool,
    sweep_workers: usize,
    /// Read-half clones of live connections, for EOF-ing idle readers at
    /// join time.
    conns: Mutex<Vec<TcpStream>>,
}

/// A running daemon. Drop order is irrelevant — call [`Server::join`]
/// for a clean exit.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    dispatch: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind, spawn the accept + dispatch threads, and return
    /// immediately.
    pub fn start(config: ServeConfig, registry: Arc<ServeRegistry>) -> Result<Server> {
        let listener =
            TcpListener::bind(&config.addr).with_context(|| format!("binding {}", config.addr))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            registry,
            batcher: Batcher::new(config.batch_window),
            tenants: TenantTable::new(config.default_tenant_cap, &config.tenant_caps),
            stats: ServeStats::default(),
            shutdown: AtomicBool::new(false),
            sweep_workers: config.sweep_workers.max(1),
            conns: Mutex::new(Vec::new()),
        });
        let dispatch = {
            let shared = shared.clone();
            std::thread::spawn(move || {
                while let Some((key, items)) = shared.batcher.next_ready() {
                    execute_batch(&shared, &key, items);
                }
            })
        };
        let handlers = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let handlers = handlers.clone();
            std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let _ = stream.set_nonblocking(false);
                            if let Ok(clone) = stream.try_clone() {
                                shared.conns.lock().unwrap().push(clone);
                            }
                            let shared = shared.clone();
                            let handler =
                                std::thread::spawn(move || handle_connection(shared, stream));
                            handlers.lock().unwrap().push(handler);
                        }
                        // nonblocking accept: poll so the shutdown flag
                        // is observed within ~10ms
                        Err(_) => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
            })
        };
        Ok(Server { shared, addr, accept: Some(accept), dispatch: Some(dispatch), handlers })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin graceful drain: stop accepting and admitting, finish what
    /// is queued. Idempotent; also triggered by the wire `shutdown` op.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.batcher.drain();
    }

    /// Whether drain has begun (wire op, SIGTERM loop, or
    /// [`Self::shutdown`]).
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Drain and wait for every thread: accept loop, dispatcher (which
    /// flushes all queued sweeps first), then the connection handlers
    /// (their readers are EOF-ed; pending responses still get written).
    pub fn join(mut self) -> Result<()> {
        self.shutdown();
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        if let Some(h) = self.dispatch.take() {
            h.join().map_err(|_| anyhow::anyhow!("dispatch thread panicked"))?;
        }
        // every outcome is delivered; unblock readers idling in
        // read_line (writers flush their queues and follow)
        for conn in self.shared.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(Shutdown::Read);
        }
        let handlers = std::mem::take(&mut *self.handlers.lock().unwrap());
        for h in handlers {
            h.join().map_err(|_| anyhow::anyhow!("connection handler panicked"))?;
        }
        Ok(())
    }
}

/// What the reader hands the writer for one request, preserving request
/// order on the connection.
enum Deliver {
    /// A response that is already known (acks, stats, rejects).
    Now(String),
    /// A query waiting on its sweep.
    Wait {
        request: Box<QueryRequest>,
        enqueued: Instant,
        outcome_rx: mpsc::Receiver<BatchOutcome>,
    },
}

fn handle_connection(shared: Arc<Shared>, stream: TcpStream) {
    let Ok(write_half) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::channel::<Deliver>();
    let writer_shared = shared.clone();
    let writer = std::thread::spawn(move || write_responses(&writer_shared, write_half, rx));
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if tx.send(dispatch_request(&shared, trimmed)).is_err() {
            break;
        }
    }
    drop(tx);
    let _ = writer.join();
}

/// Decode one request line and run admission; never blocks on the sweep.
fn dispatch_request(shared: &Arc<Shared>, line: &str) -> Deliver {
    let request = match Request::decode(line) {
        Ok(r) => r,
        Err(msg) => return Deliver::Now(wire::encode_error(&RejectKind::BadRequest, &msg)),
    };
    match request {
        Request::Ping => Deliver::Now(wire::encode_ack("ping")),
        Request::Stats => Deliver::Now(stats_response(shared)),
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.batcher.drain();
            Deliver::Now(wire::encode_ack("shutdown"))
        }
        Request::Query(q) => admit_query(shared, q),
    }
}

/// Admission: typed rejects for unknown names, bad params, tenants at
/// cap, and draining; otherwise queue the query and hand the writer a
/// receiver for its outcome.
fn admit_query(shared: &Arc<Shared>, q: Box<QueryRequest>) -> Deliver {
    let reject = |kind: RejectKind, msg: String| Deliver::Now(wire::encode_error(&kind, &msg));
    if shared.batcher.is_draining() {
        return reject(RejectKind::Draining, "daemon is draining".into());
    }
    if !shared.registry.is_registered(&q.graph) {
        return reject(RejectKind::UnknownGraph, format!("no graph registered as {:?}", q.graph));
    }
    let pipeline = match shared.registry.pipeline(&q.algo) {
        Ok(p) => p,
        Err(None) => {
            return reject(RejectKind::UnknownAlgo, format!("no algorithm named {:?}", q.algo))
        }
        Err(Some(msg)) => return reject(RejectKind::CompileFailed, msg),
    };
    let mut params = crate::dsl::ParamSet::new();
    for (name, value) in &q.params {
        params.set(name.clone(), *value);
    }
    if let Err(e) = pipeline.program().resolve_params(&params) {
        return reject(RejectKind::BadRequest, format!("params: {e}"));
    }
    let permit = match shared.tenants.admit(&q.tenant) {
        Ok(p) => p,
        Err(limit) => {
            let msg = format!("tenant {:?} is at its cap of {limit} in-flight queries", q.tenant);
            return reject(RejectKind::TenantOverCap, msg);
        }
    };
    let mut opts = RunOptions { root: q.root, params, ..Default::default() };
    if let Some(direction) = q.direction {
        opts.direction = direction;
    }
    opts.max_supersteps = q.max_supersteps;
    let enqueued = Instant::now();
    let (outcome_tx, outcome_rx) = mpsc::channel();
    let pending = Pending { opts, permit, enqueued, reply: outcome_tx };
    let key = BindingKey { graph: q.graph.clone(), algo: q.algo.clone() };
    match shared.batcher.submit(key, pending) {
        Ok(()) => Deliver::Wait { request: q, enqueued, outcome_rx },
        Err(_rejected) => reject(RejectKind::Draining, "daemon is draining".into()),
    }
}

/// The dispatcher's body: resolve the binding, run one sweep for the
/// whole batch, and send every query its outcome. A failing sweep falls
/// back to serial execution so each query gets its *own* report or
/// error.
fn execute_batch(shared: &Arc<Shared>, key: &BindingKey, items: Vec<Pending>) {
    let dispatch = Instant::now();
    let batch_size = items.len();
    shared.stats.record_batch(batch_size);
    let fail = |items: Vec<Pending>, msg: String| {
        let service = dispatch.elapsed();
        for p in items {
            let outcome = BatchOutcome {
                result: Err(msg.clone()),
                queue: dispatch.duration_since(p.enqueued),
                service,
                batch_size,
            };
            let _ = p.reply.send(outcome);
        }
    };
    let graph = match shared.registry.graph(&key.graph) {
        Ok(g) => g,
        Err(e) => {
            let msg = e.unwrap_or_else(|| format!("no graph registered as {:?}", key.graph));
            return fail(items, msg);
        }
    };
    let pipeline = match shared.registry.pipeline(&key.algo) {
        Ok(p) => p,
        Err(e) => {
            let msg = e.unwrap_or_else(|| format!("no algorithm named {:?}", key.algo));
            return fail(items, msg);
        }
    };
    let bound = match pipeline.bind(graph) {
        Ok(b) => b,
        Err(e) => return fail(items, format!("{e:#}")),
    };
    let queries: Vec<RunOptions> = items.iter().map(|p| p.opts.clone()).collect();
    match bound.run_batch_parallel(&queries, shared.sweep_workers) {
        Ok(reports) => {
            let service = dispatch.elapsed();
            for (p, report) in items.into_iter().zip(reports) {
                let outcome = BatchOutcome {
                    result: Ok(report),
                    queue: dispatch.duration_since(p.enqueued),
                    service,
                    batch_size,
                };
                let _ = p.reply.send(outcome);
            }
        }
        Err(_) => {
            for p in items {
                let result = bound.query(&p.opts).map_err(|e| format!("{e:#}"));
                let outcome = BatchOutcome {
                    result,
                    queue: dispatch.duration_since(p.enqueued),
                    service: dispatch.elapsed(),
                    batch_size,
                };
                let _ = p.reply.send(outcome);
            }
        }
    }
}

/// The writer: one response line per Deliver, in order. Exits when the
/// reader drops the channel (EOF) or the socket dies.
fn write_responses(shared: &Shared, mut stream: TcpStream, rx: mpsc::Receiver<Deliver>) {
    for deliver in rx {
        let line = match deliver {
            Deliver::Now(line) => line,
            Deliver::Wait { request, enqueued, outcome_rx } => match outcome_rx.recv() {
                Ok(outcome) => finish_query(shared, &request, enqueued, outcome),
                Err(_) => {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                    wire::encode_error(&RejectKind::Draining, "query dropped during shutdown")
                }
            },
        };
        if stream.write_all(line.as_bytes()).is_err() || stream.write_all(b"\n").is_err() {
            break;
        }
        let _ = stream.flush();
    }
}

/// Record one finished query's latencies and render its response line.
fn finish_query(
    shared: &Shared,
    req: &QueryRequest,
    enqueued: Instant,
    outcome: BatchOutcome,
) -> String {
    let total = enqueued.elapsed();
    shared.stats.queue.record(outcome.queue);
    shared.stats.service.record(outcome.service);
    shared.stats.total.record(total);
    match &outcome.result {
        Ok(report) => {
            shared.stats.served.fetch_add(1, Ordering::Relaxed);
            Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("op".into(), Json::Str("query".into())),
                ("graph".into(), Json::Str(req.graph.clone())),
                ("algo".into(), Json::Str(req.algo.clone())),
                ("root".into(), Json::Num(req.root as f64)),
                ("tenant".into(), Json::Str(req.tenant.clone())),
                ("report".into(), report_json(report)),
                ("timing".into(), timing_json(&outcome, total)),
            ])
            .render()
        }
        Err(msg) => {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            Json::Obj(vec![
                ("ok".into(), Json::Bool(false)),
                (
                    "error".into(),
                    Json::Obj(vec![
                        ("kind".into(), Json::Str("exec_failed".into())),
                        ("message".into(), Json::Str(msg.clone())),
                    ]),
                ),
                ("timing".into(), timing_json(&outcome, total)),
            ])
            .render()
        }
    }
}

fn timing_json(outcome: &BatchOutcome, total: Duration) -> Json {
    Json::Obj(vec![
        ("queue_us".into(), Json::Num(outcome.queue.as_micros() as f64)),
        ("service_us".into(), Json::Num(outcome.service.as_micros() as f64)),
        ("total_us".into(), Json::Num(total.as_micros() as f64)),
        ("batch_size".into(), Json::Num(outcome.batch_size as f64)),
    ])
}

/// The full [`RunReport`] as a wire object. Finite floats render
/// shortest-round-trip, so every modeled field survives the wire
/// bit-identically (the serve integration test's contract).
pub fn report_json(report: &RunReport) -> Json {
    let bound: Vec<(String, Json)> =
        report.bound_params.iter().map(|(n, v)| (n.clone(), Json::Num(*v))).collect();
    let deviation = match report.oracle_deviation {
        Some(d) => Json::Num(d),
        None => Json::Null,
    };
    Json::Obj(vec![
        ("program".into(), Json::Str(report.program.clone())),
        ("translator".into(), Json::Str(report.translator.into())),
        ("graph_name".into(), Json::Str(report.graph_name.clone())),
        ("num_vertices".into(), Json::Num(report.num_vertices as f64)),
        ("num_edges".into(), Json::Num(report.num_edges as f64)),
        ("supersteps".into(), Json::Num(report.supersteps as f64)),
        ("push_supersteps".into(), Json::Num(report.push_supersteps as f64)),
        ("pull_supersteps".into(), Json::Num(report.pull_supersteps as f64)),
        ("edges_traversed".into(), Json::Num(report.edges_traversed as f64)),
        ("shards".into(), Json::Num(report.shards as f64)),
        ("auto_shards".into(), Json::Num(report.auto_shards as f64)),
        ("crossing_msgs".into(), Json::Num(report.crossing_msgs as f64)),
        ("exchange_seconds".into(), Json::Num(report.exchange_seconds)),
        ("prep_seconds".into(), Json::Num(report.prep_seconds)),
        ("compile_seconds".into(), Json::Num(report.compile_seconds)),
        ("deploy_seconds".into(), Json::Num(report.deploy_seconds)),
        ("setup_seconds".into(), Json::Num(report.setup_seconds)),
        ("sim_exec_seconds".into(), Json::Num(report.sim_exec_seconds)),
        ("functional_exec_seconds".into(), Json::Num(report.functional_exec_seconds)),
        ("transfer_seconds".into(), Json::Num(report.transfer_seconds)),
        ("query_seconds".into(), Json::Num(report.query_seconds)),
        ("rt_seconds".into(), Json::Num(report.rt_seconds)),
        ("simulated_mteps".into(), Json::Num(report.simulated_mteps)),
        ("hdl_lines".into(), Json::Num(report.hdl_lines as f64)),
        ("total_cycles".into(), Json::Num(report.sim.cycles.total() as f64)),
        ("oracle_deviation".into(), deviation),
        ("bound_params".into(), Json::Obj(bound)),
    ])
}

/// The `stats` response: rolling latency histograms, batch occupancy,
/// registry residency/evictions, and per-tenant counters.
fn stats_response(shared: &Shared) -> String {
    let mut fields = vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("stats".into())),
    ];
    fields.extend(shared.stats.to_json_fields());
    let registry = &shared.registry;
    let resident: Vec<Json> = registry.resident_names().into_iter().map(Json::Str).collect();
    let pipelines: Vec<Json> = registry.pipeline_names().into_iter().map(Json::Str).collect();
    fields.push(("resident_graphs".into(), Json::Num(registry.resident_count() as f64)));
    fields.push(("max_resident_graphs".into(), Json::Num(registry.max_resident() as f64)));
    fields.push(("resident".into(), Json::Arr(resident)));
    fields.push(("evictions".into(), Json::Num(registry.evictions() as f64)));
    fields.push(("pipelines".into(), Json::Arr(pipelines)));
    fields.push(("tenants".into(), shared.tenants.snapshot()));
    fields.push(("tenant_rejects".into(), Json::Num(shared.tenants.total_rejected() as f64)));
    fields.push(("draining".into(), Json::Bool(shared.batcher.is_draining())));
    Json::Obj(fields).render()
}

static TERMINATION: AtomicBool = AtomicBool::new(false);

/// Route SIGTERM/SIGINT to a graceful drain without a signal-handling
/// dependency: a hand-declared `signal(2)` binding flips one atomic that
/// the serve CLI loop polls (async-signal-safe — the handler only
/// stores). No-op off Unix.
#[cfg(unix)]
pub fn install_termination_handler() {
    use std::os::raw::c_int;
    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;
    extern "C" fn on_term(_sig: c_int) {
        TERMINATION.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_term);
        signal(SIGINT, on_term);
    }
}

#[cfg(not(unix))]
pub fn install_termination_handler() {}

/// Whether a termination signal has arrived since
/// [`install_termination_handler`].
pub fn termination_requested() -> bool {
    TERMINATION.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Session, SessionConfig};
    use crate::graph::generate;
    use crate::serve::client::ServeClient;
    use crate::serve::wire::DEFAULT_TENANT;

    fn tiny_server(max_resident: usize, config: ServeConfig) -> Server {
        let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
        let registry = Arc::new(ServeRegistry::new(session, max_resident));
        registry.register_edges("er", generate::erdos_renyi(128, 1024, 5));
        registry.register_edges("grid", generate::grid2d(16, 16, 5));
        Server::start(config, registry).unwrap()
    }

    fn query(graph: &str, algo: &str, root: u32) -> QueryRequest {
        QueryRequest {
            graph: graph.into(),
            algo: algo.into(),
            root,
            params: Vec::new(),
            direction: None,
            tenant: DEFAULT_TENANT.into(),
            max_supersteps: None,
        }
    }

    #[test]
    fn ping_query_stats_shutdown_round_trip() {
        let server = tiny_server(4, ServeConfig::default());
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        let pong = c.request(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        let resp = c.query(&query("er", "bfs", 1)).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{}", resp.render());
        let report = resp.get("report").unwrap();
        assert!(report.get("supersteps").unwrap().as_u64().unwrap() > 0);
        assert!(report.get("edges_traversed").unwrap().as_u64().unwrap() > 0);
        let timing = resp.get("timing").unwrap();
        assert!(timing.get("batch_size").unwrap().as_u64().unwrap() >= 1);
        let stats = c.request(r#"{"op":"stats"}"#).unwrap();
        assert_eq!(stats.get("served").unwrap().as_u64(), Some(1));
        assert_eq!(stats.get("resident_graphs").unwrap().as_u64(), Some(1));
        let ack = c.request(r#"{"op":"shutdown"}"#).unwrap();
        assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn unknown_names_and_bad_lines_get_typed_rejects() {
        let server = tiny_server(4, ServeConfig::default());
        let mut c = ServeClient::connect(server.local_addr()).unwrap();
        let resp = c.query(&query("nope", "bfs", 0)).unwrap();
        assert_eq!(
            resp.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("unknown_graph")
        );
        let resp = c.query(&query("er", "quantum", 0)).unwrap();
        assert_eq!(
            resp.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("unknown_algo")
        );
        let resp = c.request("this is not json").unwrap();
        assert_eq!(resp.get("error").unwrap().get("kind").unwrap().as_str(), Some("bad_request"));
        // the connection survives every reject
        let resp = c.query(&query("er", "bfs", 0)).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn draining_daemon_rejects_new_queries() {
        let server = tiny_server(4, ServeConfig::default());
        server.shutdown();
        let mut c = ServeClient::connect(server.local_addr());
        // the accept loop may already be down; if we got in, the reject
        // must be typed
        if let Ok(c) = c.as_mut() {
            if let Ok(resp) = c.query(&query("er", "bfs", 0)) {
                assert_eq!(
                    resp.get("error").unwrap().get("kind").unwrap().as_str(),
                    Some("draining")
                );
            }
        }
        drop(c);
        server.join().unwrap();
    }
}
