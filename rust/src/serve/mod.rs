//! Always-on query service: the `jgraph serve` daemon.
//!
//! The compile-once/run-many lifecycle ([`crate::engine`]) amortizes
//! translation and graph prep across queries *within one process*; this
//! module keeps that process alive. A daemon owns a [`registry`] of
//! named prepared graphs (LRU-bounded residency) and compiled pipelines
//! (compile on first use), admits queries over a line-delimited JSON TCP
//! protocol ([`wire`]), coalesces arrivals into
//! [`run_batch_parallel`] sweeps ([`batcher`]), rations admission per
//! tenant ([`tenant`]) and threads through the global
//! [`WorkerBudget`](crate::sched::WorkerBudget), and accounts tail
//! latency with rolling histograms ([`stats`]).
//!
//! Fault tolerance (ISSUE 10): queries carry optional wall-clock
//! deadlines (`deadline_us` on the wire), execute behind per-query
//! panic-isolation fences
//! ([`run_batch_isolated`](crate::engine::BoundPipeline::run_batch_isolated)),
//! and transient failures retry with deterministic exponential backoff
//! under a per-tenant retry budget. A seeded
//! [`FaultPlan`](crate::sched::FaultPlan) (the `--fault-plan` flag or
//! `$JGRAPH_FAULT_PLAN`) injects panics, transfer errors, slow
//! supersteps, and compile failures for chaos testing — see
//! `docs/serving.md` § "Failure modes and fault injection".
//!
//! The daemon must never die to a poisoned query: this module tree is
//! compiled under `warn(clippy::unwrap_used)`, and shared mutexes are
//! taken through [`lock_recover`], which recovers a poisoned lock
//! instead of cascading the panic (every guarded structure is a
//! counter/queue that stays internally consistent across a poisoning
//! unwind).
//!
//! See `docs/serving.md` for the wire spec and operational semantics,
//! and `examples/serve_demo.rs` for an end-to-end smoke.
//!
//! [`run_batch_parallel`]: crate::engine::BoundPipeline::run_batch_parallel
#![warn(clippy::unwrap_used)]

use std::sync::{Mutex, MutexGuard, PoisonError};

pub mod batcher;
pub mod client;
pub mod registry;
pub mod server;
pub mod stats;
pub mod tenant;
pub mod wire;

pub use batcher::{BatchOutcome, Batcher, BindingKey};
pub use client::ServeClient;
pub use registry::ServeRegistry;
pub use server::{install_termination_handler, termination_requested, ServeConfig, Server};
pub use stats::{LatencyHistogram, ServeStats};
pub use tenant::{TenantPermit, TenantTable};
pub use wire::{QueryRequest, RejectKind, Request};

/// Take a shared mutex, recovering from poison instead of propagating
/// the panic: a worker that unwound while holding a stats histogram or
/// the batcher queue must not take the whole daemon down with it. Every
/// structure guarded this way is update-atomic (counters, maps, vecs),
/// so the recovered state is consistent — at worst one sample short.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
