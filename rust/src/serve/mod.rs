//! Always-on query service: the `jgraph serve` daemon.
//!
//! The compile-once/run-many lifecycle ([`crate::engine`]) amortizes
//! translation and graph prep across queries *within one process*; this
//! module keeps that process alive. A daemon owns a [`registry`] of
//! named prepared graphs (LRU-bounded residency) and compiled pipelines
//! (compile on first use), admits queries over a line-delimited JSON TCP
//! protocol ([`wire`]), coalesces arrivals into
//! [`run_batch_parallel`] sweeps ([`batcher`]), rations admission per
//! tenant ([`tenant`]) and threads through the global
//! [`WorkerBudget`](crate::sched::WorkerBudget), and accounts tail
//! latency with rolling histograms ([`stats`]).
//!
//! See `docs/serving.md` for the wire spec and operational semantics,
//! and `examples/serve_demo.rs` for an end-to-end smoke.
//!
//! [`run_batch_parallel`]: crate::engine::BoundPipeline::run_batch_parallel

pub mod batcher;
pub mod client;
pub mod registry;
pub mod server;
pub mod stats;
pub mod tenant;
pub mod wire;

pub use batcher::{BatchOutcome, Batcher, BindingKey};
pub use client::ServeClient;
pub use registry::ServeRegistry;
pub use server::{install_termination_handler, termination_requested, ServeConfig, Server};
pub use stats::{LatencyHistogram, ServeStats};
pub use tenant::{TenantPermit, TenantTable};
pub use wire::{QueryRequest, RejectKind, Request};
