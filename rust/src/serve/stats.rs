//! Tail-latency accounting for the serve daemon: rolling histograms of
//! queue / service / total time per query, plus batch-occupancy and
//! error counters. Everything is process-lifetime (no windowing) and
//! cheap enough to record on every request; the `stats` wire op renders
//! a snapshot.
//!
//! The histogram is HDR-style: log2 octaves of microseconds, 16
//! sub-buckets per octave, so quantiles are exact below 16 µs and within
//! 1/16 (≤ 6.25 %) relative error above — plenty for p50/p95/p99 over a
//! latency range spanning microsecond cache hits to multi-second cold
//! graph preps, in a fixed 1 KiB of counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::lock_recover;
use super::wire::Json;

/// Octaves of microseconds covered (2^0 .. 2^63 µs — saturates far past
/// any real latency).
const OCTAVES: usize = 64;
/// Sub-buckets per octave (relative error ≤ 1/SUBS above 16 µs).
const SUBS: usize = 16;

/// A log2-bucketed latency histogram over microseconds.
pub struct LatencyHistogram {
    inner: Mutex<Buckets>,
}

struct Buckets {
    counts: Vec<u64>,
    total: u64,
    sum_us: u128,
    max_us: u64,
}

/// Bucket index for a microsecond value: exact below 16 µs, then
/// 16 sub-buckets per power of two.
fn bucket_of(us: u64) -> usize {
    if us < SUBS as u64 {
        return us as usize;
    }
    let msb = 63 - us.leading_zeros() as usize;
    SUBS * (msb - 3) + ((us >> (msb - 4)) as usize - SUBS)
}

/// Lower bound (µs) of a bucket — what quantile queries report.
fn bucket_floor(bucket: usize) -> u64 {
    if bucket < SUBS {
        return bucket as u64;
    }
    let msb = bucket / SUBS + 3;
    let sub = bucket % SUBS;
    (1u64 << msb) + ((sub as u64) << (msb - 4))
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            inner: Mutex::new(Buckets {
                counts: vec![0; SUBS * (OCTAVES - 3)],
                total: 0,
                sum_us: 0,
                max_us: 0,
            }),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, latency: Duration) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        let mut b = lock_recover(&self.inner);
        let idx = bucket_of(us).min(b.counts.len() - 1);
        b.counts[idx] += 1;
        b.total += 1;
        b.sum_us += us as u128;
        b.max_us = b.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        lock_recover(&self.inner).total
    }

    /// The `p`-th percentile (0 < p ≤ 100) in microseconds: the lower
    /// bound of the bucket holding the p-th sample. `None` when empty.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        let b = lock_recover(&self.inner);
        if b.total == 0 {
            return None;
        }
        let rank = ((p / 100.0) * b.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (idx, &c) in b.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(bucket_floor(idx));
            }
        }
        Some(b.max_us)
    }

    /// Mean latency in microseconds (`None` when empty).
    pub fn mean_us(&self) -> Option<f64> {
        let b = lock_recover(&self.inner);
        if b.total == 0 {
            None
        } else {
            Some(b.sum_us as f64 / b.total as f64)
        }
    }

    /// Largest sample in microseconds.
    pub fn max_us(&self) -> u64 {
        lock_recover(&self.inner).max_us
    }

    /// Histogram summary as a wire JSON object.
    fn to_json(&self) -> Json {
        let opt = |v: Option<u64>| match v {
            Some(us) => Json::Num(us as f64),
            None => Json::Null,
        };
        Json::Obj(vec![
            ("count".into(), Json::Num(self.count() as f64)),
            ("p50_us".into(), opt(self.percentile_us(50.0))),
            ("p95_us".into(), opt(self.percentile_us(95.0))),
            ("p99_us".into(), opt(self.percentile_us(99.0))),
            ("mean_us".into(), self.mean_us().map(Json::Num).unwrap_or(Json::Null)),
            ("max_us".into(), Json::Num(self.max_us() as f64)),
        ])
    }
}

/// All rolling serve-side accounting, shared by the batcher and the
/// connection handlers.
#[derive(Default)]
pub struct ServeStats {
    /// Admission → batch-dispatch wait per query.
    pub queue: LatencyHistogram,
    /// Batch execution time attributed to each query in the batch.
    pub service: LatencyHistogram,
    /// Admission → response-ready, per query.
    pub total: LatencyHistogram,
    /// Sweeps dispatched.
    pub batches: AtomicU64,
    /// Queries that went through a sweep (Σ batch sizes).
    pub batched_queries: AtomicU64,
    /// Largest single sweep.
    pub max_batch: AtomicU64,
    /// Queries answered `ok:true`.
    pub served: AtomicU64,
    /// Queries answered with an execution error (post-admission).
    pub errors: AtomicU64,
    /// Queries that ran out of their wall-clock budget (a subset of
    /// `errors`, answered with a `deadline_exceeded` reject).
    pub deadline_exceeded: AtomicU64,
    /// Transient failures re-run with backoff.
    pub retries_attempted: AtomicU64,
    /// Transient failures that exhausted the retry limit or their
    /// tenant's retry budget and were answered with the failure.
    pub retries_exhausted: AtomicU64,
    /// Panics caught by an isolation fence (injected or organic) —
    /// each one a query that died without taking the daemon with it.
    pub panics_caught: AtomicU64,
    /// Faults injected by the active fault plan (a gauge mirrored from
    /// [`FaultPlan::injected_total`](crate::sched::FaultPlan::injected_total)
    /// at snapshot time; 0 when no plan is loaded).
    pub faults_injected: AtomicU64,
}

impl ServeStats {
    /// Record one dispatched sweep of `size` queries.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(size as u64, Ordering::Relaxed);
    }

    /// Mean queries per sweep (0.0 before the first sweep).
    pub fn mean_batch_occupancy(&self) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        self.batched_queries.load(Ordering::Relaxed) as f64 / batches as f64
    }

    /// The `stats` response body (everything except registry/tenant
    /// fields, which the server layers in).
    pub fn to_json_fields(&self) -> Vec<(String, Json)> {
        let counter = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        vec![
            ("served".into(), counter(&self.served)),
            ("errors".into(), counter(&self.errors)),
            ("deadline_exceeded".into(), counter(&self.deadline_exceeded)),
            ("retries_attempted".into(), counter(&self.retries_attempted)),
            ("retries_exhausted".into(), counter(&self.retries_exhausted)),
            ("panics_caught".into(), counter(&self.panics_caught)),
            ("faults_injected".into(), counter(&self.faults_injected)),
            ("batches".into(), Json::Num(self.batches.load(Ordering::Relaxed) as f64)),
            ("mean_batch_occupancy".into(), Json::Num(self.mean_batch_occupancy())),
            ("max_batch".into(), Json::Num(self.max_batch.load(Ordering::Relaxed) as f64)),
            ("queue".into(), self.queue.to_json()),
            ("service".into(), self.service.to_json()),
            ("total".into(), self.total.to_json()),
        ]
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let h = LatencyHistogram::new();
        for us in [0u64, 1, 5, 15] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.percentile_us(25.0), Some(0));
        assert_eq!(h.percentile_us(100.0), Some(15));
        assert_eq!(h.max_us(), 15);
    }

    #[test]
    fn buckets_are_monotone_and_bounded_error() {
        let mut prev = 0usize;
        for us in 1..100_000u64 {
            let b = bucket_of(us);
            assert!(b >= prev, "bucket_of must be monotone at {us}");
            prev = b;
            let floor = bucket_floor(b);
            assert!(floor <= us, "floor {floor} > {us}");
            // relative error of the reported lower bound is ≤ 1/16
            assert!((us - floor) as f64 <= us as f64 / 16.0 + 1.0, "{us} -> {floor}");
        }
    }

    #[test]
    fn percentiles_rank_correctly() {
        let h = LatencyHistogram::new();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let p50 = h.percentile_us(50.0).unwrap();
        let p99 = h.percentile_us(99.0).unwrap();
        assert!((450..=500).contains(&p50), "p50 {p50}");
        assert!((920..=990).contains(&p99), "p99 {p99}");
        assert!(p50 < p99);
        // p100 lands in the top occupied bucket, whose floor is ≤ max
        assert!(h.percentile_us(100.0).unwrap() <= h.max_us());
        let mean = h.mean_us().unwrap();
        assert!((495.0..=506.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn empty_histogram_reports_none() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(50.0), None);
        assert_eq!(h.mean_us(), None);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn stats_track_batch_occupancy() {
        let s = ServeStats::default();
        s.record_batch(4);
        s.record_batch(8);
        assert_eq!(s.mean_batch_occupancy(), 6.0);
        assert_eq!(s.max_batch.load(Ordering::Relaxed), 8);
        let fields = s.to_json_fields();
        assert!(fields.iter().any(|(k, _)| k == "mean_batch_occupancy"));
    }

    /// Pins the `stats` counter schema (ISSUE 10 satellite): the exact
    /// key list, in order, including the five fault-tolerance counters —
    /// a renamed or dropped counter is a wire-protocol break, not a
    /// refactor.
    #[test]
    fn fault_tolerance_counters_pin_the_stats_schema() {
        let s = ServeStats::default();
        s.deadline_exceeded.fetch_add(2, Ordering::Relaxed);
        s.retries_attempted.fetch_add(3, Ordering::Relaxed);
        s.retries_exhausted.fetch_add(1, Ordering::Relaxed);
        s.panics_caught.fetch_add(4, Ordering::Relaxed);
        s.faults_injected.store(9, Ordering::Relaxed);
        let fields = s.to_json_fields();
        let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "served",
                "errors",
                "deadline_exceeded",
                "retries_attempted",
                "retries_exhausted",
                "panics_caught",
                "faults_injected",
                "batches",
                "mean_batch_occupancy",
                "max_batch",
                "queue",
                "service",
                "total",
            ],
            "the stats schema is pinned — additions go at a deliberate spot, renames are breaks"
        );
        let num = |name: &str| {
            fields
                .iter()
                .find(|(k, _)| k == name)
                .and_then(|(_, v)| v.as_u64())
                .unwrap_or_else(|| panic!("{name} must render as a number"))
        };
        assert_eq!(num("deadline_exceeded"), 2);
        assert_eq!(num("retries_attempted"), 3);
        assert_eq!(num("retries_exhausted"), 1);
        assert_eq!(num("panics_caught"), 4);
        assert_eq!(num("faults_injected"), 9);
    }
}
