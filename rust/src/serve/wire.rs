//! Line-delimited JSON wire format for `jgraph serve`. Hand-rolled — the
//! build is hermetic (no serde): [`Json`] is a minimal value type with a
//! recursive-descent parser and a compact renderer, and the typed
//! [`Request`]/reject layer on top is the protocol `docs/serving.md`
//! specifies.
//!
//! One request per line, one response line per request, in request order
//! per connection. Finite numbers render via Rust's shortest-round-trip
//! `Display`, so an `f64` survives encode → parse bit-identically — the
//! property the serve integration test leans on to compare wire reports
//! against direct [`run_batch_parallel`] runs.
//!
//! [`run_batch_parallel`]: crate::engine::BoundPipeline::run_batch_parallel

use std::fmt;

use crate::engine::DirectionPolicy;

/// A parsed JSON value. Object fields keep arrival order (no map): the
/// wire layer only ever looks fields up by name.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, WireError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let value = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after the document"));
        }
        Ok(value)
    }

    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact single-line rendering (valid JSON; non-finite numbers
    /// become quoted `"inf"`/`"-inf"`/`"nan"` strings — JSON has no
    /// spelling for them, and `bound_params` can carry `+inf` defaults).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => out.push_str(&render_num(*v)),
            Json::Str(s) => render_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Render one number: shortest-round-trip decimal for finite values,
/// quoted strings for the values JSON cannot spell.
fn render_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "\"nan\"".into()
    } else if v > 0.0 {
        "\"inf\"".into()
    } else {
        "\"-inf\"".into()
    }
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Wire-level decode error: byte position plus message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub pos: usize,
    pub message: String,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire decode error at byte {}: {}", self.pos, self.message)
    }
}

impl std::error::Error for WireError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, message: &str) -> WireError {
        WireError { pos: self.pos, message: message.to_string() }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), WireError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, WireError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn value(&mut self) -> Result<Json, WireError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(&format!("unexpected character {:?}", b as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, WireError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, WireError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, WireError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let c = self.unicode_escape()?;
                            out.push(c);
                            // hex digits are consumed; undo the generic
                            // advance below
                            self.pos -= 1;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x20 => return Err(self.err("unescaped control character")),
                Some(b) if b < 0x80 => {
                    out.push(b as char);
                    self.pos += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8: the input is a &str, so the
                    // sequence is valid — copy it wholesale
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let Some(c) = rest.chars().next() else {
                        return Err(self.err("invalid utf-8"));
                    };
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Decode a `\u` escape starting at its first hex digit, surrogate
    /// pairs included; leaves `pos` just past the last digit consumed.
    fn unicode_escape(&mut self) -> Result<char, WireError> {
        let code = self.hex4()?;
        if (0xD800..0xDC00).contains(&code) {
            if !self.bytes[self.pos..].starts_with(b"\\u") {
                return Err(self.err("lone high surrogate"));
            }
            self.pos += 2;
            let low = self.hex4()?;
            if !(0xDC00..0xE000).contains(&low) {
                return Err(self.err("invalid low surrogate"));
            }
            let combined = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
            return char::from_u32(combined).ok_or_else(|| self.err("invalid \\u escape"));
        }
        char::from_u32(code).ok_or_else(|| self.err("invalid \\u escape"))
    }

    fn hex4(&mut self) -> Result<u32, WireError> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let code = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos = end;
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, WireError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            if matches!(b, b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| WireError { pos: start, message: format!("invalid number {text:?}") })
    }
}

/// Typed reject reasons a request can earn without ever executing.
/// `code()` is the stable wire spelling (`error.kind` in the response).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectKind {
    /// The request line was not valid JSON / not a known op shape.
    BadRequest,
    /// No graph registered under the requested name.
    UnknownGraph,
    /// No algorithm with the requested name.
    UnknownAlgo,
    /// The algorithm failed to compile (typed [`CompileError`] text).
    ///
    /// [`CompileError`]: crate::engine::CompileError
    CompileFailed,
    /// The tenant is at its concurrency cap.
    TenantOverCap,
    /// The daemon is draining; no new queries are admitted.
    Draining,
    /// The query ran and failed (engine error; retries, if any, are
    /// already spent).
    ExecFailed,
    /// The query's `deadline_us` budget expired before it finished.
    DeadlineExceeded,
    /// A shard worker panicked mid-query; the query failed typed while
    /// its sweep siblings were untouched.
    WorkerPanicked,
}

impl RejectKind {
    pub fn code(&self) -> &'static str {
        match self {
            RejectKind::BadRequest => "bad_request",
            RejectKind::UnknownGraph => "unknown_graph",
            RejectKind::UnknownAlgo => "unknown_algo",
            RejectKind::CompileFailed => "compile_failed",
            RejectKind::TenantOverCap => "tenant_over_cap",
            RejectKind::Draining => "draining",
            RejectKind::ExecFailed => "exec_failed",
            RejectKind::DeadlineExceeded => "deadline_exceeded",
            RejectKind::WorkerPanicked => "worker_panicked",
        }
    }
}

/// Tenant name used when a query omits the `tenant` field.
pub const DEFAULT_TENANT: &str = "default";

/// One query as it arrives on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryRequest {
    pub graph: String,
    pub algo: String,
    pub root: u32,
    /// Runtime parameter bindings (`params` object: name → number).
    pub params: Vec<(String, f64)>,
    /// `"adaptive"` (default) | `"push"` | `"pull"`.
    pub direction: Option<DirectionPolicy>,
    pub tenant: String,
    pub max_supersteps: Option<u32>,
    /// Wall-clock budget in microseconds; expiry earns a typed
    /// `deadline_exceeded` reject with partial accounting. `None` = no
    /// deadline.
    pub deadline_us: Option<u64>,
}

impl QueryRequest {
    /// Render this query as one request line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut fields = vec![
            ("op".to_string(), Json::Str("query".into())),
            ("graph".to_string(), Json::Str(self.graph.clone())),
            ("algo".to_string(), Json::Str(self.algo.clone())),
            ("root".to_string(), Json::Num(self.root as f64)),
        ];
        if !self.params.is_empty() {
            let obj =
                self.params.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
            fields.push(("params".to_string(), Json::Obj(obj)));
        }
        if let Some(d) = self.direction {
            let name = match d {
                DirectionPolicy::PushOnly => "push",
                DirectionPolicy::Adaptive => "adaptive",
                DirectionPolicy::ForcePull => "pull",
            };
            fields.push(("direction".to_string(), Json::Str(name.into())));
        }
        if self.tenant != DEFAULT_TENANT {
            fields.push(("tenant".to_string(), Json::Str(self.tenant.clone())));
        }
        if let Some(cap) = self.max_supersteps {
            fields.push(("max_supersteps".to_string(), Json::Num(cap as f64)));
        }
        if let Some(us) = self.deadline_us {
            fields.push(("deadline_us".to_string(), Json::Num(us as f64)));
        }
        Json::Obj(fields).render()
    }
}

/// Every request shape the daemon accepts.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Query(Box<QueryRequest>),
    /// Rolling latency/occupancy/eviction counters.
    Stats,
    /// Liveness probe.
    Ping,
    /// Initiate graceful drain: queued queries finish, then the daemon
    /// exits. Equivalent to SIGTERM.
    Shutdown,
}

impl Request {
    /// Decode one request line. Errors are [`RejectKind::BadRequest`]
    /// material — the server answers them without dropping the
    /// connection.
    pub fn decode(line: &str) -> Result<Request, String> {
        let doc = Json::parse(line).map_err(|e| e.to_string())?;
        let op = doc.get("op").and_then(Json::as_str).unwrap_or("query");
        match op {
            "stats" => Ok(Request::Stats),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            "query" => {
                let graph = doc
                    .get("graph")
                    .and_then(Json::as_str)
                    .ok_or("query needs a \"graph\" string")?
                    .to_string();
                let algo = doc
                    .get("algo")
                    .and_then(Json::as_str)
                    .ok_or("query needs an \"algo\" string")?
                    .to_string();
                let root = match doc.get("root") {
                    None => 0,
                    Some(v) => v
                        .as_u64()
                        .filter(|&r| r <= u32::MAX as u64)
                        .ok_or("\"root\" must be a u32")? as u32,
                };
                let mut params = Vec::new();
                if let Some(p) = doc.get("params") {
                    let Json::Obj(fields) = p else {
                        return Err("\"params\" must be an object".into());
                    };
                    for (name, value) in fields {
                        let v = value
                            .as_f64()
                            .ok_or_else(|| format!("param {name:?} must be a number"))?;
                        params.push((name.clone(), v));
                    }
                }
                let direction = match doc.get("direction").and_then(Json::as_str) {
                    None => None,
                    Some("adaptive") => Some(DirectionPolicy::Adaptive),
                    Some("push") => Some(DirectionPolicy::PushOnly),
                    Some("pull") => Some(DirectionPolicy::ForcePull),
                    Some(other) => {
                        return Err(format!(
                            "unknown direction {other:?} (adaptive|push|pull)"
                        ))
                    }
                };
                let tenant = doc
                    .get("tenant")
                    .and_then(Json::as_str)
                    .unwrap_or(DEFAULT_TENANT)
                    .to_string();
                let max_supersteps = match doc.get("max_supersteps") {
                    None => None,
                    Some(v) => Some(
                        v.as_u64()
                            .filter(|&c| c <= u32::MAX as u64)
                            .ok_or("\"max_supersteps\" must be a u32")?
                            as u32,
                    ),
                };
                let deadline_us = match doc.get("deadline_us") {
                    None => None,
                    Some(v) => Some(v.as_u64().ok_or("\"deadline_us\" must be a u64")?),
                };
                Ok(Request::Query(Box::new(QueryRequest {
                    graph,
                    algo,
                    root,
                    params,
                    direction,
                    tenant,
                    max_supersteps,
                    deadline_us,
                })))
            }
            other => Err(format!("unknown op {other:?} (query|stats|ping|shutdown)")),
        }
    }
}

/// Encode a typed reject/error response line.
pub fn encode_error(kind: &RejectKind, message: &str) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(false)),
        (
            "error".to_string(),
            Json::Obj(vec![
                ("kind".to_string(), Json::Str(kind.code().into())),
                ("message".to_string(), Json::Str(message.into())),
            ]),
        ),
    ])
    .render()
}

/// Encode a plain acknowledgement (`ping`/`shutdown`).
pub fn encode_ack(op: &str) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str(op.into())),
    ])
    .render()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_values() {
        let text = r#"{"op":"query","graph":"email","root":7,"params":{"damping":0.85},
                       "flags":[true,false,null],"note":"a\"b\\c\nd"}"#;
        let doc = Json::parse(text).unwrap();
        assert_eq!(doc.get("graph").unwrap().as_str(), Some("email"));
        assert_eq!(doc.get("root").unwrap().as_u64(), Some(7));
        assert_eq!(
            doc.get("params").unwrap().get("damping").unwrap().as_f64(),
            Some(0.85)
        );
        assert_eq!(doc.get("note").unwrap().as_str(), Some("a\"b\\c\nd"));
        // render → parse is the identity on the value
        let again = Json::parse(&doc.render()).unwrap();
        assert_eq!(doc, again);
    }

    #[test]
    fn finite_f64_survives_encode_parse_bit_identically() {
        for v in [0.85, 1.0 / 3.0, 2.2250738585072014e-308, 1.7e308, -0.0, 123456.789] {
            let line = Json::Num(v).render();
            let back = Json::parse(&line).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v} -> {line}");
        }
    }

    #[test]
    fn non_finite_numbers_render_as_strings() {
        assert_eq!(Json::Num(f64::INFINITY).render(), "\"inf\"");
        assert_eq!(Json::Num(f64::NEG_INFINITY).render(), "\"-inf\"");
        assert_eq!(Json::Num(f64::NAN).render(), "\"nan\"");
    }

    #[test]
    fn unicode_escapes_decode() {
        let doc = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(doc.as_str(), Some("é😀"));
    }

    #[test]
    fn malformed_documents_error_with_position() {
        for bad in ["{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1 2", "{\"a\" 1}"] {
            let err = Json::parse(bad).unwrap_err();
            assert!(!err.message.is_empty(), "{bad}");
        }
    }

    #[test]
    fn query_request_decodes_with_defaults() {
        let req = Request::decode(r#"{"op":"query","graph":"email","algo":"bfs"}"#).unwrap();
        let Request::Query(q) = req else { panic!("expected query") };
        assert_eq!(q.root, 0);
        assert_eq!(q.tenant, DEFAULT_TENANT);
        assert!(q.params.is_empty());
        assert_eq!(q.direction, None);
        assert_eq!(q.max_supersteps, None);
        assert_eq!(q.deadline_us, None);
    }

    #[test]
    fn query_request_encode_decode_round_trips() {
        let q = QueryRequest {
            graph: "grid".into(),
            algo: "pagerank".into(),
            root: 12,
            params: vec![("damping".into(), 0.9), ("tolerance".into(), 1e-4)],
            direction: Some(DirectionPolicy::PushOnly),
            tenant: "alice".into(),
            max_supersteps: Some(64),
            deadline_us: Some(250_000),
        };
        let Request::Query(back) = Request::decode(&q.encode()).unwrap() else {
            panic!("expected query");
        };
        assert_eq!(*back, q);
    }

    #[test]
    fn control_ops_decode() {
        assert_eq!(Request::decode(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(Request::decode(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(Request::decode(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown);
        assert!(Request::decode(r#"{"op":"reboot"}"#).is_err());
        assert!(Request::decode("not json").is_err());
    }

    #[test]
    fn reject_kinds_have_stable_codes() {
        assert_eq!(RejectKind::TenantOverCap.code(), "tenant_over_cap");
        assert_eq!(RejectKind::ExecFailed.code(), "exec_failed");
        assert_eq!(RejectKind::DeadlineExceeded.code(), "deadline_exceeded");
        assert_eq!(RejectKind::WorkerPanicked.code(), "worker_panicked");
        let line = encode_error(&RejectKind::TenantOverCap, "tenant \"t\" at cap 2");
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            doc.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("tenant_over_cap")
        );
    }
}
