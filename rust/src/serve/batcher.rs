//! Arrival batching: queries landing within one batch window coalesce
//! into a single [`run_batch_parallel`] sweep per (graph, algorithm)
//! binding, so a burst of BFS roots on the same graph pays one bind +
//! one worker-pool lease instead of N. The window bounds added latency:
//! a query waits at most `window` before its sweep dispatches (and not
//! at all once the daemon is draining).
//!
//! The batcher owns only queueing and readiness; execution stays in the
//! server (which holds the registry). A dispatcher thread loops on
//! [`Batcher::next_ready`], which blocks until some binding's window has
//! elapsed and hands back the whole queue for that binding.
//!
//! [`run_batch_parallel`]: crate::engine::BoundPipeline::run_batch_parallel

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::engine::{QueryFailure, RunOptions, RunReport};

use super::lock_recover;
use super::tenant::TenantPermit;

/// The coalescing key: queries agreeing on both fields run in one sweep.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BindingKey {
    pub graph: String,
    pub algo: String,
}

/// What a query's connection gets back from its sweep.
pub struct BatchOutcome {
    /// The engine's report, or the query's own typed failure — one
    /// poisoned query in a sweep fails alone (per-query isolation
    /// fences), and the writer maps the failure kind to its wire reject.
    pub result: Result<RunReport, QueryFailure>,
    /// Admission → sweep dispatch.
    pub queue: Duration,
    /// Sweep dispatch → sweep done (batch-level: shared by the batch).
    pub service: Duration,
    /// Queries in the sweep this one rode in.
    pub batch_size: usize,
}

/// One admitted query waiting for its sweep.
pub struct Pending {
    pub opts: RunOptions,
    /// The tenant this query was admitted under — the dispatcher charges
    /// retries to this tenant's budget.
    pub tenant: String,
    /// Held from admission until the response is written; dropping it
    /// (after the reply sends) frees the tenant's slot.
    pub permit: TenantPermit,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<BatchOutcome>,
}

struct QueueEntry {
    /// When the oldest waiting query arrived — the window anchors here.
    since: Instant,
    items: Vec<Pending>,
}

struct State {
    queues: HashMap<BindingKey, QueueEntry>,
    draining: bool,
}

/// The arrival batcher. `submit` never blocks; `next_ready` blocks the
/// dispatcher until a batch is due.
pub struct Batcher {
    state: Mutex<State>,
    cv: Condvar,
    window: Duration,
}

impl Batcher {
    pub fn new(window: Duration) -> Self {
        Batcher {
            state: Mutex::new(State { queues: HashMap::new(), draining: false }),
            cv: Condvar::new(),
            window,
        }
    }

    /// The configured batch window.
    pub fn window(&self) -> Duration {
        self.window
    }

    /// Queue one admitted query. `Err` hands the query back when the
    /// daemon is draining (the caller answers with a typed reject).
    pub fn submit(&self, key: BindingKey, pending: Pending) -> Result<(), Pending> {
        let mut state = lock_recover(&self.state);
        if state.draining {
            return Err(pending);
        }
        let now = Instant::now();
        state
            .queues
            .entry(key)
            .or_insert_with(|| QueueEntry { since: now, items: Vec::new() })
            .items
            .push(pending);
        self.cv.notify_all();
        Ok(())
    }

    /// Stop admitting; queued queries still dispatch (immediately, the
    /// window no longer applies). After the last queue empties,
    /// [`Self::next_ready`] returns `None` and the dispatcher exits.
    pub fn drain(&self) {
        lock_recover(&self.state).draining = true;
        self.cv.notify_all();
    }

    pub fn is_draining(&self) -> bool {
        lock_recover(&self.state).draining
    }

    /// Block until one binding's batch is due, then hand its whole queue
    /// over. `None` means drained and empty: the dispatcher's exit.
    pub fn next_ready(&self) -> Option<(BindingKey, Vec<Pending>)> {
        let mut state = lock_recover(&self.state);
        loop {
            let now = Instant::now();
            let draining = state.draining;
            let due = state
                .queues
                .iter()
                .filter(|(_, q)| !q.items.is_empty())
                .filter(|(_, q)| draining || now.duration_since(q.since) >= self.window)
                .min_by_key(|(_, q)| q.since)
                .map(|(k, _)| k.clone());
            if let Some(key) = due {
                let entry = state.queues.remove(&key).expect("due key is present");
                return Some((key, entry.items));
            }
            let earliest =
                state.queues.values().filter(|q| !q.items.is_empty()).map(|q| q.since).min();
            match earliest {
                Some(since) => {
                    let timeout = (since + self.window).saturating_duration_since(now);
                    state = self
                        .cv
                        .wait_timeout(state, timeout)
                        .unwrap_or_else(PoisonError::into_inner)
                        .0;
                }
                None if draining => return None,
                None => {
                    state = self.cv.wait(state).unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::serve::tenant::TenantTable;

    fn pending(table: &TenantTable) -> (Pending, mpsc::Receiver<BatchOutcome>) {
        let (tx, rx) = mpsc::channel();
        let p = Pending {
            opts: RunOptions::default(),
            tenant: "test".into(),
            permit: table.admit("test").unwrap(),
            enqueued: Instant::now(),
            reply: tx,
        };
        (p, rx)
    }

    fn key(graph: &str, algo: &str) -> BindingKey {
        BindingKey { graph: graph.into(), algo: algo.into() }
    }

    #[test]
    fn arrivals_within_the_window_coalesce_into_one_batch() {
        let table = TenantTable::new(16, &[]);
        let b = Batcher::new(Duration::from_millis(40));
        for _ in 0..3 {
            let (p, _rx) = pending(&table);
            b.submit(key("g", "bfs"), p).unwrap();
        }
        let t0 = Instant::now();
        let (k, items) = b.next_ready().unwrap();
        assert_eq!(k, key("g", "bfs"));
        assert_eq!(items.len(), 3, "one sweep for the burst");
        assert!(t0.elapsed() >= Duration::from_millis(20), "the window applied");
    }

    #[test]
    fn different_bindings_batch_separately() {
        let table = TenantTable::new(16, &[]);
        let b = Batcher::new(Duration::from_millis(5));
        let (p, _r1) = pending(&table);
        b.submit(key("g", "bfs"), p).unwrap();
        let (p, _r2) = pending(&table);
        b.submit(key("g", "pagerank"), p).unwrap();
        let (_, first) = b.next_ready().unwrap();
        let (_, second) = b.next_ready().unwrap();
        assert_eq!(first.len(), 1);
        assert_eq!(second.len(), 1);
    }

    #[test]
    fn draining_rejects_new_work_and_flushes_the_queue() {
        let table = TenantTable::new(16, &[]);
        // a window long enough that only drain can flush it in test time
        let b = Batcher::new(Duration::from_secs(600));
        let (p, _r1) = pending(&table);
        b.submit(key("g", "bfs"), p).unwrap();
        let (p, _r2) = pending(&table);
        b.submit(key("g", "bfs"), p).unwrap();
        b.drain();
        assert!(b.is_draining());
        let (p, _r3) = pending(&table);
        assert!(b.submit(key("g", "bfs"), p).is_err(), "draining admits nothing");
        let (_, items) = b.next_ready().unwrap();
        assert_eq!(items.len(), 2, "queued work still dispatches on drain");
        assert!(b.next_ready().is_none(), "drained and empty ends the dispatcher");
    }
}
