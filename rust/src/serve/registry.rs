//! The daemon's registry of named graphs and compiled pipelines —
//! everything `jgraph serve` owns that outlives a single request.
//!
//! Graphs register as *sources* (a [`catalog`](crate::graph::catalog)
//! spec or an in-memory edge list) and are prepared on first use; the
//! resident [`PreparedGraph`] set is bounded by an LRU cap, so a daemon
//! serving many graphs holds at most `max_resident` CSR/CSC/shard cache
//! sets at once. Eviction only drops the registry's `Arc` — queries in
//! flight keep their graph alive, and the next query on an evicted name
//! reloads it transparently (paying `prep_seconds` again, visible in its
//! reports).
//!
//! Pipelines compile on first use per algorithm name and are never
//! evicted (a [`CompiledPipeline`] is a few kilobytes of design + program
//! — the memory that matters is the graphs). This is the serving-layer
//! analogue of the AOT artifact cache in
//! [`crate::runtime::registry::KernelRegistry`]: same
//! compile-on-first-use discipline, one level up the stack.
//!
//! Concurrency: first touches of the same name race on a per-slot
//! [`OnceLock`], so the prep runs exactly once and both callers share
//! one `Arc` (asserted by the serve integration tests). The expensive
//! build happens outside the registry locks.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::dsl::program::GasProgram;
use crate::engine::{CompiledPipeline, Session};
use crate::graph::catalog;
use crate::graph::edgelist::EdgeList;
use crate::prep::prepared::{PrepOptions, PreparedGraph};

use super::lock_recover;

/// Where a registered graph's edges come from when it must be
/// (re)prepared.
#[derive(Clone)]
pub enum GraphSource {
    /// A [`catalog::load_spec`] spec (preset name or file path).
    Spec { spec: String, seed: u64 },
    /// An in-memory edge list (tests, embedders).
    Edges(Arc<EdgeList>),
}

/// One resident graph: the source it rebuilds from plus the
/// once-per-residency prepared form. Two threads racing on the first
/// touch share the `OnceLock` build.
struct GraphSlot {
    name: String,
    source: GraphSource,
    prep: OnceLock<Result<Arc<PreparedGraph>, String>>,
}

impl GraphSlot {
    fn prepare(&self) -> Result<Arc<PreparedGraph>, String> {
        self.prep
            .get_or_init(|| {
                let built = match &self.source {
                    GraphSource::Spec { spec, seed } => {
                        let (_, el) = catalog::load_spec(spec, *seed)
                            .map_err(|e| format!("loading graph {:?}: {e:#}", self.name))?;
                        PreparedGraph::prepare(&el, &PrepOptions::named(self.name.clone()))
                    }
                    GraphSource::Edges(el) => {
                        PreparedGraph::prepare(el, &PrepOptions::named(self.name.clone()))
                    }
                };
                built
                    .map(Arc::new)
                    .map_err(|e| format!("preparing graph {:?}: {e:#}", self.name))
            })
            .clone()
    }
}

/// LRU-ordered resident set: `order` front = least recently used.
#[derive(Default)]
struct Resident {
    slots: HashMap<String, Arc<GraphSlot>>,
    order: Vec<String>,
}

impl Resident {
    fn touch(&mut self, name: &str) {
        self.order.retain(|n| n != name);
        self.order.push(name.to_string());
    }
}

/// The registry. All methods take `&self`; every lock is internal and
/// never held across a prepare/compile.
pub struct ServeRegistry {
    session: Mutex<Session>,
    sources: Mutex<HashMap<String, GraphSource>>,
    resident: Mutex<Resident>,
    pipelines: Mutex<HashMap<String, Arc<CompiledPipeline>>>,
    max_resident: usize,
    evictions: AtomicU64,
}

impl ServeRegistry {
    /// A registry compiling through `session`, holding at most
    /// `max_resident` prepared graphs (clamped ≥ 1).
    pub fn new(session: Session, max_resident: usize) -> Self {
        ServeRegistry {
            session: Mutex::new(session),
            sources: Mutex::new(HashMap::new()),
            resident: Mutex::new(Resident::default()),
            pipelines: Mutex::new(HashMap::new()),
            max_resident: max_resident.max(1),
            evictions: AtomicU64::new(0),
        }
    }

    /// Register `name` to resolve through the graph catalog (preset or
    /// path), deterministically under `seed`. Re-registering replaces
    /// the source but not an already-resident prep.
    pub fn register_spec(&self, name: impl Into<String>, spec: impl Into<String>, seed: u64) {
        let source = GraphSource::Spec { spec: spec.into(), seed };
        lock_recover(&self.sources).insert(name.into(), source);
    }

    /// Register `name` with in-memory edges.
    pub fn register_edges(&self, name: impl Into<String>, edges: EdgeList) {
        let source = GraphSource::Edges(Arc::new(edges));
        lock_recover(&self.sources).insert(name.into(), source);
    }

    /// Whether `name` has a registered source (resident or not).
    pub fn is_registered(&self, name: &str) -> bool {
        lock_recover(&self.sources).contains_key(name)
    }

    /// Registered graph names, sorted.
    pub fn graph_names(&self) -> Vec<String> {
        let mut names: Vec<String> = lock_recover(&self.sources).keys().cloned().collect();
        names.sort();
        names
    }

    /// Resident (prepared) graph names in LRU order, least recent first.
    pub fn resident_names(&self) -> Vec<String> {
        lock_recover(&self.resident).order.clone()
    }

    /// Resident prepared-graph count (always ≤ the configured cap).
    pub fn resident_count(&self) -> usize {
        lock_recover(&self.resident).slots.len()
    }

    /// Graphs evicted over the registry's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// The configured resident cap.
    pub fn max_resident(&self) -> usize {
        self.max_resident
    }

    /// Get (preparing on first use) the named graph. `Err(None)` means
    /// the name is unregistered; `Err(Some(msg))` a load/prep failure.
    #[allow(clippy::type_complexity)]
    pub fn graph(&self, name: &str) -> Result<Arc<PreparedGraph>, Option<String>> {
        let slot = {
            let mut resident = lock_recover(&self.resident);
            match resident.slots.get(name) {
                Some(slot) => {
                    resident.touch(name);
                    slot.clone()
                }
                None => {
                    let source = lock_recover(&self.sources).get(name).cloned();
                    let Some(source) = source else { return Err(None) };
                    // Make room before inserting: evict least-recently
                    // used names until the new slot fits the cap.
                    while resident.slots.len() >= self.max_resident {
                        let Some(victim) = resident.order.first().cloned() else { break };
                        resident.slots.remove(&victim);
                        resident.order.retain(|n| n != &victim);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    let slot = Arc::new(GraphSlot {
                        name: name.to_string(),
                        source,
                        prep: OnceLock::new(),
                    });
                    resident.slots.insert(name.to_string(), slot.clone());
                    resident.touch(name);
                    slot
                }
            }
        };
        // Prepare outside the lock: concurrent callers of the same name
        // share the slot's OnceLock; other names proceed unblocked.
        match slot.prepare() {
            Ok(prep) => Ok(prep),
            Err(msg) => {
                // Drop the failed slot so a later request can retry
                // (e.g. the file appears); holders of the error keep it.
                let mut resident = lock_recover(&self.resident);
                if resident
                    .slots
                    .get(name)
                    .is_some_and(|s| Arc::ptr_eq(s, &slot))
                {
                    resident.slots.remove(name);
                    resident.order.retain(|n| n != name);
                }
                Err(Some(msg))
            }
        }
    }

    /// Get (compiling on first use) the pipeline for `algo`. `Err(None)`
    /// means no such algorithm; `Err(Some(msg))` a compile failure.
    #[allow(clippy::type_complexity)]
    pub fn pipeline(&self, algo: &str) -> Result<Arc<CompiledPipeline>, Option<String>> {
        if let Some(p) = lock_recover(&self.pipelines).get(algo) {
            return Ok(p.clone());
        }
        let Some(program) = program_by_name(algo) else { return Err(None) };
        // Compile outside the pipelines lock (the session lock
        // serializes compiles; losers of a race just re-insert the same
        // value).
        let compiled = lock_recover(&self.session)
            .compile(&program)
            .map_err(|e| Some(e.to_string()))?;
        let compiled = Arc::new(compiled);
        let mut pipelines = lock_recover(&self.pipelines);
        Ok(pipelines.entry(algo.to_string()).or_insert(compiled).clone())
    }

    /// Compiled pipeline names, sorted.
    pub fn pipeline_names(&self) -> Vec<String> {
        let mut names: Vec<String> = lock_recover(&self.pipelines).keys().cloned().collect();
        names.sort();
        names
    }
}

/// Algorithm lookup by wire/CLI name (the `jgraph run --algo` names).
pub fn program_by_name(name: &str) -> Option<GasProgram> {
    use crate::dsl::algorithms;
    Some(match name {
        "bfs" => algorithms::bfs(),
        "pagerank" | "pr" => algorithms::pagerank(),
        "sssp" => algorithms::sssp(),
        "wcc" => algorithms::wcc(),
        "spmv" => algorithms::spmv(),
        "degree-count" => algorithms::degree_count(),
        "widest-path" => algorithms::widest_path(),
        "reachability" => algorithms::reachability(),
        "max-label" => algorithms::max_label(),
        _ => return None,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::engine::SessionConfig;
    use crate::graph::generate;

    fn registry(max_resident: usize) -> ServeRegistry {
        let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
        ServeRegistry::new(session, max_resident)
    }

    #[test]
    fn unknown_names_are_typed_not_errors_with_messages() {
        let reg = registry(2);
        assert!(matches!(reg.graph("nope"), Err(None)));
        assert!(matches!(reg.pipeline("nope"), Err(None)));
    }

    #[test]
    fn graphs_prepare_once_and_lru_evicts_over_cap() {
        let reg = registry(2);
        reg.register_edges("a", generate::erdos_renyi(64, 256, 1));
        reg.register_edges("b", generate::erdos_renyi(64, 256, 2));
        reg.register_edges("c", generate::erdos_renyi(64, 256, 3));
        let a1 = reg.graph("a").unwrap();
        let a2 = reg.graph("a").unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "repeat touches share one prep");
        reg.graph("b").unwrap();
        assert_eq!(reg.resident_count(), 2);
        assert_eq!(reg.evictions(), 0);
        // third graph evicts the least recently used ("a"? no — "a" was
        // touched before "b", so "a" is LRU)
        reg.graph("c").unwrap();
        assert_eq!(reg.resident_count(), 2);
        assert_eq!(reg.evictions(), 1);
        assert_eq!(reg.resident_names(), vec!["b".to_string(), "c".to_string()]);
        // the evicted graph reloads transparently as a fresh prep
        let a3 = reg.graph("a").unwrap();
        assert!(!Arc::ptr_eq(&a1, &a3), "reload is a new prepared graph");
        assert_eq!(a3.num_vertices(), a1.num_vertices());
        assert_eq!(reg.resident_count(), 2);
        assert_eq!(reg.evictions(), 2);
    }

    #[test]
    fn touch_order_protects_recently_used_graphs() {
        let reg = registry(2);
        reg.register_edges("a", generate::chain(32));
        reg.register_edges("b", generate::chain(32));
        reg.register_edges("c", generate::chain(32));
        reg.graph("a").unwrap();
        reg.graph("b").unwrap();
        reg.graph("a").unwrap(); // "a" is now most recent
        reg.graph("c").unwrap(); // evicts "b"
        assert_eq!(reg.resident_names(), vec!["a".to_string(), "c".to_string()]);
    }

    #[test]
    fn pipelines_compile_once_per_algo() {
        let reg = registry(2);
        let p1 = reg.pipeline("bfs").unwrap();
        let p2 = reg.pipeline("bfs").unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(p1.program().name, "bfs");
        assert_eq!(reg.pipeline_names(), vec!["bfs".to_string()]);
    }

    #[test]
    fn concurrent_first_touches_share_one_prepared_graph() {
        // The satellite contract: two threads loading the same named
        // graph race on the slot's OnceLock — CSR/CSC/auto-shard are
        // built once and both callers hold the same Arc.
        let reg = registry(2);
        reg.register_edges("shared", generate::erdos_renyi(128, 1024, 7));
        let barrier = std::sync::Barrier::new(2);
        let (a, b) = std::thread::scope(|scope| {
            let ta = scope.spawn(|| {
                barrier.wait();
                reg.graph("shared").unwrap()
            });
            let tb = scope.spawn(|| {
                barrier.wait();
                reg.graph("shared").unwrap()
            });
            (ta.join().unwrap(), tb.join().unwrap())
        });
        assert!(Arc::ptr_eq(&a, &b), "racing loads must share one prep");
        // the lazily-built caches are the same objects through either Arc
        assert!(std::ptr::eq(a.csc(), b.csc()));
        assert!(std::ptr::eq(a.out_deg().as_ptr(), b.out_deg().as_ptr()));
    }

    #[test]
    fn failed_loads_surface_and_do_not_poison_the_slot() {
        let reg = registry(2);
        reg.register_spec("ghost", "/nonexistent/ghost.txt", 1);
        let Err(Some(msg)) = reg.graph("ghost") else { panic!("expected a load error") };
        assert!(msg.contains("ghost"), "{msg}");
        // the failed slot is not left resident
        assert_eq!(reg.resident_count(), 0);
    }
}
