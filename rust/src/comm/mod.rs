//! Communication manager (paper §V-C1): host↔FPGA data transfer and
//! configuration management. The physical PCIe link and the XRT/XOCL
//! control shell are simulated (DESIGN.md §2): [`pcie`] is a
//! bandwidth/latency model of Gen3×16 DMA, [`xrt`] mimics the XRT user-
//! space shell (device status, configuration registers, xclbin flash),
//! and [`CommManager`] is the paper's "several easy-to-use interfaces to
//! help status transfer and configuration management".

pub mod pcie;
pub mod xrt;

use anyhow::Result;

use crate::graph::csr::Csr;

pub use pcie::PcieModel;
pub use xrt::{DeviceStatus, XrtShell};

/// The high-level interface the DSL's control functions map to
/// (`Get_FPGA_Message`, `Transport`).
#[derive(Debug)]
pub struct CommManager {
    pub pcie: PcieModel,
    pub shell: XrtShell,
    /// Accumulated simulated transfer time (the Transport part of the
    /// paper's running time).
    pub transfer_seconds: f64,
    pub bytes_moved: u64,
}

/// Record of one `Transport` call.
#[derive(Debug, Clone, Copy)]
pub struct TransferRecord {
    pub bytes: u64,
    pub seconds: f64,
}

impl CommManager {
    /// Gen3×16 link to a freshly "flashed" U200 shell.
    pub fn new() -> Self {
        Self {
            pcie: PcieModel::gen3_x16(),
            shell: XrtShell::new(),
            transfer_seconds: 0.0,
            bytes_moved: 0,
        }
    }

    /// `Get_FPGA_Message()` — device status through the shell.
    pub fn fpga_message(&self) -> DeviceStatus {
        self.shell.status()
    }

    /// `Transport(CPU_ip, FPGA_ip, Graph)` — DMA the CSR arrays to device
    /// DDR. Fails if the device has not been configured (matching XRT's
    /// behaviour when no xclbin is loaded).
    pub fn transport_graph(&mut self, graph: &Csr) -> Result<TransferRecord> {
        self.shell.require_configured()?;
        let bytes = graph.byte_size() as u64;
        let seconds = self.pcie.transfer_seconds(bytes);
        self.transfer_seconds += seconds;
        self.bytes_moved += bytes;
        Ok(TransferRecord { bytes, seconds })
    }

    /// DMA raw result buffers back (vertex values).
    pub fn read_back(&mut self, bytes: u64) -> TransferRecord {
        let seconds = self.pcie.transfer_seconds(bytes);
        self.transfer_seconds += seconds;
        self.bytes_moved += bytes;
        TransferRecord { bytes, seconds }
    }
}

impl Default for CommManager {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{csr::Csr, generate};

    #[test]
    fn transport_requires_configuration() {
        let g = Csr::from_edgelist(&generate::chain(10));
        let mut cm = CommManager::new();
        assert!(cm.transport_graph(&g).is_err(), "unconfigured device must reject DMA");
        cm.shell.configure("bfs.xclbin", 8, 1).unwrap();
        let rec = cm.transport_graph(&g).unwrap();
        assert_eq!(rec.bytes, g.byte_size() as u64);
        assert!(rec.seconds > 0.0);
    }

    #[test]
    fn transfer_time_accumulates() {
        let g = Csr::from_edgelist(&generate::erdos_renyi(100, 1000, 1));
        let mut cm = CommManager::new();
        cm.shell.configure("x.xclbin", 8, 1).unwrap();
        cm.transport_graph(&g).unwrap();
        let t1 = cm.transfer_seconds;
        cm.read_back(4 * 100);
        assert!(cm.transfer_seconds > t1);
        assert_eq!(cm.bytes_moved, g.byte_size() as u64 + 400);
    }
}
