//! Communication manager (paper §V-C1): host↔FPGA data transfer and
//! configuration management. The physical PCIe link and the XRT/XOCL
//! control shell are simulated (DESIGN.md §2): [`pcie`] is a
//! bandwidth/latency model of Gen3×16 DMA, [`xrt`] mimics the XRT user-
//! space shell (device status, configuration registers, xclbin flash),
//! and [`CommManager`] is the paper's "several easy-to-use interfaces to
//! help status transfer and configuration management".
//!
//! Transfer accounting is **thread-safe**: the seconds/bytes ledger sits
//! behind a mutex so concurrent queries
//! ([`crate::engine::BoundPipeline::run_batch_parallel`]) can share one
//! manager through `&self`. Workers model their DMA with the pure
//! [`CommManager::plan_read_back`] and the engine commits the records in
//! query order after the join, so totals are bit-identical to the
//! sequential path regardless of thread interleaving.

pub mod pcie;
pub mod xrt;

use std::sync::Mutex;

use anyhow::Result;

use crate::graph::csr::Csr;
use crate::sched::{Deadline, FaultPlan, Seam};

pub use pcie::PcieModel;
pub use xrt::{DeviceStatus, XrtShell};

/// Accumulated DMA totals (the Transport part of the paper's running
/// time), guarded by the [`CommManager`]'s mutex.
#[derive(Debug, Default, Clone, Copy)]
struct Ledger {
    transfer_seconds: f64,
    bytes_moved: u64,
}

/// The high-level interface the DSL's control functions map to
/// (`Get_FPGA_Message`, `Transport`).
#[derive(Debug)]
pub struct CommManager {
    pub pcie: PcieModel,
    pub shell: XrtShell,
    ledger: Mutex<Ledger>,
}

/// Record of one `Transport` call.
#[derive(Debug, Clone, Copy)]
pub struct TransferRecord {
    pub bytes: u64,
    pub seconds: f64,
}

/// Payload bytes per boundary-exchange message (dst id + f64 value
/// packed to the interconnect flit, matching
/// [`crate::accel::multipe::InterconnectModel::bytes_per_msg`]).
pub const EXCHANGE_BYTES_PER_MSG: u64 = 8;
/// Peer-to-peer exchange bandwidth (card-to-card / PE-to-PE DMA class,
/// ~16 GB/s — an order below the bulk PCIe Gen3×16 stream rate).
pub const EXCHANGE_BYTES_PER_SECOND: f64 = 16.0e9;
/// Fixed handshake latency per exchange round.
pub const EXCHANGE_LATENCY_SECONDS: f64 = 2.0e-6;

impl CommManager {
    /// Gen3×16 link to a freshly "flashed" U200 shell.
    pub fn new() -> Self {
        Self {
            pcie: PcieModel::gen3_x16(),
            shell: XrtShell::new(),
            ledger: Mutex::new(Ledger::default()),
        }
    }

    /// `Get_FPGA_Message()` — device status through the shell.
    pub fn fpga_message(&self) -> DeviceStatus {
        self.shell.status()
    }

    /// Accumulated simulated transfer time across all committed DMAs.
    pub fn transfer_seconds(&self) -> f64 {
        self.ledger.lock().unwrap().transfer_seconds
    }

    /// Accumulated bytes across all committed DMAs.
    pub fn bytes_moved(&self) -> u64 {
        self.ledger.lock().unwrap().bytes_moved
    }

    /// `Transport(CPU_ip, FPGA_ip, Graph)` — DMA the CSR arrays to device
    /// DDR. Fails if the device has not been configured (matching XRT's
    /// behaviour when no xclbin is loaded).
    pub fn transport_graph(&self, graph: &Csr) -> Result<TransferRecord> {
        self.shell.require_configured()?;
        let bytes = graph.byte_size() as u64;
        let record = TransferRecord { bytes, seconds: self.pcie.transfer_seconds(bytes) };
        self.commit(&record);
        Ok(record)
    }

    /// Model a result read-back DMA **without** touching the ledger: pure
    /// on the link model, safe to call from any thread. Pair with
    /// [`Self::commit`] — parallel queries plan their own DMA and the
    /// engine commits the records deterministically after the join.
    pub fn plan_read_back(&self, bytes: u64) -> TransferRecord {
        TransferRecord { bytes, seconds: self.pcie.transfer_seconds(bytes) }
    }

    /// Model a boundary-exchange transfer (sharded execution's cut-edge
    /// messages between PEs / devices) **without** touching the ledger —
    /// the exchange analogue of [`Self::plan_read_back`], committed the
    /// same deterministic way. Small-message traffic, so it is priced by
    /// its own class: [`EXCHANGE_BYTES_PER_MSG`] bytes per message over a
    /// peer-to-peer link ([`EXCHANGE_BYTES_PER_SECOND`]) with one
    /// [`EXCHANGE_LATENCY_SECONDS`] handshake per exchange round, not by
    /// the bulk PCIe DMA model.
    pub fn plan_exchange(&self, msgs: u64) -> TransferRecord {
        let bytes = msgs * EXCHANGE_BYTES_PER_MSG;
        TransferRecord {
            bytes,
            seconds: EXCHANGE_LATENCY_SECONDS + bytes as f64 / EXCHANGE_BYTES_PER_SECOND,
        }
    }

    /// Fold one transfer record into the shared accounting.
    pub fn commit(&self, record: &TransferRecord) {
        let mut ledger = self.ledger.lock().unwrap();
        ledger.transfer_seconds += record.seconds;
        ledger.bytes_moved += record.bytes;
    }

    /// Commit a query's planned transfer records behind the
    /// fault-tolerance guards (ISSUE 10): re-check the deadline and trip
    /// the [`Seam::Commit`] fault seam **before** any record lands, so a
    /// cancelled or faulted query leaves the shared ledger untouched —
    /// all-or-nothing, keeping sibling queries' accounting bit-identical.
    /// With `deadline`/`faults` both `None` this is exactly a plain
    /// [`Self::commit`] loop.
    pub fn commit_guarded(
        &self,
        records: &[TransferRecord],
        deadline: Option<&Deadline>,
        faults: Option<&FaultPlan>,
        token: u64,
        supersteps_completed: u32,
    ) -> Result<()> {
        if let Some(deadline) = deadline {
            deadline.check(supersteps_completed)?;
        }
        if let Some(plan) = faults {
            plan.trip(Seam::Commit, token)?;
        }
        for record in records {
            self.commit(record);
        }
        Ok(())
    }

    /// DMA raw result buffers back (vertex values): plan + commit.
    pub fn read_back(&self, bytes: u64) -> TransferRecord {
        let record = self.plan_read_back(bytes);
        self.commit(&record);
        record
    }
}

impl Default for CommManager {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{csr::Csr, generate};

    #[test]
    fn transport_requires_configuration() {
        let g = Csr::from_edgelist(&generate::chain(10));
        let mut cm = CommManager::new();
        assert!(cm.transport_graph(&g).is_err(), "unconfigured device must reject DMA");
        cm.shell.configure("bfs.xclbin", 8, 1).unwrap();
        let rec = cm.transport_graph(&g).unwrap();
        assert_eq!(rec.bytes, g.byte_size() as u64);
        assert!(rec.seconds > 0.0);
    }

    #[test]
    fn transfer_time_accumulates() {
        let g = Csr::from_edgelist(&generate::erdos_renyi(100, 1000, 1));
        let mut cm = CommManager::new();
        cm.shell.configure("x.xclbin", 8, 1).unwrap();
        cm.transport_graph(&g).unwrap();
        let t1 = cm.transfer_seconds();
        cm.read_back(4 * 100);
        assert!(cm.transfer_seconds() > t1);
        assert_eq!(cm.bytes_moved(), g.byte_size() as u64 + 400);
    }

    #[test]
    fn planned_transfers_commit_identically_to_direct_read_back() {
        let mut direct = CommManager::new();
        direct.shell.configure("a.xclbin", 8, 1).unwrap();
        let mut deferred = CommManager::new();
        deferred.shell.configure("a.xclbin", 8, 1).unwrap();

        let sizes = [400u64, 4_096, 123_456, 400];
        for &b in &sizes {
            direct.read_back(b);
        }
        // plan on worker threads, commit in order afterwards — the ledger
        // must be bit-identical to the sequential path
        let deferred_ref = &deferred;
        let records: Vec<TransferRecord> = std::thread::scope(|s| {
            let handles: Vec<_> = sizes
                .iter()
                .map(|&b| s.spawn(move || deferred_ref.plan_read_back(b)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(deferred.bytes_moved(), 0, "planning must not touch the ledger");
        for r in &records {
            deferred.commit(r);
        }
        assert_eq!(direct.bytes_moved(), deferred.bytes_moved());
        assert_eq!(direct.transfer_seconds().to_bits(), deferred.transfer_seconds().to_bits());
    }

    #[test]
    fn guarded_commits_are_all_or_nothing() {
        let cm = CommManager::new();
        let recs = [cm.plan_read_back(400), cm.plan_read_back(4_096)];
        // no guards: exactly a plain commit loop
        cm.commit_guarded(&recs, None, None, 0, 0).unwrap();
        assert_eq!(cm.bytes_moved(), 400 + 4_096);
        let before = (cm.bytes_moved(), cm.transfer_seconds().to_bits());
        // a tripped commit seam leaves the ledger untouched
        let plan = FaultPlan::parse("transfer_error@commit#9").unwrap();
        let err = cm.commit_guarded(&recs, None, Some(&plan), 9, 0).unwrap_err();
        assert!(err.downcast_ref::<crate::sched::InjectedFault>().is_some());
        assert_eq!((cm.bytes_moved(), cm.transfer_seconds().to_bits()), before);
        // an expired deadline likewise, with partial accounting stamped
        let d = Deadline::in_duration(std::time::Duration::ZERO);
        let err = cm.commit_guarded(&recs, Some(&d), None, 0, 3).unwrap_err();
        let de = err.downcast_ref::<crate::sched::DeadlineExceeded>().unwrap();
        assert_eq!(de.supersteps_completed, 3);
        assert_eq!((cm.bytes_moved(), cm.transfer_seconds().to_bits()), before);
    }

    #[test]
    fn exchange_plans_are_pure_and_scale_with_messages() {
        let cm = CommManager::new();
        let small = cm.plan_exchange(100);
        let big = cm.plan_exchange(100_000);
        assert_eq!(small.bytes, 100 * EXCHANGE_BYTES_PER_MSG);
        assert!(small.seconds >= EXCHANGE_LATENCY_SECONDS);
        assert!(big.seconds > small.seconds);
        assert_eq!(cm.bytes_moved(), 0, "planning must not touch the ledger");
        // committed through the same ledger as DMA records
        cm.commit(&small);
        assert_eq!(cm.bytes_moved(), small.bytes);
        assert_eq!(cm.transfer_seconds().to_bits(), small.seconds.to_bits());
    }
}
