//! PCIe DMA model: Gen3×16 (the paper's card edge). Effective DMA
//! throughput on XRT-era shells is ~10–12 GB/s of the 15.75 GB/s raw
//! (TLP/DLLP overhead + driver); small transfers pay a fixed setup cost.


/// Bandwidth/latency model of a host↔device DMA link.
#[derive(Debug, Clone, Copy)]
pub struct PcieModel {
    /// Effective bulk bandwidth, bytes/s.
    pub effective_bw: f64,
    /// Per-transfer setup latency, seconds (descriptor + doorbell + IRQ).
    pub setup_latency: f64,
}

impl PcieModel {
    /// PCI Express Gen3 ×16 as deployed with XRT/XDMA.
    pub fn gen3_x16() -> Self {
        PcieModel { effective_bw: 11.0e9, setup_latency: 30.0e-6 }
    }

    /// Simulated wall time for one DMA of `bytes`.
    pub fn transfer_seconds(&self, bytes: u64) -> f64 {
        self.setup_latency + bytes as f64 / self.effective_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_transfers_are_latency_bound() {
        let p = PcieModel::gen3_x16();
        let t4 = p.transfer_seconds(4);
        assert!((t4 - p.setup_latency).abs() / p.setup_latency < 0.01);
    }

    #[test]
    fn bulk_transfers_are_bandwidth_bound() {
        let p = PcieModel::gen3_x16();
        let gb = p.transfer_seconds(1 << 30);
        // ~0.098s for 1 GiB at 11 GB/s
        assert!((0.08..0.12).contains(&gb), "{gb}");
    }

    #[test]
    fn monotone_in_bytes() {
        let p = PcieModel::gen3_x16();
        assert!(p.transfer_seconds(1000) < p.transfer_seconds(1_000_000));
    }
}
