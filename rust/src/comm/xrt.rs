//! Simulated XRT shell — the user-space control layer the paper's
//! communication manager wraps ("the control shell for host consists of OS
//! kernel controller XOCL and user space controller Xilinx Runtime (XRT)
//! ... We can get FPGA running status and send control instructions
//! through these tools").

use anyhow::{bail, Result};

/// Device lifecycle, mirroring `xbutil` states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceState {
    /// Card present, no xclbin loaded.
    Unconfigured,
    /// Bitstream flashed and clocks up.
    Ready,
    /// Kernel launched, supersteps in flight.
    Running,
    /// Fault injected / overtemperature — rejects everything until reset.
    Error,
}

/// A `Get_FPGA_Message` response.
#[derive(Debug, Clone)]
pub struct DeviceStatus {
    pub state: DeviceState,
    pub xclbin: Option<String>,
    pub pipelines: u32,
    pub pes: u32,
    /// Modeled die temperature (°C) — grows with configured parallelism.
    pub temperature_c: f64,
    pub completed_launches: u64,
}

/// The simulated shell. Control-register writes validate state
/// transitions the way XRT does (e.g. you cannot launch an unconfigured
/// device); the failure-injection tests drive the `Error` path.
#[derive(Debug)]
pub struct XrtShell {
    state: DeviceState,
    xclbin: Option<String>,
    pipelines: u32,
    pes: u32,
    launches: u64,
}

impl XrtShell {
    pub fn new() -> Self {
        Self { state: DeviceState::Unconfigured, xclbin: None, pipelines: 0, pes: 0, launches: 0 }
    }

    /// Flash an xclbin and set the parallelism CSRs (`Set_Pipeline`,
    /// `Set_PE`).
    pub fn configure(&mut self, xclbin: &str, pipelines: u32, pes: u32) -> Result<()> {
        if self.state == DeviceState::Error {
            bail!("device in error state; reset required before configure");
        }
        if pipelines == 0 || pes == 0 {
            bail!("configure: pipelines and pes must be >= 1");
        }
        self.xclbin = Some(xclbin.to_string());
        self.pipelines = pipelines;
        self.pes = pes;
        self.state = DeviceState::Ready;
        Ok(())
    }

    /// Kick one superstep (the host driver's `JG_CSR_LAUNCH` write).
    pub fn launch(&mut self) -> Result<()> {
        match self.state {
            DeviceState::Ready | DeviceState::Running => {
                self.state = DeviceState::Running;
                self.launches += 1;
                Ok(())
            }
            DeviceState::Unconfigured => bail!("launch on unconfigured device"),
            DeviceState::Error => bail!("launch on errored device"),
        }
    }

    /// Superstep completion interrupt.
    pub fn complete(&mut self) {
        if self.state == DeviceState::Running {
            self.state = DeviceState::Ready;
        }
    }

    /// Inject a device fault (failure-injection tests).
    pub fn inject_error(&mut self) {
        self.state = DeviceState::Error;
    }

    /// `xbutil reset`.
    pub fn reset(&mut self) {
        *self = Self::new();
    }

    pub fn require_configured(&self) -> Result<()> {
        match self.state {
            DeviceState::Unconfigured => bail!("device not configured (no xclbin loaded)"),
            DeviceState::Error => bail!("device in error state"),
            _ => Ok(()),
        }
    }

    pub fn status(&self) -> DeviceStatus {
        DeviceStatus {
            state: self.state,
            xclbin: self.xclbin.clone(),
            pipelines: self.pipelines,
            pes: self.pes,
            temperature_c: 45.0 + 1.5 * (self.pipelines * self.pes) as f64,
            completed_launches: self.launches,
        }
    }
}

impl Default for XrtShell {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let mut s = XrtShell::new();
        assert_eq!(s.status().state, DeviceState::Unconfigured);
        s.configure("bfs.xclbin", 8, 1).unwrap();
        assert_eq!(s.status().state, DeviceState::Ready);
        s.launch().unwrap();
        assert_eq!(s.status().state, DeviceState::Running);
        s.complete();
        assert_eq!(s.status().state, DeviceState::Ready);
        assert_eq!(s.status().completed_launches, 1);
    }

    #[test]
    fn launch_requires_configure() {
        let mut s = XrtShell::new();
        assert!(s.launch().is_err());
    }

    #[test]
    fn error_state_blocks_until_reset() {
        let mut s = XrtShell::new();
        s.configure("x", 8, 1).unwrap();
        s.inject_error();
        assert!(s.launch().is_err());
        assert!(s.configure("x", 8, 1).is_err());
        assert!(s.require_configured().is_err());
        s.reset();
        s.configure("x", 4, 2).unwrap();
        s.launch().unwrap();
    }

    #[test]
    fn configure_validates_parallelism() {
        let mut s = XrtShell::new();
        assert!(s.configure("x", 0, 1).is_err());
        assert!(s.configure("x", 1, 0).is_err());
    }

    #[test]
    fn temperature_scales_with_lanes() {
        let mut a = XrtShell::new();
        a.configure("x", 1, 1).unwrap();
        let mut b = XrtShell::new();
        b.configure("x", 64, 2).unwrap();
        assert!(b.status().temperature_c > a.status().temperature_c);
    }
}
