//! Admission-side concurrency caps: a non-blocking permit counter that
//! bounds how many requests one principal (a tenant, a binding, a queue)
//! may have in flight at once. Composes with [`super::WorkerBudget`]
//! rather than duplicating it: the budget rations *threads* among pools
//! that already hold work, while a [`ConcurrencyCap`] rations *admission*
//! — whether a request may enter the system at all. A request admitted
//! under its cap still executes inside whatever worker lease its sweep
//! is granted.
//!
//! Caps never block. An over-cap acquire returns `None` immediately —
//! the serving layer turns that into a typed reject on the wire (see
//! [`crate::serve::tenant`]) instead of queueing unbounded work behind a
//! slow tenant.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// A fixed in-flight limit with lock-free acquire/release and reject
/// accounting. Cheap enough to keep one per tenant.
#[derive(Debug)]
pub struct ConcurrencyCap {
    limit: usize,
    inflight: AtomicUsize,
    peak: AtomicUsize,
    rejected: AtomicU64,
}

impl ConcurrencyCap {
    /// A cap admitting at most `limit` concurrent holders (clamped ≥ 1:
    /// a zero cap would deadlock every caller that retries).
    pub fn new(limit: usize) -> Self {
        ConcurrencyCap {
            limit: limit.max(1),
            inflight: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// The configured in-flight limit.
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Permits currently held.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// High-water mark of [`Self::inflight`].
    pub fn peak_inflight(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Acquires rejected because the cap was full.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Raw acquire: returns `true` and counts one holder when under the
    /// limit, `false` (and one reject) when full. Callers that prefer
    /// RAII use [`Self::try_acquire`]; owners that must move the permit
    /// across threads pair this with [`Self::release`] in their own
    /// `Drop` (see [`crate::serve::tenant::TenantPermit`]).
    pub fn try_begin(&self) -> bool {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(cur + 1, Ordering::Relaxed);
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Return one permit taken by [`Self::try_begin`].
    pub fn release(&self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// RAII acquire: `None` when the cap is full (counted as a reject).
    pub fn try_acquire(&self) -> Option<CapPermit<'_>> {
        if self.try_begin() {
            Some(CapPermit { cap: self })
        } else {
            None
        }
    }
}

/// RAII permit from [`ConcurrencyCap::try_acquire`]; releases on drop
/// (unwind included).
#[derive(Debug)]
pub struct CapPermit<'a> {
    cap: &'a ConcurrencyCap,
}

impl Drop for CapPermit<'_> {
    fn drop(&mut self) {
        self.cap.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn over_cap_acquires_reject_without_blocking() {
        let cap = ConcurrencyCap::new(2);
        let a = cap.try_acquire().unwrap();
        let b = cap.try_acquire().unwrap();
        assert_eq!(cap.inflight(), 2);
        assert!(cap.try_acquire().is_none(), "third holder must be rejected");
        assert_eq!(cap.rejected(), 1);
        drop(a);
        // a freed permit is immediately grantable again
        let c = cap.try_acquire().unwrap();
        assert_eq!(cap.inflight(), 2);
        drop(b);
        drop(c);
        assert_eq!(cap.inflight(), 0);
        assert_eq!(cap.peak_inflight(), 2);
        assert_eq!(cap.rejected(), 1);
    }

    #[test]
    fn zero_limit_is_clamped_to_one() {
        let cap = ConcurrencyCap::new(0);
        assert_eq!(cap.limit(), 1);
        let p = cap.try_acquire().unwrap();
        assert!(cap.try_acquire().is_none());
        drop(p);
    }

    #[test]
    fn permit_releases_on_panic() {
        let cap = ConcurrencyCap::new(1);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _permit = cap.try_acquire().unwrap();
            panic!("holder died");
        }));
        assert!(outcome.is_err());
        assert_eq!(cap.inflight(), 0, "unwind must return the permit");
        assert!(cap.try_acquire().is_some());
    }

    #[test]
    fn concurrent_acquires_never_exceed_the_limit() {
        let cap = ConcurrencyCap::new(3);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..200 {
                        if let Some(p) = cap.try_acquire() {
                            assert!(cap.inflight() <= 3);
                            drop(p);
                        }
                    }
                });
            }
        });
        assert_eq!(cap.inflight(), 0);
        assert!(cap.peak_inflight() <= 3);
    }
}
