//! The runtime scheduler proper: picks/validates a [`ParallelismPlan`]
//! against device resources, assigns graph partitions to PEs, and tracks
//! superstep progress for the engine.

use anyhow::{bail, Result};

use super::ParallelismPlan;
use crate::accel::device::DeviceModel;
use crate::prep::partition::Partitioning;
use crate::translator::resource::ResourceEstimate;

/// Events the scheduler records (surfaced in run reports and tests).
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerEvent {
    PlanAccepted { plan: ParallelismPlan },
    PlanReduced { requested: ParallelismPlan, granted: ParallelismPlan, reason: String },
    SuperstepStarted { index: u32, active_vertices: usize },
    SuperstepFinished { index: u32, updated: usize },
    Converged { supersteps: u32 },
    IterationCapHit { cap: u32 },
}

/// The outcome of plan admission: the granted plan plus the admission
/// events that produced it. Admission is a per-**binding** decision (the
/// design and device do not change between queries), so this is computed
/// once when a pipeline is bound to a graph and reused by every query —
/// each query derives its own cheap [`RuntimeScheduler`] from it via
/// [`AdmittedPlan::scheduler`] instead of re-validating resources.
#[derive(Debug, Clone)]
pub struct AdmittedPlan {
    pub granted: ParallelismPlan,
    pub events: Vec<SchedulerEvent>,
}

impl AdmittedPlan {
    /// Validate the requested plan against the device; shrink it (halving
    /// pipelines, then PEs) until the replicated design fits. Fails only
    /// if even 1×1 does not fit.
    pub fn admit(
        requested: ParallelismPlan,
        per_lane: &ResourceEstimate,
        device: &DeviceModel,
    ) -> Result<Self> {
        if requested.pipelines == 0 || requested.pes == 0 {
            bail!("parallelism plan must have at least 1 pipeline and 1 PE");
        }
        let mut plan = requested;
        let mut events = Vec::new();
        loop {
            let total = per_lane.scaled(plan.total_lanes());
            if total.fits(device) {
                if plan == requested {
                    events.push(SchedulerEvent::PlanAccepted { plan });
                } else {
                    events.push(SchedulerEvent::PlanReduced {
                        requested,
                        granted: plan,
                        reason: format!(
                            "requested {}x{} lanes exceed device resources",
                            requested.pipelines, requested.pes
                        ),
                    });
                }
                return Ok(Self { granted: plan, events });
            }
            if plan.pipelines > 1 {
                plan.pipelines /= 2;
            } else if plan.pes > 1 {
                plan.pes /= 2;
            } else {
                bail!(
                    "design does not fit the device even at 1 pipeline x 1 PE: \
                     need {:?}, device {:?}",
                    per_lane,
                    device.name
                );
            }
        }
    }

    /// Derive a per-query scheduler from the granted plan. O(1): no
    /// resource re-validation — admission already happened at bind time.
    pub fn scheduler(&self, cap: u32) -> RuntimeScheduler {
        RuntimeScheduler { plan: self.granted, events: self.events.clone(), superstep: 0, cap }
    }

    /// Place execution shards onto the granted PEs round-robin; returns
    /// `pe_of_shard`. The binding-time analogue of
    /// [`RuntimeScheduler::place_partitions`] — shard placement is fixed
    /// per binding, not per query, so it lives on the admitted plan.
    pub fn place_shards(&self, num_shards: usize) -> Vec<u32> {
        (0..num_shards).map(|s| (s as u32) % self.granted.pes.max(1)).collect()
    }
}

/// Scheduler state for one run.
#[derive(Debug)]
pub struct RuntimeScheduler {
    pub plan: ParallelismPlan,
    pub events: Vec<SchedulerEvent>,
    superstep: u32,
    cap: u32,
}

impl RuntimeScheduler {
    /// Admit `requested` and build a scheduler for one run — the one-shot
    /// path. Query traffic should admit once with [`AdmittedPlan::admit`]
    /// and derive per-query schedulers from the granted plan instead.
    pub fn admit(
        requested: ParallelismPlan,
        per_lane: &ResourceEstimate,
        device: &DeviceModel,
        cap: u32,
    ) -> Result<Self> {
        Ok(AdmittedPlan::admit(requested, per_lane, device)?.scheduler(cap))
    }

    /// Record a superstep start; errors when the iteration cap is hit
    /// (safety net against non-converging programs).
    pub fn begin_superstep(&mut self, active_vertices: usize) -> Result<u32> {
        if self.superstep >= self.cap {
            self.events.push(SchedulerEvent::IterationCapHit { cap: self.cap });
            bail!("iteration cap {} hit without convergence", self.cap);
        }
        self.events.push(SchedulerEvent::SuperstepStarted {
            index: self.superstep,
            active_vertices,
        });
        Ok(self.superstep)
    }

    pub fn end_superstep(&mut self, updated: usize) {
        self.events.push(SchedulerEvent::SuperstepFinished { index: self.superstep, updated });
        self.superstep += 1;
    }

    pub fn converged(&mut self) {
        self.events.push(SchedulerEvent::Converged { supersteps: self.superstep });
    }

    pub fn supersteps(&self) -> u32 {
        self.superstep
    }

    /// Assign partition parts to PEs round-robin; returns `pe_of_part`.
    pub fn place_partitions(&self, partitioning: &Partitioning) -> Vec<u32> {
        (0..partitioning.num_parts).map(|p| (p as u32) % self.plan.pes).collect()
    }
}

/// Search the largest plan that fits: doubles pipelines up to `max_lanes`,
/// then PEs — the auto-tuning path of `Set_Pipeline`/`Set_PE` when the
/// user passes 0 ("let the scheduler decide").
pub fn auto_plan(
    per_lane: &ResourceEstimate,
    device: &DeviceModel,
    max_pipelines: u32,
    max_pes: u32,
) -> ParallelismPlan {
    let mut best = ParallelismPlan::new(1, 1);
    let mut pipes = 1;
    while pipes <= max_pipelines {
        let mut pes = 1;
        while pes <= max_pes {
            let plan = ParallelismPlan::new(pipes, pes);
            if per_lane.scaled(plan.total_lanes()).fits(device) {
                if plan.total_lanes() > best.total_lanes() {
                    best = plan;
                }
            }
            pes *= 2;
        }
        pipes *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::device::DeviceModel;
    use crate::translator::resource::ResourceEstimate;

    fn lane() -> ResourceEstimate {
        ResourceEstimate { lut: 20_000, ff: 30_000, bram_kb: 500, uram: 16, dsp: 8 }
    }

    #[test]
    fn admit_accepts_fitting_plan() {
        let s =
            RuntimeScheduler::admit(ParallelismPlan::new(8, 1), &lane(), &DeviceModel::u200(), 100)
                .unwrap();
        assert_eq!(s.plan, ParallelismPlan::new(8, 1));
        assert!(matches!(s.events[0], SchedulerEvent::PlanAccepted { .. }));
    }

    #[test]
    fn admit_shrinks_oversized_plan() {
        // 1024 pipelines x 4 PEs cannot fit; scheduler must shrink, not fail
        let s = RuntimeScheduler::admit(
            ParallelismPlan::new(1024, 4),
            &lane(),
            &DeviceModel::u200(),
            100,
        )
        .unwrap();
        assert!(s.plan.total_lanes() < 4096);
        assert!(matches!(s.events[0], SchedulerEvent::PlanReduced { .. }));
        // granted plan actually fits
        assert!(lane().scaled(s.plan.total_lanes()).fits(&DeviceModel::u200()));
    }

    #[test]
    fn admit_rejects_impossible_lane() {
        let huge = ResourceEstimate { lut: 10_000_000, ..lane() };
        let err = RuntimeScheduler::admit(
            ParallelismPlan::new(1, 1),
            &huge,
            &DeviceModel::u200(),
            100,
        )
        .unwrap_err();
        assert!(err.to_string().contains("does not fit"));
    }

    #[test]
    fn admit_rejects_zero_plan() {
        assert!(RuntimeScheduler::admit(
            ParallelismPlan::new(0, 1),
            &lane(),
            &DeviceModel::u200(),
            100
        )
        .is_err());
    }

    #[test]
    fn iteration_cap_enforced() {
        let mut s =
            RuntimeScheduler::admit(ParallelismPlan::default(), &lane(), &DeviceModel::u200(), 2)
                .unwrap();
        s.begin_superstep(10).unwrap();
        s.end_superstep(5);
        s.begin_superstep(5).unwrap();
        s.end_superstep(0);
        assert!(s.begin_superstep(0).is_err());
        assert_eq!(s.supersteps(), 2);
    }

    #[test]
    fn admitted_plan_spawns_independent_per_query_schedulers() {
        let admitted =
            AdmittedPlan::admit(ParallelismPlan::new(1024, 4), &lane(), &DeviceModel::u200())
                .unwrap();
        // the grant happened once; every derived scheduler sees it
        assert!(matches!(admitted.events[0], SchedulerEvent::PlanReduced { .. }));
        let mut a = admitted.scheduler(2);
        let mut b = admitted.scheduler(2);
        assert_eq!(a.plan, admitted.granted);
        assert_eq!(b.plan, admitted.granted);
        // progress in one query does not leak into another
        a.begin_superstep(4).unwrap();
        a.end_superstep(4);
        assert_eq!(a.supersteps(), 1);
        assert_eq!(b.supersteps(), 0);
        b.begin_superstep(4).unwrap();
        b.end_superstep(0);
        b.begin_superstep(0).unwrap();
        b.end_superstep(0);
        assert!(b.begin_superstep(0).is_err(), "cap applies per query");
        assert!(a.begin_superstep(1).is_ok(), "other query unaffected");
    }

    #[test]
    fn admit_wrapper_equals_admitted_plan_path() {
        let via_wrapper =
            RuntimeScheduler::admit(ParallelismPlan::new(8, 1), &lane(), &DeviceModel::u200(), 7)
                .unwrap();
        let via_split =
            AdmittedPlan::admit(ParallelismPlan::new(8, 1), &lane(), &DeviceModel::u200())
                .unwrap()
                .scheduler(7);
        assert_eq!(via_wrapper.plan, via_split.plan);
        assert_eq!(via_wrapper.events, via_split.events);
    }

    #[test]
    fn auto_plan_maximizes_lanes() {
        let plan = auto_plan(&lane(), &DeviceModel::u200(), 64, 4);
        assert!(plan.total_lanes() >= 8);
        assert!(lane().scaled(plan.total_lanes()).fits(&DeviceModel::u200()));
        // one doubling more must not fit in at least one direction
        let doubled = ResourceEstimate::default();
        let _ = doubled;
    }

    #[test]
    fn placement_round_robin() {
        let s =
            RuntimeScheduler::admit(ParallelismPlan::new(2, 2), &lane(), &DeviceModel::u200(), 10)
                .unwrap();
        let g = crate::graph::generate::erdos_renyi(40, 100, 1);
        let p = crate::prep::partition::partition(
            &g,
            4,
            crate::prep::partition::PartitionStrategy::Range,
        )
        .unwrap();
        assert_eq!(s.place_partitions(&p), vec![0, 1, 0, 1]);
    }

    #[test]
    fn shard_placement_round_robin_over_granted_pes() {
        let admitted =
            AdmittedPlan::admit(ParallelismPlan::new(2, 2), &lane(), &DeviceModel::u200())
                .unwrap();
        assert_eq!(admitted.place_shards(5), vec![0, 1, 0, 1, 0]);
        assert_eq!(admitted.place_shards(0), Vec::<u32>::new());
    }
}
