//! Runtime scheduler (paper §V-C2): "the parallel pipelines scheduling and
//! processing elements (PEs) scheduling, aiming at parallelism management
//! for the whole project... We can specify a specific number of pipelines
//! and PE for the program to achieve flexible parallelism."

pub mod budget;
pub mod caps;
pub mod faults;
pub mod scheduler;

pub use budget::{available_workers, PoolLease, WorkerBudget};
pub use caps::{CapPermit, ConcurrencyCap};
pub use faults::{
    Deadline, DeadlineExceeded, Fault, FaultKind, FaultPlan, InjectedFault, Seam, WorkerPanic,
};
pub use scheduler::{auto_plan, AdmittedPlan, RuntimeScheduler, SchedulerEvent};


/// The two parallelism knobs the DSL exposes (`Set_Pipeline`, `Set_PE`).
/// The paper's Algorithm 1 uses `Pipeline = 8, PE = 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelismPlan {
    /// Parallel pipeline lanes inside one PE (edges/cycle at II=1).
    pub pipelines: u32,
    /// Processing elements (replicated datapaths over graph partitions).
    pub pes: u32,
}

impl Default for ParallelismPlan {
    fn default() -> Self {
        // the paper's evaluation setting
        ParallelismPlan { pipelines: 8, pes: 1 }
    }
}

impl ParallelismPlan {
    pub fn new(pipelines: u32, pes: u32) -> Self {
        Self { pipelines, pes }
    }

    /// Total lane count across PEs.
    pub fn total_lanes(&self) -> u32 {
        self.pipelines * self.pes
    }
}
