//! Process-wide worker budget: one ledger every thread pool leases from,
//! so nested parallelism **divides** the machine instead of multiplying
//! into oversubscription. The failure mode this kills: a
//! `run_batch_parallel` sweep of 16 queries, each auto-sharded 8 ways,
//! used to spawn 16 × 8 threads on an 8-core box — now the batch pool
//! and every per-query shard pool draw from the same
//! [`WorkerBudget::global`] ledger, and the *total* live thread count
//! stays within the core count.
//!
//! ## Accounting model
//!
//! The ledger counts **extra** threads: every pool's calling thread
//! participates as worker 0 (see [`crate::engine::sharded`] — worker 0's
//! bucket runs inline), so a pool of `w` workers spawns `w - 1` threads
//! and leases exactly that many permits. A budget of `N` workers
//! therefore holds `N - 1` permits, and with one root caller the live
//! thread count is `1 + leased ≤ N`. Leases never block: a pool asks for
//! the size it wants and is granted whatever is left (possibly zero —
//! the pool then runs serially on its caller). Releases are RAII
//! ([`PoolLease`]), so permits return even on unwind.
//!
//! Budget pressure only shrinks pools, never changes results: the
//! sharded engine is bit-identical at every worker count, so a query
//! squeezed to one worker returns the same report it would have with
//! eight.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Worker-thread count the process should target: the `JGRAPH_WORKERS`
/// environment variable when set (≥ 1; read once, cached — export it
/// before the first query to pin single-threaded execution), otherwise
/// [`std::thread::available_parallelism`], falling back to 1.
pub fn available_workers() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        std::env::var("JGRAPH_WORKERS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&w| w >= 1)
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// A permit ledger for extra worker threads (see the module docs for the
/// accounting model). [`WorkerBudget::global`] is the process-wide
/// instance the engine uses; [`WorkerBudget::new`] builds local ones for
/// tests and embedders that want their own ceiling.
#[derive(Debug)]
pub struct WorkerBudget {
    /// Permits: extra threads allowed beyond the root caller.
    extra_limit: usize,
    leased: AtomicUsize,
    peak: AtomicUsize,
}

impl WorkerBudget {
    /// A budget targeting `workers` total live threads (so
    /// `workers - 1` spawnable extras; `workers` is clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        WorkerBudget {
            extra_limit: workers.max(1) - 1,
            leased: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// The process-wide budget, sized from [`available_workers`] on
    /// first use.
    pub fn global() -> &'static WorkerBudget {
        static GLOBAL: OnceLock<WorkerBudget> = OnceLock::new();
        GLOBAL.get_or_init(|| WorkerBudget::new(available_workers()))
    }

    /// Total live threads this budget targets (extras + the root caller).
    pub fn total_workers(&self) -> usize {
        self.extra_limit + 1
    }

    /// Extra-thread permits currently out on leases.
    pub fn leased(&self) -> usize {
        self.leased.load(Ordering::Relaxed)
    }

    /// High-water mark of [`Self::leased`] over the budget's lifetime —
    /// what tests assert never exceeded `total_workers() - 1`.
    pub fn peak_leased(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Lease permits for a pool that wants `pool` workers total. Grants
    /// up to `pool - 1` extras, bounded by what is left; never blocks.
    /// The returned lease's [`PoolLease::workers`] is the pool size to
    /// actually run with (1 when nothing was available — run serially).
    pub fn lease(&self, pool: usize) -> PoolLease<'_> {
        let want = pool.max(1) - 1;
        let mut cur = self.leased.load(Ordering::Relaxed);
        let extras = loop {
            let take = want.min(self.extra_limit.saturating_sub(cur));
            if take == 0 {
                break 0;
            }
            match self.leased.compare_exchange_weak(
                cur,
                cur + take,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(cur + take, Ordering::Relaxed);
                    break take;
                }
                Err(actual) => cur = actual,
            }
        };
        PoolLease { budget: self, extras }
    }
}

/// RAII grant from [`WorkerBudget::lease`]: holds `extras` permits and
/// returns them on drop.
#[derive(Debug)]
pub struct PoolLease<'a> {
    budget: &'a WorkerBudget,
    extras: usize,
}

impl PoolLease<'_> {
    /// Extra threads this lease covers spawning.
    pub fn extras(&self) -> usize {
        self.extras
    }

    /// Pool size to run with: the granted extras plus the calling thread.
    pub fn workers(&self) -> usize {
        self.extras + 1
    }
}

impl Drop for PoolLease<'_> {
    fn drop(&mut self) {
        if self.extras > 0 {
            self.budget.leased.fetch_sub(self.extras, Ordering::AcqRel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leases_cap_at_the_extra_limit_and_release_on_drop() {
        let b = WorkerBudget::new(4);
        assert_eq!(b.total_workers(), 4);
        let batch = b.lease(4);
        assert_eq!(batch.workers(), 4);
        assert_eq!(batch.extras(), 3);
        assert_eq!(b.leased(), 3);
        // the ledger is drained: a nested pool runs on its caller alone
        let nested = b.lease(8);
        assert_eq!(nested.workers(), 1);
        drop(nested);
        drop(batch);
        assert_eq!(b.leased(), 0);
        // permits came back
        assert_eq!(b.lease(2).workers(), 2);
        assert_eq!(b.peak_leased(), 3);
    }

    #[test]
    fn nested_batch_and_shard_leases_divide_not_multiply() {
        // 8-core budget, batch pool of 4 workers, each nesting a
        // shard pool that asks for 8: the old behavior would be
        // 4 × 8 = 32 live threads; the ledger bounds it to 8.
        let b = WorkerBudget::new(8);
        let batch = b.lease(4);
        assert_eq!(batch.workers(), 4);
        let per_query: Vec<_> = (0..4).map(|_| b.lease(8)).collect();
        let live = 1 + b.leased();
        assert!(live <= b.total_workers(), "live {live} > budget {}", b.total_workers());
        // every granted extra is accounted: batch extras + shard extras
        let shard_extras: usize = per_query.iter().map(|l| l.extras()).sum();
        assert_eq!(b.leased(), batch.extras() + shard_extras);
        drop(per_query);
        drop(batch);
        assert_eq!(b.leased(), 0);
        assert!(b.peak_leased() <= b.total_workers() - 1);
    }

    #[test]
    fn single_core_budget_grants_nothing() {
        let b = WorkerBudget::new(1);
        assert_eq!(b.total_workers(), 1);
        assert_eq!(b.lease(16).workers(), 1);
        assert_eq!(b.leased(), 0);
        // degenerate asks are clamped
        let b = WorkerBudget::new(0);
        assert_eq!(b.total_workers(), 1);
        assert_eq!(b.lease(0).workers(), 1);
    }

    #[test]
    fn panicking_worker_releases_its_lease() {
        // A shard/batch worker that panics while holding a lease must
        // not leak it: `PoolLease` releases on unwind, so the ledger
        // returns to zero once the panic has propagated.
        let b = WorkerBudget::new(4);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _lease = b.lease(4);
            assert_eq!(b.leased(), 3);
            panic!("worker died mid-superstep");
        }));
        assert!(outcome.is_err());
        assert_eq!(b.leased(), 0, "unwind must return every permit");
        // the budget stays fully usable after the panic
        assert_eq!(b.lease(4).workers(), 4);
    }

    #[test]
    fn panic_in_a_scoped_worker_thread_releases_its_lease() {
        // Same invariant across a thread boundary: the engine's pools
        // lease inside `std::thread::scope` workers, and a panic there
        // resurfaces at the scope join. The lease must already be back.
        let b = WorkerBudget::new(4);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            std::thread::scope(|scope| {
                scope.spawn(|| {
                    let _lease = b.lease(3);
                    panic!("shard worker died");
                });
            });
        }));
        assert!(outcome.is_err(), "scope join must propagate the worker panic");
        assert_eq!(b.leased(), 0, "the dead worker's lease must not leak");
        assert_eq!(b.lease(2).workers(), 2);
    }

    #[test]
    fn concurrent_leases_never_exceed_the_limit() {
        let b = WorkerBudget::new(5);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for want in [1usize, 2, 3, 7] {
                        let lease = b.lease(want);
                        assert!(b.leased() <= b.total_workers() - 1);
                        assert!(lease.workers() <= want.max(1));
                        drop(lease);
                    }
                });
            }
        });
        assert_eq!(b.leased(), 0);
        assert!(b.peak_leased() <= 4);
    }
}
