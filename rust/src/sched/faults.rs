//! Query deadlines and deterministic fault injection.
//!
//! Two small primitives every layer of the fault-tolerant query core
//! leases from (the robustness analogue of [`super::budget::WorkerBudget`]):
//!
//! * [`Deadline`] — a wall-clock budget carried on
//!   `RunOptions`/the wire (`deadline_us`), checked cooperatively at
//!   superstep boundaries and transfer commits. Expiry is a **typed**
//!   [`DeadlineExceeded`] with partial accounting (supersteps completed,
//!   elapsed), never a silent hang.
//! * [`FaultPlan`] — a seeded schedule of injected faults for chaos
//!   testing. A fault decision is a **pure function of
//!   `(seed, seam, token)`** — no mutable hit counters — so the same
//!   plan string produces the same fault sequence regardless of thread
//!   interleaving, worker count, or batch composition. Same seed → same
//!   faults → reproducible chaos tests.
//!
//! # Fault-plan grammar
//!
//! ```text
//! plan  := [ "seed=" u64 ";" ] rule { ";" rule }
//! rule  := kind "@" seam [ "#" token | "%" modulus ] [ "~" millis ]
//! kind  := panic | exec_fail | transfer_error | compile_fail | slow
//! seam  := compile | exec | superstep | commit | shard
//! token := u64 | identifier        (identifiers hash via token_of_name)
//! ```
//!
//! * a bare rule fires on **every** hit of its seam;
//! * `#token` fires when the seam's token matches exactly (the exec
//!   seam's token is [`exec_token`]`(root, attempt)`, so `#root` hits
//!   attempt 0 only and a retry re-runs clean);
//! * `%modulus` fires pseudo-randomly on ~1/modulus of hits, derived
//!   from `mix(seed ^ seam ^ token)`;
//! * `~millis` sets the sleep for `slow` faults (wall-clock only —
//!   modeled report fields are never perturbed).
//!
//! Example: `seed=7;panic@exec#41;transfer_error@commit%13;slow@superstep%50~3`.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Environment variable read by [`FaultPlan::from_env`] (and honored by
/// `jgraph serve` when `--fault-plan` is absent).
pub const FAULT_PLAN_ENV: &str = "JGRAPH_FAULT_PLAN";

// ---------------------------------------------------------------------------
// Deadlines
// ---------------------------------------------------------------------------

/// A per-query wall-clock budget. Cheap to copy, checked cooperatively
/// (superstep boundaries, transfer commits) — expiry yields a typed
/// [`DeadlineExceeded`] carrying partial accounting.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    at: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn in_duration(budget: Duration) -> Self {
        let start = Instant::now();
        // saturate absurd budgets (u64::MAX µs overflows Instant math)
        let at = start.checked_add(budget).unwrap_or(start + Duration::from_secs(86_400 * 365));
        Deadline { start, at }
    }

    /// Has the budget elapsed?
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }

    /// Time left before expiry (zero once expired).
    pub fn remaining(&self) -> Duration {
        self.at.saturating_duration_since(Instant::now())
    }

    /// The typed expiry error, stamped with what completed before it.
    pub fn exceeded(&self, supersteps_completed: u32) -> DeadlineExceeded {
        DeadlineExceeded {
            supersteps_completed,
            elapsed: self.start.elapsed(),
            budget: self.at.saturating_duration_since(self.start),
        }
    }

    /// Cooperative check: `Err(DeadlineExceeded)` once expired.
    pub fn check(&self, supersteps_completed: u32) -> Result<(), DeadlineExceeded> {
        if self.expired() {
            Err(self.exceeded(supersteps_completed))
        } else {
            Ok(())
        }
    }
}

/// Typed deadline expiry with partial accounting — downcastable through
/// `anyhow` so the serve layer can map it to a `deadline_exceeded` wire
/// reject instead of a generic execution failure.
#[derive(Debug, Clone)]
pub struct DeadlineExceeded {
    /// Supersteps that completed before the budget ran out.
    pub supersteps_completed: u32,
    /// Wall-clock time the query had been running.
    pub elapsed: Duration,
    /// The budget the query was admitted with.
    pub budget: Duration,
}

impl fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "deadline exceeded after {} supersteps ({:.0} us elapsed of a {:.0} us budget)",
            self.supersteps_completed,
            self.elapsed.as_secs_f64() * 1e6,
            self.budget.as_secs_f64() * 1e6,
        )
    }
}

impl std::error::Error for DeadlineExceeded {}

// ---------------------------------------------------------------------------
// Fault kinds, seams, tokens
// ---------------------------------------------------------------------------

/// What an injected fault does at its seam.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// `panic!` at the seam (caught by the nearest isolation fence).
    Panic,
    /// A typed, transient execution error (retryable).
    ExecFail,
    /// A typed, transient transfer/commit error (retryable).
    TransferError,
    /// A persistent compile failure (keyed by algorithm-name token).
    CompileFail,
    /// A wall-clock sleep — latency only, modeled results untouched.
    Slow,
}

impl FaultKind {
    /// Every kind, in stable counter order.
    pub const ALL: [FaultKind; 5] = [
        FaultKind::Panic,
        FaultKind::ExecFail,
        FaultKind::TransferError,
        FaultKind::CompileFail,
        FaultKind::Slow,
    ];

    /// The grammar keyword for this kind.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::ExecFail => "exec_fail",
            FaultKind::TransferError => "transfer_error",
            FaultKind::CompileFail => "compile_fail",
            FaultKind::Slow => "slow",
        }
    }

    fn index(&self) -> usize {
        match self {
            FaultKind::Panic => 0,
            FaultKind::ExecFail => 1,
            FaultKind::TransferError => 2,
            FaultKind::CompileFail => 3,
            FaultKind::Slow => 4,
        }
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "panic" => FaultKind::Panic,
            "exec_fail" => FaultKind::ExecFail,
            "transfer_error" => FaultKind::TransferError,
            "compile_fail" => FaultKind::CompileFail,
            "slow" => FaultKind::Slow,
            other => bail!(
                "unknown fault kind {other:?} (panic|exec_fail|transfer_error|compile_fail|slow)"
            ),
        })
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A named seam where faults can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Seam {
    /// Pipeline compile (token: [`token_of_name`] of the algorithm).
    Compile,
    /// Query execution start (token: [`exec_token`]`(root, attempt)`).
    Exec,
    /// Superstep boundary (token: superstep index).
    Superstep,
    /// Transfer commit (token: [`exec_token`]`(root, attempt)`, so
    /// `#root` commit faults hit attempt 0 only and a retry commits).
    Commit,
    /// Shard worker, inside its isolation fence (token:
    /// [`shard_token`]`(root, shard)`).
    Shard,
}

impl Seam {
    /// The grammar keyword for this seam.
    pub fn name(&self) -> &'static str {
        match self {
            Seam::Compile => "compile",
            Seam::Exec => "exec",
            Seam::Superstep => "superstep",
            Seam::Commit => "commit",
            Seam::Shard => "shard",
        }
    }

    fn tag(&self) -> u64 {
        // arbitrary distinct constants folded into the decision hash so
        // the same token behaves independently at different seams
        match self {
            Seam::Compile => 0x636f_6d70,
            Seam::Exec => 0x6578_6563,
            Seam::Superstep => 0x7375_7072,
            Seam::Commit => 0x636f_6d6d,
            Seam::Shard => 0x7368_6172,
        }
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "compile" => Seam::Compile,
            "exec" => Seam::Exec,
            "superstep" => Seam::Superstep,
            "commit" => Seam::Commit,
            "shard" => Seam::Shard,
            other => bail!("unknown fault seam {other:?} (compile|exec|superstep|commit|shard)"),
        })
    }
}

impl fmt::Display for Seam {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// splitmix64 finalizer — the pure decision hash behind `%modulus` rules.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Stable hash of a name into a fault token (`#wcc` in the grammar).
pub fn token_of_name(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The exec seam's token: `#root` rules hit attempt 0 only, so a retried
/// query naturally re-runs clean — no per-rule mutable state needed.
pub fn exec_token(root: u32, attempt: u32) -> u64 {
    root as u64 | ((attempt as u64) << 32)
}

/// The shard seam's token: one `(root, shard)` pair per worker dispatch.
pub fn shard_token(root: u32, shard: usize) -> u64 {
    root as u64 | ((shard as u64) << 32)
}

/// Deterministic retry backoff: `base * 2^attempt` plus a seeded jitter
/// of up to one `base`, pure in `(seed, root, attempt)` — so a chaos
/// test replays the exact same waits the daemon took. The exponent is
/// clamped so absurd attempt counts saturate instead of overflowing.
pub fn retry_backoff(seed: u64, root: u32, attempt: u32, base: Duration) -> Duration {
    let scaled = base.saturating_mul(1u32 << attempt.min(16));
    let span_us = base.as_micros().min(u64::MAX as u128) as u64;
    let jitter_us = if span_us == 0 { 0 } else { mix(seed ^ exec_token(root, attempt)) % span_us };
    scaled.saturating_add(Duration::from_micros(jitter_us))
}

// ---------------------------------------------------------------------------
// The plan
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Selector {
    Always,
    Token(u64),
    Modulus(u64),
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct Rule {
    kind: FaultKind,
    seam: Seam,
    selector: Selector,
    slow: Duration,
}

/// A decided fault at a seam.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    /// What fires.
    pub kind: FaultKind,
    /// Sleep duration for [`FaultKind::Slow`] (the `~millis` suffix).
    pub slow: Duration,
}

/// A seeded, deterministic fault schedule. See the module docs for the
/// grammar. Decisions are pure functions of `(seed, seam, token)`;
/// only the injection **counters** are mutable (relaxed atomics,
/// surfaced through the serve `stats` op).
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<Rule>,
    source: String,
    injected: [AtomicU64; FaultKind::ALL.len()],
}

impl FaultPlan {
    /// Parse a plan string (see the module-level grammar).
    pub fn parse(plan: &str) -> Result<FaultPlan> {
        let mut seed = 42u64;
        let mut rules = Vec::new();
        for (i, raw) in plan.split(';').enumerate() {
            let part = raw.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(value) = part.strip_prefix("seed=") {
                if i != 0 {
                    bail!("fault plan: seed= must be the first segment, got {part:?}");
                }
                seed = value.trim().parse().with_context(|| format!("fault plan seed {value:?}"))?;
                continue;
            }
            rules.push(Self::parse_rule(part)?);
        }
        if rules.is_empty() {
            bail!("fault plan {plan:?} declares no rules");
        }
        Ok(FaultPlan {
            seed,
            rules,
            source: plan.to_string(),
            injected: Default::default(),
        })
    }

    fn parse_rule(part: &str) -> Result<Rule> {
        let (mut head, slow) = match part.split_once('~') {
            Some((head, ms)) => {
                let ms: u64 =
                    ms.trim().parse().with_context(|| format!("fault rule {part:?}: ~millis"))?;
                (head.trim(), Duration::from_millis(ms))
            }
            None => (part, Duration::from_millis(2)),
        };
        let mut selector = Selector::Always;
        if let Some((h, tok)) = head.split_once('#') {
            selector = Selector::Token(match tok.trim().parse::<u64>() {
                Ok(n) => n,
                Err(_) => token_of_name(tok.trim()),
            });
            head = h;
        } else if let Some((h, m)) = head.split_once('%') {
            let m: u64 =
                m.trim().parse().with_context(|| format!("fault rule {part:?}: %modulus"))?;
            if m == 0 {
                bail!("fault rule {part:?}: %modulus must be >= 1");
            }
            selector = Selector::Modulus(m);
            head = h;
        }
        let (kind, seam) = head
            .split_once('@')
            .with_context(|| format!("fault rule {part:?}: expected kind@seam"))?;
        Ok(Rule {
            kind: FaultKind::parse(kind.trim())?,
            seam: Seam::parse(seam.trim())?,
            selector,
            slow,
        })
    }

    /// Parse `$JGRAPH_FAULT_PLAN` if set (`Ok(None)` when unset).
    pub fn from_env() -> Result<Option<Arc<FaultPlan>>> {
        match std::env::var(FAULT_PLAN_ENV) {
            Ok(plan) if !plan.trim().is_empty() => {
                Ok(Some(Arc::new(Self::parse(&plan).with_context(|| {
                    format!("parsing {FAULT_PLAN_ENV}")
                })?)))
            }
            _ => Ok(None),
        }
    }

    /// The plan string this was parsed from.
    pub fn source(&self) -> &str {
        &self.source
    }

    /// The determinism seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Decide whether a fault fires at `(seam, token)` — a pure function
    /// of the plan and its arguments (first matching rule wins), plus a
    /// relaxed counter bump when one does.
    pub fn decide(&self, seam: Seam, token: u64) -> Option<Fault> {
        for rule in self.rules.iter().filter(|r| r.seam == seam) {
            let hit = match rule.selector {
                Selector::Always => true,
                Selector::Token(t) => token == t,
                Selector::Modulus(m) => mix(self.seed ^ seam.tag() ^ token) % m == 0,
            };
            if hit {
                self.injected[rule.kind.index()].fetch_add(1, Ordering::Relaxed);
                return Some(Fault { kind: rule.kind, slow: rule.slow });
            }
        }
        None
    }

    /// Decide **and act**: sleep on `slow`, `panic!` on `panic` (for the
    /// nearest isolation fence to catch), return a typed
    /// [`InjectedFault`] for the error kinds.
    pub fn trip(&self, seam: Seam, token: u64) -> Result<(), InjectedFault> {
        let Some(fault) = self.decide(seam, token) else {
            return Ok(());
        };
        match fault.kind {
            FaultKind::Slow => {
                std::thread::sleep(fault.slow);
                Ok(())
            }
            FaultKind::Panic => panic!("{}", InjectedFault { kind: FaultKind::Panic, seam }),
            kind => Err(InjectedFault { kind, seam }),
        }
    }

    /// Faults injected so far, by kind (stable [`FaultKind::ALL`] order).
    pub fn injected_by_kind(&self) -> [(FaultKind, u64); FaultKind::ALL.len()] {
        let mut out = [(FaultKind::Panic, 0); FaultKind::ALL.len()];
        for (slot, kind) in out.iter_mut().zip(FaultKind::ALL) {
            *slot = (kind, self.injected[kind.index()].load(Ordering::Relaxed));
        }
        out
    }

    /// Total faults injected so far.
    pub fn injected_total(&self) -> u64 {
        self.injected.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

// ---------------------------------------------------------------------------
// Typed fault errors
// ---------------------------------------------------------------------------

/// A typed injected-fault error, downcastable through `anyhow` so the
/// retry policy can tell transient injected failures from real ones.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    /// The fault kind that fired.
    pub kind: FaultKind,
    /// Where it fired.
    pub seam: Seam,
}

impl InjectedFault {
    /// Is this fault worth retrying? (Exec/transfer faults are keyed by
    /// attempt, so a retry re-rolls; compile faults are persistent.)
    pub fn transient(&self) -> bool {
        matches!(self.kind, FaultKind::ExecFail | FaultKind::TransferError)
    }
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected fault: {}@{}", self.kind, self.seam)
    }
}

impl std::error::Error for InjectedFault {}

/// A shard worker died mid-superstep (real bug or injected panic). The
/// whole query fails typed — partial shard scratch can never be merged
/// bit-identically — while sibling queries in the sweep are untouched.
#[derive(Debug, Clone)]
pub struct WorkerPanic {
    /// Which shard's worker panicked.
    pub shard: usize,
    /// The stringified panic payload.
    pub message: String,
}

impl fmt::Display for WorkerPanic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard worker {} panicked: {}", self.shard, self.message)
    }
}

impl std::error::Error for WorkerPanic {}

/// Render a caught panic payload (`Box<dyn Any>`) as a message.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_parses_every_form() {
        let plan = FaultPlan::parse(
            "seed=7;panic@exec#41;transfer_error@commit%13;slow@superstep%50~3;compile_fail@compile#wcc;exec_fail@shard",
        )
        .unwrap();
        assert_eq!(plan.seed(), 7);
        assert_eq!(plan.rules.len(), 5);
        assert_eq!(plan.rules[0].selector, Selector::Token(41));
        assert_eq!(plan.rules[1].selector, Selector::Modulus(13));
        assert_eq!(plan.rules[2].slow, Duration::from_millis(3));
        assert_eq!(plan.rules[3].selector, Selector::Token(token_of_name("wcc")));
        assert_eq!(plan.rules[4].selector, Selector::Always);
    }

    #[test]
    fn grammar_rejects_malformed_rules() {
        for bad in [
            "",
            "panic@nowhere",
            "meteor@exec",
            "panic@exec%0",
            "panic@exec~lots",
            "panic",
            "exec_fail@exec;seed=3",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    /// The determinism contract: the same plan string replayed over the
    /// same token sequence yields the identical fault sequence, counters
    /// included — decisions are pure in (seed, seam, token).
    #[test]
    fn same_seed_produces_identical_fault_sequence() {
        let src = "seed=99;panic@exec%17;transfer_error@commit%29;slow@superstep%7~1";
        let a = FaultPlan::parse(src).unwrap();
        let b = FaultPlan::parse(src).unwrap();
        let seams = [Seam::Exec, Seam::Commit, Seam::Superstep, Seam::Shard];
        let decisions = |plan: &FaultPlan| {
            let mut out = Vec::new();
            for &seam in &seams {
                for token in 0..4096u64 {
                    out.push(plan.decide(seam, token));
                }
            }
            out
        };
        let da = decisions(&a);
        assert_eq!(da, decisions(&b), "same plan must replay the same fault sequence");
        assert!(da.iter().flatten().count() > 100, "moduli must actually fire");
        assert_eq!(a.injected_total(), b.injected_total());
        // and a different seed reshuffles the modulus hits
        let c = FaultPlan::parse(&src.replace("seed=99", "seed=100")).unwrap();
        assert_ne!(da, decisions(&c), "a different seed must reshuffle modulus rules");
    }

    #[test]
    fn exec_token_keys_faults_to_the_first_attempt() {
        let plan = FaultPlan::parse("exec_fail@exec#41").unwrap();
        assert!(plan.decide(Seam::Exec, exec_token(41, 0)).is_some());
        assert!(plan.decide(Seam::Exec, exec_token(41, 1)).is_none(), "retry re-runs clean");
        assert!(plan.decide(Seam::Exec, exec_token(40, 0)).is_none(), "other roots untouched");
        assert!(plan.decide(Seam::Commit, exec_token(41, 0)).is_none(), "other seams untouched");
        assert_eq!(plan.injected_total(), 1);
        assert_eq!(plan.injected_by_kind()[FaultKind::ExecFail.index()].1, 1);
    }

    #[test]
    fn trip_maps_kinds_to_behaviours() {
        let plan = FaultPlan::parse("exec_fail@exec#1;slow@superstep#2~1").unwrap();
        let err = plan.trip(Seam::Exec, 1).unwrap_err();
        assert_eq!(err.kind, FaultKind::ExecFail);
        assert!(err.transient());
        plan.trip(Seam::Superstep, 2).unwrap(); // sleeps, then Ok
        plan.trip(Seam::Superstep, 3).unwrap(); // no rule, no-op
        let panicking = FaultPlan::parse("panic@exec#9").unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = panicking.trip(Seam::Exec, 9);
        }));
        let payload = caught.unwrap_err();
        assert!(panic_message(payload.as_ref()).contains("injected fault: panic@exec"));
    }

    #[test]
    fn retry_backoff_is_deterministic_and_scales_with_attempt() {
        let base = Duration::from_millis(2);
        let a = retry_backoff(7, 41, 1, base);
        assert_eq!(a, retry_backoff(7, 41, 1, base), "pure in (seed, root, attempt)");
        assert!(a >= base * 2 && a < base * 3, "{a:?}");
        assert!(retry_backoff(7, 41, 2, base) >= base * 4, "exponential in attempt");
        assert_eq!(retry_backoff(7, 41, 3, Duration::ZERO), Duration::ZERO);
    }

    #[test]
    fn deadline_checks_and_partial_accounting() {
        let d = Deadline::in_duration(Duration::from_secs(3600));
        assert!(!d.expired());
        d.check(3).unwrap();
        let expired = Deadline::in_duration(Duration::ZERO);
        let err = expired.check(5).unwrap_err();
        assert_eq!(err.supersteps_completed, 5);
        let msg = err.to_string();
        assert!(msg.contains("deadline exceeded after 5 supersteps"), "{msg}");
        // absurd budgets saturate instead of panicking
        let far = Deadline::in_duration(Duration::from_micros(u64::MAX));
        assert!(!far.expired());
    }
}
