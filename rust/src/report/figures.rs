//! Figures 1 and 5: the qualitative environment comparison and the
//! development-cost breakdown, rendered as ASCII (plus CSV rows for
//! plotting).

use anyhow::Result;

use crate::dsl::algorithms;
use crate::engine::{RunOptions, Session, SessionConfig};
use crate::graph::generate;
use crate::prep::prepared::PrepOptions;
use crate::translator::{Translator, TranslatorKind};

/// Figure 1 — development approaches: programming cost vs performance.
/// The paper plots four quadrants; we annotate ours with measured numbers.
pub fn fig1_environments() -> String {
    let mut s = String::from(
        "Figure 1: graph programming environments on FPGA (cost vs performance)\n\
         \n\
           performance\n\
           ^\n\
           |  [graph accelerators]        [JGraph: DSL + light translator]\n\
           |   high perf, months of        high perf, minutes to program,\n\
           |   expert RTL work             seconds to translate\n\
           |\n\
           |  [general HLS tools]         [CPU graph frameworks]\n\
           |   middling perf, hours         low perf, minutes\n\
           |   of pragma tuning\n\
           +-------------------------------------------------> ease of programming\n\n",
    );
    // measured annotation
    let p = algorithms::bfs();
    let d = Translator::jgraph().translate(&p).unwrap();
    s += &format!(
        "measured: translate {:.3} ms, {} HDL lines, {} DSL interfaces available\n",
        d.translate_seconds * 1e3,
        d.hdl_lines,
        crate::dsl::registry::interface_count()
    );
    s
}

/// One Fig. 5 bar: the three development-cost periods for one tool.
#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub tool: &'static str,
    /// Program preparation (authoring + graph preprocessing), seconds.
    pub preparation: f64,
    /// System compilation (translate + synthesis), seconds.
    pub compilation: f64,
    /// Environment deployment (flash + transport), seconds.
    pub deployment: f64,
}

impl Fig5Row {
    pub fn total(&self) -> f64 {
        self.preparation + self.compilation + self.deployment
    }
}

/// Authoring-effort model (seconds) per flow: the human side of the
/// preparation period the paper describes ("variable time of manpower").
/// DSL authoring is minutes; C+pragma tuning and Spatial template work
/// are hours — scaled here to the paper's relative bar heights.
fn authoring_seconds(kind: TranslatorKind) -> f64 {
    match kind {
        TranslatorKind::JGraph => 60.0 * 5.0,      // 5 min: pick template, set params
        TranslatorKind::VivadoHls => 60.0 * 45.0,  // 45 min: C kernel + pragmas
        TranslatorKind::Spatial => 60.0 * 30.0,    // 30 min: Spatial templates
    }
}

/// Figure 5 — measured + modeled development-cost periods for the three
/// flows on the small evaluation graph (BFS).
pub fn fig5_devcost() -> Result<(String, Vec<Fig5Row>)> {
    let program = algorithms::bfs();
    let graph = generate::email_eu_core_like(42);
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let mut rows = Vec::new();
    for kind in TranslatorKind::all() {
        let compiled = session.compile_with(Translator::of_kind(kind), &program)?;
        let mut bound = compiled.load(&graph, PrepOptions::named("email-Eu-core"))?;
        let r = bound.run(&RunOptions::default())?;
        rows.push(Fig5Row {
            tool: kind.label(),
            preparation: authoring_seconds(kind) + r.prep_seconds,
            compilation: r.compile_seconds,
            deployment: r.deploy_seconds,
        });
    }
    let mut s = String::from(
        "Figure 5: development cost for programming on FPGA (three periods)\n\n",
    );
    let max = rows.iter().map(Fig5Row::total).fold(0.0, f64::max);
    for r in &rows {
        let bar = |v: f64| "#".repeat(((v / max) * 48.0).ceil() as usize);
        s += &format!("{:>10} | prep  {:>8.1}s {}\n", r.tool, r.preparation, bar(r.preparation));
        s += &format!("{:>10} | comp  {:>8.1}s {}\n", "", r.compilation, bar(r.compilation));
        s += &format!("{:>10} | depl  {:>8.1}s {}\n", "", r.deployment, bar(r.deployment));
        s += &format!("{:>10} | total {:>8.1}s\n\n", "", r.total());
    }
    s += "csv: tool,preparation_s,compilation_s,deployment_s,total_s\n";
    for r in &rows {
        s += &format!(
            "csv: {},{:.2},{:.2},{:.2},{:.2}\n",
            r.tool,
            r.preparation,
            r.compilation,
            r.deployment,
            r.total()
        );
    }
    Ok((s, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_renders_with_measurement() {
        let s = fig1_environments();
        assert!(s.contains("JGraph"));
        assert!(s.contains("measured: translate"));
    }

    #[test]
    fn fig5_jgraph_cheapest_overall() {
        let (s, rows) = fig5_devcost().unwrap();
        assert!(s.contains("Figure 5"));
        let total = |label: &str| {
            rows.iter().find(|r| r.tool == label).unwrap().total()
        };
        // the paper's point: our flow reduces development + compile cost
        assert!(total("FAgraph") < total("Vivado HLS"));
        assert!(total("FAgraph") < total("Spatial"));
        // every flow's compile period dominates its deployment period
        for r in &rows {
            assert!(r.compilation > 0.0 && r.deployment > 0.0);
        }
    }
}
