//! Report layer: regenerates every table and figure of the paper's
//! evaluation from the live system (DESIGN.md §5 per-experiment index).
//! Survey tables (I–III) are static comparative data the paper compiled
//! from the literature; measured tables (IV, V) and figures (1, 5) are
//! computed by running the translators/simulator/engine.

pub mod figures;
pub mod tables;

pub use figures::{fig1_environments, fig5_devcost, Fig5Row};
pub use tables::{table1, table2, table3, table4, table5, Table5Row};

/// Render a list of rows as a fixed-width text table (CLI + bench output).
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let sep: String =
        widths.iter().map(|w| format!("+{}", "-".repeat(w + 2))).collect::<String>() + "+";
    let mut out = format!("{title}\n{sep}\n");
    let render_row = |cells: &[String]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            line += &format!("| {:width$} ", c, width = widths[i]);
        }
        line + "|"
    };
    out += &render_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out += &format!("\n{sep}\n");
    for row in rows {
        out += &render_row(row);
        out += "\n";
    }
    out + &sep + "\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let t = render_table(
            "T",
            &["a", "bbbb"],
            &[vec!["xx".into(), "y".into()], vec!["1".into(), "22222".into()]],
        );
        assert!(t.contains("| xx | y     |"));
        assert!(t.lines().all(|l| l.len() <= 80));
    }
}
