//! Tables I–V. The survey tables reproduce the paper's text; Table IV
//! counts the live registry; Table V actually runs the three translators
//! through the engine + simulator on the two evaluation graphs.

use anyhow::Result;

use crate::dsl::{algorithms, registry};
use crate::engine::{DirectionPolicy, RunOptions, Session, SessionConfig};
use crate::graph::edgelist::EdgeList;
use crate::graph::generate;
use crate::prep::prepared::PrepOptions;
use crate::translator::{Translator, TranslatorKind};

use super::render_table;

/// Table I — graph applications and algorithms (survey, verbatim).
pub fn table1() -> String {
    render_table(
        "Table I: graph processing applications and algorithms",
        &["Application", "Vertices", "Edges", "Algorithms"],
        &[
            vec!["Social network".into(), "individual".into(), "friendship".into(), "PR/BFS/DFS".into()],
            vec!["Electronic commerce".into(), "customer".into(), "transaction".into(), "BC/TC/SSSP".into()],
            vec!["Telecommunication".into(), "phone".into(), "conversation".into(), "SSSP/MM".into()],
            vec!["Supply chain".into(), "supplier".into(), "channel".into(), "DFS/BFS/SSSP".into()],
        ],
    )
}

/// Table II — languages on FPGAs with PD / TT / RTL estimates (survey,
/// verbatim), with our measured row appended.
pub fn table2() -> String {
    let mut rows: Vec<Vec<String>> = [
        ("HDL", "Verilog/VHDL", "all", "hard", "short", "high"),
        ("HDL", "SystemC", "all", "hard", "short", "high"),
        ("HDL", "OpenCL", "all", "hard", "short", "high"),
        ("HDL-like", "Chisel", "all", "middle", "middle", "poor"),
        ("High-level", "Vivado HLS", "all", "easy", "middle", "poor"),
        ("High-level", "Spatial", "all", "middle", "long", "middle"),
        ("High-level", "GraphIt (C)", "graph", "easy", "-", "-"),
        ("High-level", "Falcon (C)", "graph", "easy", "-", "-"),
        ("Graph accel", "Graphgen", "graph", "-", "short", "high"),
        ("Graph accel", "GraVF", "graph", "-", "short", "high"),
        ("Graph accel", "GraphSoC", "graph", "-", "short", "high"),
        ("Graph accel", "Graphicionado", "graph", "-", "short", "high"),
    ]
    .iter()
    .map(|r| vec![r.0.into(), r.1.into(), r.2.into(), r.3.into(), r.4.into(), r.5.into()])
    .collect();
    // our row: measured TT (translate is sub-millisecond; report "short")
    rows.push(vec![
        "Graph DSL".into(),
        "JGraph (this work)".into(),
        "graph".into(),
        "easy".into(),
        "short".into(),
        "high".into(),
    ]);
    render_table(
        "Table II: languages on FPGAs (PD=programming difficulty, TT=translate time, RTL=code perf)",
        &["Type", "Language", "Field", "PD", "TT", "RTL"],
        &rows,
    )
}

/// Table III — programmable interfaces of FPGA graph frameworks (survey).
pub fn table3() -> String {
    let rows: Vec<Vec<String>> = [
        ("GraphGen'14", "single FPGA", "app-specific graph", "update-function(v)"),
        ("GraphSoc'15", "single FPGA (multi-PE)", "SpMV etc.", "SND,RSV,ACCU,UPD + comm ISA"),
        ("GraVF'16", "single FPGA", "basic", "Apply, Scatter"),
        ("Graphicionado'16", "single FPGA", "collab. filtering etc.", "Reduce(v,r), Apply(v), Process_Edge"),
        ("GraphOps'16", "single FPGA (library)", "SpMV/conduct/vcover", "Data/Control/Utility blocks"),
        ("FPGP'16", "single FPGA", "BFS", "BFS_kernel, data control, mem ctrl"),
        ("HitGraph'19", "single FPGA", "SpMV/WCC", "Apply_update, Process_edge"),
        ("Graphlet'11", "off-chip storage", "graph counting", "graph PEs + interconnect + runtime"),
        ("GraFBoost'18", "flash storage", "BC etc.", "vertex_update, finalize, is_active, edge_program"),
        ("GPOP'19", "HBM2", "SpMV/WCC etc.", "algorithmic parameters"),
        ("ForeGraph'17", "multi-FPGA", "WCC etc.", "PEs + data/interconnect controllers"),
        ("GraVF-M'19", "multi-FPGA", "WCC etc.", "gather, apply, scatter kernels"),
        ("JGraph (this work)", "single FPGA (simulated)", "any GAS algorithm", "25+ interfaces, 3 levels"),
    ]
    .iter()
    .map(|r| vec![r.0.into(), r.1.into(), r.2.into(), r.3.into()])
    .collect();
    render_table(
        "Table III: programmable interfaces for graph processing on FPGA accelerators",
        &["Framework", "Platform", "Algorithms", "Interfaces"],
        &rows,
    )
}

/// Table IV — atomic-operator counts, computed from the live registry.
pub fn table4() -> String {
    let rows: Vec<Vec<String>> = registry::table4_rows()
        .into_iter()
        .map(|r| {
            vec![
                format!("{}'{}", r.system, r.year % 100),
                r.operator_count.to_string(),
                r.operators.split_whitespace().collect::<Vec<_>>().join(" "),
            ]
        })
        .collect();
    render_table(
        "Table IV: graph atomic operators vs accelerators/programming environments",
        &["Accelerator", "Num", "Graph atomic operators"],
        &rows,
    )
}

/// One measured Table V cell group.
#[derive(Debug, Clone)]
pub struct Table5Row {
    pub translator: &'static str,
    pub code_lines: usize,
    pub graph: String,
    pub rt_seconds: f64,
    pub mteps: f64,
}

/// The Table V configuration: which graphs, which generator seeds.
pub fn table5_graphs(small_only: bool) -> Vec<(String, EdgeList)> {
    let mut v = vec![("email-Eu-core (synthetic)".to_string(), generate::email_eu_core_like(42))];
    if !small_only {
        v.push(("soc-Slashdot0922 (synthetic)".to_string(), generate::soc_slashdot_like(42)));
    }
    v
}

/// Run Table V: BFS through all three translators on both graphs.
/// `use_xla=false` keeps it pure-simulation (benches); the CLI passes
/// true to also exercise the AOT path.
pub fn table5(use_xla: bool, small_only: bool) -> Result<(String, Vec<Table5Row>)> {
    let program = algorithms::bfs();
    let graphs = table5_graphs(small_only);
    let session = Session::new(SessionConfig { use_xla, ..Default::default() });
    let mut rows = Vec::new();
    for kind in TranslatorKind::all() {
        // compile once per flow; every graph binds against the same design
        let compiled = session.compile_with(Translator::of_kind(kind), &program)?;
        for (name, el) in &graphs {
            let mut bound = compiled.load(el, PrepOptions::named(name.clone()))?;
            // Reproduction fidelity: the paper's Table V models the push
            // schedule, so the table pins PushOnly. Direction-optimized
            // numbers are tracked in benches/engine_mteps.rs instead.
            let r = bound
                .run(&RunOptions::default().with_direction(DirectionPolicy::PushOnly))?;
            rows.push(Table5Row {
                translator: kind.label(),
                code_lines: r.hdl_lines,
                graph: name.clone(),
                rt_seconds: r.rt_seconds,
                mteps: r.simulated_mteps,
            });
        }
    }
    let text_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.translator.to_string(),
                r.code_lines.to_string(),
                r.graph.clone(),
                format!("{:.1}", r.rt_seconds),
                format!("{:.2}", r.mteps),
            ]
        })
        .collect();
    let table = render_table(
        "Table V: generated code efficiency and graph processing capability (BFS)",
        &["Work", "Code lines", "Graph", "RT(s)", "TP(MTEPS)"],
        &text_rows,
    );
    Ok((table, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn survey_tables_render() {
        for t in [table1(), table2(), table3(), table4()] {
            assert!(t.lines().count() > 5, "{t}");
        }
    }

    #[test]
    fn table4_contains_our_25_plus() {
        let t = table4();
        assert!(t.contains("FAgraph"));
        assert!(t.contains(&registry::interface_count().to_string()));
    }

    #[test]
    fn table5_small_ordering_holds() {
        // simulation-only, small graph: fast enough for unit tests
        let (_, rows) = table5(false, true).unwrap();
        assert_eq!(rows.len(), 3);
        let get = |label: &str| rows.iter().find(|r| r.translator == label).unwrap();
        let (j, v, s) = (get("FAgraph"), get("Vivado HLS"), get("Spatial"));
        // Table V shape: code lines FAgraph < Vivado < Spatial
        assert!(j.code_lines < v.code_lines && v.code_lines < s.code_lines);
        // throughput FAgraph > Vivado >> Spatial
        assert!(j.mteps > v.mteps && v.mteps > 4.0 * s.mteps);
        // running time FAgraph fastest
        assert!(j.rt_seconds < v.rt_seconds && j.rt_seconds < s.rt_seconds);
        // all in the "tens of seconds" regime
        assert!(rows.iter().all(|r| r.rt_seconds < 60.0));
    }
}
