//! Chisel intermediate stage. The paper (§III/§IV) uses Chisel as the
//! intermediate language: "We use Chisel, a state-of-the-art HDL language
//! with Scala as the intermediate language... there is a conversion from
//! Chisel HDL to Verilog HDL that can be executed on the FPGA."
//!
//! The light-weight flow therefore emits a Chisel module-generator first
//! ([`emit_chisel`]) and lowers it to the Verilog the FPGA consumes
//! ([`chisel_to_verilog`] — our stand-in for Chisel's FIRRTL pipeline,
//! structured the same way: elaborate the generator's parameters, then
//! print the flat module). Parity with the direct Verilog emitter is
//! enforced by tests: the converted output must have the same module
//! structure and line-count class.

use crate::dsl::program::{FrontierPolicy, GasProgram, ReduceOp, StateType};
use crate::sched::ParallelismPlan;

use super::codegen_hdl::{code_lines, emit_jgraph, sanitize};
use super::lower::alu_chain;

/// Emit the Chisel (Scala-embedded) generator for a translated design.
/// Fact-driven like the lowering: datapath-narrowed `ArgRegFile`, conflict
/// resolver only for non-idempotent reduces.
pub fn emit_chisel(program: &GasProgram, plan: &ParallelismPlan) -> String {
    let facts = crate::analysis::analyze(program);
    let name = sanitize(&program.name);
    let chain = alu_chain(&program.apply);
    let acc = match program.reduce {
        ReduceOp::Min => "AccOp.Min",
        ReduceOp::Max => "AccOp.Max",
        ReduceOp::Sum => "AccOp.Sum",
    };
    let dtype = match program.state {
        StateType::I32 => "SInt(32.W)",
        StateType::F32 => "FixedF32()",
    };
    let mut s = String::new();
    s += &format!("// jgraph Chisel generator for {} (apply = {})\n", program.name, program.apply.render());
    s += "import chisel3._\nimport jgraph.modules._\n\n";
    s += &format!(
        "class {}Top(val lanes: Int = {}, val pes: Int = {}) extends Module {{\n",
        name, plan.pipelines, plan.pes
    );
    s += "  val io = IO(new AcceleratorBundle)\n";
    s += "  val dma   = Module(new PcieDma)\n";
    s += "  val mem   = Module(new MemCtrl(channels = 4))\n";
    if !facts.datapath_params.is_empty() {
        // host-written per query: parameter names elaborate, values never
        // do — and only datapath-live names elaborate at all
        let names: Vec<String> =
            facts.datapath_params.iter().map(|n| format!("\"{n}\"")).collect();
        s += &format!("  val args  = Module(new ArgRegFile(Seq({})))\n", names.join(", "));
    }
    s += &format!("  val vbram = Module(new VertexBram({dtype}))\n");
    s += "  val vload = Module(new VertexLoader(vbram))\n";
    s += "  val off   = Module(new OffsetFetch(mem.port(0)))\n";
    if program.frontier == FrontierPolicy::Active {
        s += "  val fq    = Module(new FrontierQueue(off.rowReq))\n";
    }
    s += "  val lanesVec = Seq.tabulate(lanes * pes) { i =>\n";
    s += &format!(
        "    val f = Module(new EdgeFetch(weights = {}, mem.port(1)))\n",
        program.uses_weights
    );
    s += "    val g = Module(new Gather(f.out, vload.vals))\n";
    let mut prev = "g.out".to_string();
    for (k, op) in chain.iter().enumerate() {
        s += &format!("    val a{k} = Module(new ApplyAlu(AluOp.{}))\n", capitalize(op));
        s += &format!("    a{k}.in := {prev}\n");
        prev = format!("a{k}.out");
    }
    if facts.needs_conflict_unit() {
        s += &format!("    val cu = Module(new ConflictUnit({acc}))\n");
        s += &format!("    cu.in := {prev}\n");
        prev = "cu.out".to_string();
    }
    s += &format!("    val r = Module(new ReduceUnit({acc}, banks = 16))\n");
    s += &format!("    r.in := {prev}\n");
    s += "    val w = Module(new VertexWr(r.out, vbram))\n";
    s += "    w\n  }\n";
    s += "  io.status := Cat(mem.busy, 0.U(31.W))\n}\n";
    s
}

/// "FIRRTL" lowering: elaborate the Chisel generator and print Verilog.
/// Our stand-in elaborates the same design through the direct Verilog
/// emitter — structurally what chisel3's build does (generator in, flat
/// Verilog out) without the JVM.
pub fn chisel_to_verilog(program: &GasProgram, plan: &ParallelismPlan) -> ChiselBuild {
    let chisel = emit_chisel(program, plan);
    let t0 = std::time::Instant::now();
    let verilog = emit_jgraph(program, plan);
    ChiselBuild {
        chisel_lines: code_lines(&chisel),
        verilog_lines: code_lines(&verilog),
        chisel,
        verilog,
        elaborate_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// Result of the Chisel → Verilog stage.
#[derive(Debug, Clone)]
pub struct ChiselBuild {
    pub chisel: String,
    pub verilog: String,
    pub chisel_lines: usize,
    pub verilog_lines: usize,
    pub elaborate_seconds: f64,
}

fn capitalize(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;

    #[test]
    fn chisel_is_a_parameterized_generator() {
        let ch = emit_chisel(&algorithms::bfs(), &ParallelismPlan::default());
        assert!(ch.contains("class bfsTop(val lanes: Int = 8, val pes: Int = 1)"));
        assert!(ch.contains("Seq.tabulate(lanes * pes)"));
        assert!(ch.contains("FrontierQueue"), "BFS needs the frontier queue");
        // lane count is a parameter: generator size is lane-independent
        let ch16 = emit_chisel(&algorithms::bfs(), &ParallelismPlan::new(16, 2));
        assert_eq!(code_lines(&ch), code_lines(&ch16));
    }

    #[test]
    fn apply_chain_present_in_chisel() {
        let ch = emit_chisel(&algorithms::sssp(), &ParallelismPlan::default());
        assert!(ch.contains("ApplyAlu(AluOp.Add)"));
        let ch = emit_chisel(&algorithms::spmv(), &ParallelismPlan::default());
        assert!(ch.contains("ApplyAlu(AluOp.Mul)"));
        assert!(ch.contains("AccOp.Sum"));
    }

    #[test]
    fn conversion_produces_compact_verilog() {
        for p in algorithms::all() {
            let b = chisel_to_verilog(&p, &ParallelismPlan::default());
            // the Chisel generator and the flat Verilog are the same size
            // class (both instantiate the fixed module library)
            assert!(b.chisel_lines < 60, "{}: {}", p.name, b.chisel_lines);
            assert!(b.verilog_lines < 60, "{}: {}", p.name, b.verilog_lines);
            assert!(b.verilog.contains("module"));
            assert!(b.elaborate_seconds < 0.1);
        }
    }

    #[test]
    fn pagerank_has_no_frontier_queue_in_chisel() {
        let ch = emit_chisel(&algorithms::pagerank(), &ParallelismPlan::default());
        assert!(!ch.contains("FrontierQueue"));
        // datapath-narrowed register file: tolerance stays on the host
        assert!(ch.contains("ArgRegFile(Seq(\"damping\"))"), "{ch}");
        assert!(!ch.contains("tolerance"), "host-only params must not elaborate");
        assert!(!ch.contains("0.85"), "parameter values must not elaborate");
        // the non-idempotent reduce keeps its conflict resolver ...
        assert!(ch.contains("ConflictUnit(AccOp.Sum)"));
        // ... which idempotent designs elide
        let bfs = emit_chisel(&algorithms::bfs(), &ParallelismPlan::default());
        assert!(!bfs.contains("ConflictUnit"));
    }
}
