//! HDL code generation. The JGraph emitter produces the *compact*
//! module-instantiation style the paper credits for its small code size
//! (Table V: 35 lines for BFS vs 54 for Vivado HLS and 128 for Spatial):
//! pre-optimized modules are instantiated and wired, lanes come from a
//! `generate` loop, and no per-variable registers are spelled out.
//!
//! The baselines ([`super::baselines`]) emit the same design the way their
//! flows would: flattened loop-pipelined RTL (Vivado-HLS-like) and
//! fully-unrolled register-per-variable RTL (Spatial-like).

use crate::dsl::program::{FrontierPolicy, GasProgram, ReduceOp, StateType};
use crate::sched::ParallelismPlan;

use super::lower::alu_chain;

/// Emit compact Verilog for a lowered design (the light-weight flow).
/// Mirrors the fact-driven lowering ([`super::lower::lower`]): the
/// argument register file holds only datapath-live parameters and the
/// same-destination conflict resolver appears only for non-idempotent
/// reduces.
pub fn emit_jgraph(program: &GasProgram, plan: &ParallelismPlan) -> String {
    let facts = crate::analysis::analyze(program);
    let mut s = String::new();
    let name = sanitize(&program.name);
    let dtype = match program.state {
        StateType::I32 => "32'sd",
        StateType::F32 => "32'f",
    };
    let acc = match program.reduce {
        ReduceOp::Min => "MIN",
        ReduceOp::Max => "MAX",
        ReduceOp::Sum => "SUM",
    };
    let chain = alu_chain(&program.apply);

    s += &format!("// jgraph-generated design: {} (apply = {})\n", program.name, program.apply.render());
    s += &format!("module {name}_top #(\n");
    s += &format!("  parameter LANES = {},\n", plan.pipelines);
    s += &format!("  parameter PES = {},\n", plan.pes);
    s += &format!("  parameter ACC_OP = \"{acc}\"\n");
    s += ") (\n  input clk, input rst,\n";
    s += "  input  [511:0] ddr_rd_data, output [63:0] ddr_rd_addr,\n";
    s += "  output [511:0] ddr_wr_data, output [63:0] ddr_wr_addr,\n";
    s += "  input  [31:0] csr_cmd, output [31:0] csr_status\n);\n";
    s += "  wire [511:0] edge_stream [0:PES*LANES-1];\n";
    s += &format!("  wire [31:0] msg [0:PES*LANES-1]; // {dtype} messages\n");
    s += "  pcie_dma      u_dma   (.clk(clk), .rst(rst), .csr(csr_cmd));\n";
    s += "  mem_ctrl #(4) u_mem   (.clk(clk), .rd_addr(ddr_rd_addr), .rd_data(ddr_rd_data));\n";
    if !facts.datapath_params.is_empty() {
        // one register per *datapath-live* parameter, host-written per
        // query — names only: the emitted HDL is identical for every bound
        // value. Host-loop params (tolerance, max_depth) get no register.
        s += &format!(
            "  arg_regs #(.N({})) u_args (.clk(clk), .rst(rst), .wr_data(csr_cmd)); // runtime params: {}\n",
            facts.datapath_params.len(),
            facts.datapath_params.join(", ")
        );
    }
    s += "  vertex_bram   u_vbram (.clk(clk), .wr(wb_bus), .rd(vload_bus)); // state in URAM\n";
    s += "  vertex_loader u_vload (.clk(clk), .bram(vload_bus));\n";
    s += "  offset_fetch  u_off   (.clk(clk), .mem(u_mem.port0));\n";
    if program.frontier == FrontierPolicy::Active {
        s += "  frontier_q    u_fq    (.clk(clk), .push(wb_bus), .pop(u_off.row_req));\n";
    }
    s += "  genvar i;\n  generate for (i = 0; i < PES*LANES; i = i + 1) begin : lane\n";
    s += &format!(
        "    edge_fetch #(.W({})) f (.clk(clk), .off(u_off.rows), .mem(u_mem.port1), .out(edge_stream[i]));\n",
        program.uses_weights as u32
    );
    s += "    gather       g (.clk(clk), .edges(edge_stream[i]), .vals(u_vload.vals));\n";
    for (k, op) in chain.iter().enumerate() {
        s += &format!("    apply_alu #(.OP(\"{op}\")) a{k} (.clk(clk), .in(g.out), .out(msg[i]));\n");
    }
    if chain.is_empty() {
        s += "    assign msg[i] = g.out; // pass-through apply\n";
    }
    if facts.needs_conflict_unit() {
        s += "    conflict_unit #(.OP(ACC_OP)) c (.clk(clk), .in(msg[i])); // non-idempotent reduce\n";
        s += "    reduce_unit #(.OP(ACC_OP), .BANKS(16)) r (.clk(clk), .in(c.out), .wb(wb_bus));\n";
    } else {
        s += "    reduce_unit #(.OP(ACC_OP), .BANKS(16)) r (.clk(clk), .in(msg[i]), .wb(wb_bus));\n";
    }
    s += "    vertex_wr    w (.clk(clk), .in(r.out), .bram(wb_bus));\n";
    s += "  end endgenerate\n";
    s += "  assign csr_status = {u_mem.busy, 31'd0};\nendmodule\n";
    s
}

/// Identifier-safe module name.
pub fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Count non-empty, non-comment-only code lines — the Table V metric.
pub fn code_lines(src: &str) -> usize {
    src.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with("//"))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;

    #[test]
    fn bfs_hdl_is_compact() {
        let hdl = emit_jgraph(&algorithms::bfs(), &ParallelismPlan::default());
        let lines = code_lines(&hdl);
        // Table V: FAgraph generates 35 lines for BFS. Allow the
        // reproduction a small band around it.
        assert!(
            (25..=45).contains(&lines),
            "expected ~35 HDL lines, got {lines}:\n{hdl}"
        );
        assert!(hdl.contains("frontier_q"), "BFS needs the frontier queue");
        assert!(hdl.contains("vertex_bram"));
    }

    #[test]
    fn lane_count_is_parameter_not_unrolled() {
        // compactness comes from the generate loop: 8 lanes and 16 lanes
        // must produce identical line counts
        let a = emit_jgraph(&algorithms::bfs(), &ParallelismPlan::new(8, 1));
        let b = emit_jgraph(&algorithms::bfs(), &ParallelismPlan::new(16, 2));
        assert_eq!(code_lines(&a), code_lines(&b));
        assert!(b.contains("parameter LANES = 16"));
        assert!(b.contains("parameter PES = 2"));
    }

    #[test]
    fn apply_chain_emits_one_alu_per_op() {
        let hdl = emit_jgraph(&algorithms::sssp(), &ParallelismPlan::default());
        assert_eq!(hdl.matches("apply_alu").count(), 1); // src + w
        assert!(hdl.contains("OP(\"add\")"));
        let pr = emit_jgraph(&algorithms::pagerank(), &ParallelismPlan::default());
        assert!(pr.contains("pass-through apply")); // bare src gather
    }

    #[test]
    fn runtime_params_become_registers_never_literals() {
        let pr = emit_jgraph(&algorithms::pagerank(), &ParallelismPlan::default());
        assert!(pr.contains("arg_regs"), "parameterized design needs the register file");
        // analyzer-narrowed layout: tolerance is host-loop state
        assert!(pr.contains("runtime params: damping"));
        assert!(!pr.contains("tolerance"), "host-only params cost no registers");
        assert!(pr.contains(".N(1)"), "one register: damping only");
        assert!(!pr.contains("0.85"), "parameter values must not leak into HDL");
        // closed programs carry no register file
        let wcc = emit_jgraph(&algorithms::wcc(), &ParallelismPlan::default());
        assert!(!wcc.contains("arg_regs"));
        // ... and neither do programs whose params are all host-consumed
        let bfs = emit_jgraph(&algorithms::bfs(), &ParallelismPlan::default());
        assert!(!bfs.contains("arg_regs"), "max_depth lives in the host loop");
    }

    #[test]
    fn conflict_unit_emitted_only_for_non_idempotent_reduces() {
        let pr = emit_jgraph(&algorithms::pagerank(), &ParallelismPlan::default());
        assert!(pr.contains("conflict_unit"), "Sum reduce needs the resolver");
        assert!(pr.contains(".in(c.out)"), "reduce consumes the resolved stream");
        for p in [algorithms::bfs(), algorithms::wcc(), algorithms::sssp()] {
            let hdl = emit_jgraph(&p, &ParallelismPlan::default());
            assert!(!hdl.contains("conflict_unit"), "{}: idempotent reduce elides it", p.name);
            assert!(hdl.contains(".in(msg[i])"));
        }
    }

    #[test]
    #[allow(deprecated)]
    fn hdl_is_identical_across_parameter_values() {
        // the artifact-cache story: any pre-bound defaults produce the
        // same emitted design and the same sanitized kernel name
        let a = emit_jgraph(&algorithms::pagerank_with(0.85, 1e-6), &ParallelismPlan::default());
        let b = emit_jgraph(&algorithms::pagerank_with(0.95, 1e-9), &ParallelismPlan::default());
        assert_eq!(a, b);
        assert_eq!(
            sanitize(&algorithms::pagerank_with(0.85, 1e-6).name),
            sanitize(&algorithms::pagerank_with(0.95, 1e-9).name),
        );
        assert_eq!(sanitize(&algorithms::pagerank().name), "pagerank");
    }

    #[test]
    fn reduce_op_parameterized() {
        let hdl = emit_jgraph(&algorithms::wcc(), &ParallelismPlan::default());
        assert!(hdl.contains("ACC_OP = \"MIN\""));
        let hdl = emit_jgraph(&algorithms::spmv(), &ParallelismPlan::default());
        assert!(hdl.contains("ACC_OP = \"SUM\""));
    }

    #[test]
    fn sanitize_makes_identifiers() {
        assert_eq!(sanitize("pagerank(d=0.85)"), "pagerank_d_0_85_");
    }

    #[test]
    fn code_lines_skips_blank_and_comments() {
        assert_eq!(code_lines("// c\n\n  a;\nb; // t\n"), 2);
    }
}
