//! Pipeline schedule of a translated design: the numbers the cycle
//! simulator consumes. The *translator kind* determines the schedule
//! quality — this is where "light-weight, accelerator-tailored" beats
//! general-purpose HLS (paper §V-B).


use crate::sched::ParallelismPlan;

/// The execution schedule of a generated design.
#[derive(Debug, Clone, Copy)]
pub struct PipelineSpec {
    /// Parallel edge lanes per PE.
    pub lanes: u32,
    /// Replicated processing elements.
    pub pes: u32,
    /// Initiation interval: cycles between successive edges entering one
    /// lane. II=1 = fully pipelined.
    pub ii: u32,
    /// Pipeline depth in cycles (fill/drain cost per superstep).
    pub depth: u32,
    /// Kernel clock (Hz).
    pub clock_hz: f64,
    /// Vertex state held in BRAM/URAM (the paper's "vertex value are often
    /// transfered to BRAM in advance"). General HLS flows miss this.
    pub bram_vertex_cache: bool,
    /// Extra control cycles per edge (loop/branch overhead the flow could
    /// not pipeline away; ~0 for the tailored flow, large for Spatial's
    /// serialized outer loop).
    pub per_edge_overhead: f64,
}

impl PipelineSpec {
    /// Peak edge throughput (edges/s) ignoring memory stalls:
    /// lanes*pes / (II + overhead) per cycle.
    pub fn peak_teps(&self) -> f64 {
        let per_cycle =
            (self.lanes * self.pes) as f64 / (self.ii as f64 + self.per_edge_overhead);
        per_cycle * self.clock_hz
    }

    /// Effective lanes (used by the simulator's bank-conflict window).
    pub fn total_lanes(&self) -> u32 {
        self.lanes * self.pes
    }
}

/// Build the schedule a given translator achieves for `plan` on a device
/// clocked at `clock_hz` with pipeline `depth` stages.
pub fn schedule(
    kind: super::TranslatorKind,
    plan: ParallelismPlan,
    depth: u32,
    clock_hz: f64,
) -> PipelineSpec {
    use super::TranslatorKind::*;
    match kind {
        // Tailored flow: II=1 lanes, BRAM-cached vertices, no control
        // overhead — the module library was designed for exactly this.
        JGraph => PipelineSpec {
            lanes: plan.pipelines,
            pes: plan.pes,
            ii: 1,
            depth,
            clock_hz,
            bram_vertex_cache: true,
            per_edge_overhead: 0.0,
        },
        // Generic HLS: conservative dependence analysis on the vertex
        // read-modify-write forces II=2; vertex cache must be requested
        // with pragmas the generic flow does not emit.
        VivadoHls => PipelineSpec {
            lanes: plan.pipelines,
            pes: plan.pes,
            ii: 2,
            depth: depth * 2, // scheduler inserts extra registers
            clock_hz,
            bram_vertex_cache: false,
            per_edge_overhead: 0.25,
        },
        // Spatial-like staged IR: the irregular gather defeats its
        // pattern-based parallelization — the edge loop serializes onto
        // one effective lane with heavy per-iteration control.
        Spatial => PipelineSpec {
            lanes: 1,
            pes: plan.pes.min(2),
            ii: 4,
            depth: depth * 3,
            clock_hz,
            bram_vertex_cache: false,
            per_edge_overhead: 4.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::translator::TranslatorKind;

    #[test]
    fn peak_ordering_matches_table5() {
        let plan = ParallelismPlan::default(); // 8 x 1, the paper's setting
        let clock = 250.0e6;
        let j = schedule(TranslatorKind::JGraph, plan, 20, clock);
        let v = schedule(TranslatorKind::VivadoHls, plan, 20, clock);
        let s = schedule(TranslatorKind::Spatial, plan, 20, clock);
        assert!(j.peak_teps() > v.peak_teps());
        assert!(v.peak_teps() > 10.0 * s.peak_teps());
        // jgraph peak at 8 lanes, II=1, 250 MHz = 2 GTEPS
        assert!((j.peak_teps() - 2.0e9).abs() < 1.0);
    }

    #[test]
    fn only_jgraph_gets_vertex_cache() {
        let plan = ParallelismPlan::default();
        assert!(schedule(TranslatorKind::JGraph, plan, 10, 1e8).bram_vertex_cache);
        assert!(!schedule(TranslatorKind::VivadoHls, plan, 10, 1e8).bram_vertex_cache);
        assert!(!schedule(TranslatorKind::Spatial, plan, 10, 1e8).bram_vertex_cache);
    }

    #[test]
    fn lanes_scale_peak() {
        let a = schedule(TranslatorKind::JGraph, ParallelismPlan::new(4, 1), 10, 1e8);
        let b = schedule(TranslatorKind::JGraph, ParallelismPlan::new(8, 2), 10, 1e8);
        assert!((b.peak_teps() / a.peak_teps() - 4.0).abs() < 1e-9);
    }
}
