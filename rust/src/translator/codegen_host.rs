//! Host-side C code generation (paper §V-A: "the C code will be executed
//! on CPU, mainly including data transmission control commands"). The
//! generated program drives the (simulated) XRT shell: configure, DMA the
//! CSR arrays, **write the query's runtime parameters into the argument
//! register file**, launch supersteps, poll status, read results back.
//!
//! Declared parameters surface as a `<name>_args_t` struct argument of the
//! generated entry point and as `xrt_csr_write` lines into `JG_ARG_BASE`
//! — the code references parameter *names*, never values, so the emitted
//! driver (like the HDL) is byte-identical across parameter bindings.

use crate::dsl::params::Scalar;
use crate::dsl::program::{Convergence, GasProgram};
use crate::sched::ParallelismPlan;

/// C expression for a scalar: literals print, parameter references read
/// the args struct.
fn scalar_c(s: &Scalar) -> String {
    match s {
        Scalar::Lit(v) => format!("{v}"),
        Scalar::Param(name) => format!("args->{}", super::codegen_hdl::sanitize(name)),
    }
}

/// Emit the host C program for a translated design.
pub fn emit_host_c(program: &GasProgram, plan: &ParallelismPlan) -> String {
    let name = super::codegen_hdl::sanitize(&program.name);
    let has_params = program.has_runtime_params();
    let mut conv = match &program.convergence {
        Convergence::EmptyFrontier => "status.frontier_size == 0".to_string(),
        Convergence::NoChange => "status.updated == 0".to_string(),
        Convergence::FixedIterations(_) => "iter == MAX_ITERS".to_string(),
        Convergence::DeltaBelow(t) => match t {
            Scalar::Lit(_) => "status.delta < TOLERANCE".to_string(),
            Scalar::Param(_) => format!("status.delta < {}", scalar_c(t)),
        },
    };
    if let Some(limit) = &program.depth_limit {
        conv = format!("{conv} || iter >= (uint32_t){}", scalar_c(limit));
    }
    let max_iters = match program.convergence {
        Convergence::FixedIterations(k) => k,
        _ => 0,
    };
    let mut s = String::new();
    s += &format!("/* jgraph host driver for {} */\n", program.name);
    s += "#include \"xrt_shell.h\"\n#include \"jgraph_csr.h\"\n\n";
    s += &format!("#define PIPELINES {}\n#define PES {}\n", plan.pipelines, plan.pes);
    if max_iters > 0 {
        s += &format!("#define MAX_ITERS {max_iters}\n");
    }
    if let Convergence::DeltaBelow(Scalar::Lit(t)) = &program.convergence {
        s += &format!("#define TOLERANCE {t}\n");
    }
    if has_params {
        let fields: Vec<String> = program
            .params
            .names()
            .iter()
            .map(|n| format!("double {};", super::codegen_hdl::sanitize(n)))
            .collect();
        s += &format!("typedef struct {{ {} }} {name}_args_t;\n", fields.join(" "));
        s += &format!(
            "\nint run_{name}(const char *graph_path, uint32_t root, const {name}_args_t *args) {{\n"
        );
    } else {
        s += &format!("\nint run_{name}(const char *graph_path, uint32_t root) {{\n");
    }
    s += "  jg_csr_t g = jg_read_graph(graph_path);          /* FIFO + Layout */\n";
    s += "  xrt_device_t dev = xrt_open(0);                  /* Get_FPGA_Message */\n";
    s += &format!("  xrt_configure(dev, \"{name}.xclbin\", PIPELINES, PES);\n");
    s += "  xrt_dma_write(dev, JG_REGION_OFFSETS, g.offsets, g.n + 1);  /* Transport */\n";
    s += "  xrt_dma_write(dev, JG_REGION_TARGETS, g.targets, g.m);\n";
    if program.uses_weights {
        s += "  xrt_dma_write(dev, JG_REGION_WEIGHTS, g.weights, g.m);\n";
    }
    s += "  xrt_csr_write(dev, JG_CSR_ROOT, root);\n";
    for (i, p) in program.params.names().iter().enumerate() {
        s += &format!(
            "  xrt_csr_write(dev, JG_ARG_BASE + {i}, jg_f32_bits(args->{}));  /* Set_Argument */\n",
            super::codegen_hdl::sanitize(p)
        );
    }
    s += "  jg_status_t status; uint32_t iter = 0;\n";
    s += "  do {                                             /* superstep loop */\n";
    s += "    xrt_csr_write(dev, JG_CSR_LAUNCH, iter);\n";
    s += "    status = xrt_poll(dev);\n";
    s += "    iter++;\n";
    s += &format!("  }} while (!({conv}));\n");
    s += "  xrt_dma_read(dev, JG_REGION_VERTICES, g.values, g.n);\n";
    s += "  jg_write_result(g);                              /* FIFO_write */\n";
    s += "  xrt_close(dev);\n  return 0;\n}\n";
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::translator::codegen_hdl::code_lines;

    #[test]
    fn bfs_host_uses_frontier_convergence() {
        let c = emit_host_c(&algorithms::bfs(), &ParallelismPlan::default());
        assert!(c.contains("frontier_size == 0"));
        assert!(c.contains("#define PIPELINES 8"));
        assert!(!c.contains("JG_REGION_WEIGHTS"), "BFS is unweighted");
        // the optional depth bound reads its argument register
        assert!(c.contains("iter >= (uint32_t)args->max_depth"));
    }

    #[test]
    fn sssp_host_transfers_weights() {
        let c = emit_host_c(&algorithms::sssp(), &ParallelismPlan::default());
        assert!(c.contains("JG_REGION_WEIGHTS"));
        assert!(c.contains("updated == 0"));
    }

    #[test]
    fn pagerank_host_reads_registers_not_literals() {
        let c = emit_host_c(&algorithms::pagerank(), &ParallelismPlan::default());
        assert!(c.contains("pagerank_args_t"), "params surface as an args struct:\n{c}");
        assert!(c.contains("status.delta < args->tolerance"));
        assert!(c.contains("JG_ARG_BASE + 0"), "damping register write");
        assert!(c.contains("JG_ARG_BASE + 1"), "tolerance register write");
        assert!(!c.contains("0.85"), "no parameter value may be baked in");
        assert!(!c.contains("#define TOLERANCE"));
    }

    #[test]
    #[allow(deprecated)]
    fn host_driver_is_identical_across_parameter_values() {
        let a = emit_host_c(&algorithms::pagerank_with(0.85, 1e-6), &ParallelismPlan::default());
        let b = emit_host_c(&algorithms::pagerank_with(0.95, 1e-9), &ParallelismPlan::default());
        assert_eq!(a, b);
    }

    #[test]
    fn literal_tolerance_still_compiles_in() {
        use crate::dsl::apply::ApplyExpr;
        use crate::dsl::builder::GasProgramBuilder;
        // a hand-built closed program keeps the compile-time #define path
        let p = GasProgramBuilder::new("fixed-pr")
            .apply(ApplyExpr::src())
            .convergence(Convergence::DeltaBelow(1e-4.into()))
            .build()
            .unwrap();
        let c = emit_host_c(&p, &ParallelismPlan::default());
        assert!(c.contains("#define TOLERANCE 0.0001"));
        assert!(c.contains("status.delta < TOLERANCE"));
    }

    #[test]
    fn host_code_is_short() {
        let c = emit_host_c(&algorithms::bfs(), &ParallelismPlan::default());
        assert!(code_lines(&c) < 30, "host driver should stay small: {}", code_lines(&c));
    }
}
