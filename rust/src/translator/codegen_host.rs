//! Host-side C code generation (paper §V-A: "the C code will be executed
//! on CPU, mainly including data transmission control commands"). The
//! generated program drives the (simulated) XRT shell: configure, DMA the
//! CSR arrays, launch supersteps, poll status, read results back.

use crate::dsl::program::{Convergence, GasProgram};
use crate::sched::ParallelismPlan;

/// Emit the host C program for a translated design.
pub fn emit_host_c(program: &GasProgram, plan: &ParallelismPlan) -> String {
    let name = super::codegen_hdl::sanitize(&program.name);
    let conv = match program.convergence {
        Convergence::EmptyFrontier => "status.frontier_size == 0",
        Convergence::NoChange => "status.updated == 0",
        Convergence::FixedIterations(_) => "iter == MAX_ITERS",
        Convergence::DeltaBelow(_) => "status.delta < TOLERANCE",
    };
    let max_iters = match program.convergence {
        Convergence::FixedIterations(k) => k,
        _ => 0,
    };
    let mut s = String::new();
    s += &format!("/* jgraph host driver for {} */\n", program.name);
    s += "#include \"xrt_shell.h\"\n#include \"jgraph_csr.h\"\n\n";
    s += &format!("#define PIPELINES {}\n#define PES {}\n", plan.pipelines, plan.pes);
    if max_iters > 0 {
        s += &format!("#define MAX_ITERS {max_iters}\n");
    }
    if matches!(program.convergence, Convergence::DeltaBelow(_)) {
        if let Convergence::DeltaBelow(t) = program.convergence {
            s += &format!("#define TOLERANCE {t}\n");
        }
    }
    s += &format!("\nint run_{name}(const char *graph_path, uint32_t root) {{\n");
    s += "  jg_csr_t g = jg_read_graph(graph_path);          /* FIFO + Layout */\n";
    s += "  xrt_device_t dev = xrt_open(0);                  /* Get_FPGA_Message */\n";
    s += &format!("  xrt_configure(dev, \"{name}.xclbin\", PIPELINES, PES);\n");
    s += "  xrt_dma_write(dev, JG_REGION_OFFSETS, g.offsets, g.n + 1);  /* Transport */\n";
    s += "  xrt_dma_write(dev, JG_REGION_TARGETS, g.targets, g.m);\n";
    if program.uses_weights {
        s += "  xrt_dma_write(dev, JG_REGION_WEIGHTS, g.weights, g.m);\n";
    }
    s += "  xrt_csr_write(dev, JG_CSR_ROOT, root);\n";
    s += "  jg_status_t status; uint32_t iter = 0;\n";
    s += "  do {                                             /* superstep loop */\n";
    s += "    xrt_csr_write(dev, JG_CSR_LAUNCH, iter);\n";
    s += "    status = xrt_poll(dev);\n";
    s += "    iter++;\n";
    s += &format!("  }} while (!({conv}));\n");
    s += "  xrt_dma_read(dev, JG_REGION_VERTICES, g.values, g.n);\n";
    s += "  jg_write_result(g);                              /* FIFO_write */\n";
    s += "  xrt_close(dev);\n  return 0;\n}\n";
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::translator::codegen_hdl::code_lines;

    #[test]
    fn bfs_host_uses_frontier_convergence() {
        let c = emit_host_c(&algorithms::bfs(), &ParallelismPlan::default());
        assert!(c.contains("frontier_size == 0"));
        assert!(c.contains("#define PIPELINES 8"));
        assert!(!c.contains("JG_REGION_WEIGHTS"), "BFS is unweighted");
    }

    #[test]
    fn sssp_host_transfers_weights() {
        let c = emit_host_c(&algorithms::sssp(), &ParallelismPlan::default());
        assert!(c.contains("JG_REGION_WEIGHTS"));
        assert!(c.contains("updated == 0"));
    }

    #[test]
    fn pagerank_host_has_tolerance() {
        let c = emit_host_c(&algorithms::pagerank(0.85, 1e-4), &ParallelismPlan::default());
        assert!(c.contains("#define TOLERANCE 0.0001"));
        assert!(c.contains("status.delta < TOLERANCE"));
    }

    #[test]
    fn host_code_is_short() {
        let c = emit_host_c(&algorithms::bfs(), &ParallelismPlan::default());
        assert!(code_lines(&c) < 30, "host driver should stay small");
    }
}
