//! Baseline translators — the Table V comparators, reproduced *in spirit*:
//! we implement the structural inefficiencies of the general-purpose flows
//! (register-per-variable lowering, per-iteration ALU replication,
//! conservative pipelining) and actually run them, rather than shipping the
//! vendors' binaries (DESIGN.md §2 substitution table).

pub mod spatial;
pub mod vivado;
