//! Spatial-like baseline: a staged-IR accelerator DSL compiler. Its
//! pattern-based parallelization handles dense loop nests well, but the
//! irregular gather of graph traversal defeats it (paper §II, Table II:
//! "Spatial ... middle PD, long TT, middle RTL"): the edge loop is emitted
//! fully unrolled with per-iteration ALUs and explicit registers for every
//! temporary — "they often use as many registers and logic units as they
//! can" (§I). Lands near Table V's 128 lines for BFS.

use crate::dsl::program::{FrontierPolicy, GasProgram, ReduceOp};
use crate::sched::ParallelismPlan;

use super::super::codegen_hdl::sanitize;
use super::super::lower::alu_chain;

/// Unroll factor the Spatial-like flow picks for the inner edge loop.
pub const UNROLL: usize = 8;

/// Emit the unrolled, register-heavy RTL.
pub fn emit_hdl(program: &GasProgram, _plan: &ParallelismPlan) -> String {
    let name = sanitize(&program.name);
    let chain = alu_chain(&program.apply);
    let mut s = String::new();
    s += &format!(
        "// spatial-like baseline RTL for {} (unrolled x{UNROLL}, serialized outer loop)\n",
        program.name
    );
    s += &format!("module {name}_spatial (\n  input clock, input reset, input io_enable,\n");
    s += "  output io_done,\n";
    s += "  input [511:0] io_dram_rdata, output [63:0] io_dram_raddr,\n";
    s += "  output [511:0] io_dram_wdata, output [63:0] io_dram_waddr\n);\n";
    s += "  // stage counters (metaprogrammed controller tree)\n";
    s += "  reg [31:0] ctr_outer; reg [31:0] ctr_inner; reg [2:0] state_outer;\n";
    s += "  reg [31:0] sram_offsets [0:1023]; // banked scratchpads per stage\n";
    s += "  reg [31:0] sram_edges [0:1023];\n";
    s += "  reg [31:0] sram_values [0:1023];\n";
    if program.frontier == FrontierPolicy::Active {
        s += "  reg [31:0] fifo_frontier [0:4095]; reg [11:0] fifo_wptr, fifo_rptr;\n";
    }
    // Per-unrolled-iteration register + ALU block — the structural waste:
    // every temporary of every iteration becomes its own named register
    // ("they often use as many registers and logic units as they can").
    for u in 0..UNROLL {
        s += &format!("  // --- unrolled iteration {u}\n");
        s += &format!("  reg [63:0] x{u}_addr;\n");
        s += &format!("  reg [31:0] x{u}_edge;\n");
        s += &format!("  reg [31:0] x{u}_src;\n");
        s += &format!("  reg [31:0] x{u}_dst;\n");
        s += &format!("  reg [31:0] x{u}_gathered;\n");
        s += &format!("  reg        x{u}_valid;\n");
        s += &format!("  reg        x{u}_stage_en;\n");
        if program.uses_weights {
            s += &format!("  reg [31:0] x{u}_weight;\n");
        }
        if chain.is_empty() {
            s += &format!("  wire [31:0] x{u}_msg = x{u}_gathered;\n");
        } else {
            let mut prev = format!("x{u}_gathered");
            for (k, op) in chain.iter().enumerate() {
                s += &format!("  reg [31:0] x{u}_t{k};\n");
                s += &format!("  wire [31:0] x{u}_alu{k} = alu_{op}({prev}, x{u}_edge);\n");
                prev = format!("x{u}_alu{k}");
            }
            s += &format!("  wire [31:0] x{u}_msg = {prev};\n");
        }
    }
    let red = match program.reduce {
        ReduceOp::Min => "min",
        ReduceOp::Max => "max",
        ReduceOp::Sum => "add",
    };
    s += "  // reduction tree over the unrolled lane registers (serialized writeback)\n";
    let mut level = 0;
    let mut width = UNROLL;
    let mut prev_prefix = "x".to_string();
    while width > 1 {
        for i in 0..width / 2 {
            let (a, b) = if level == 0 {
                (format!("{prev_prefix}{}_msg", 2 * i), format!("{prev_prefix}{}_msg", 2 * i + 1))
            } else {
                (format!("{prev_prefix}{}", 2 * i), format!("{prev_prefix}{}", 2 * i + 1))
            };
            s += &format!("  wire [31:0] red{level}_{i} = alu_{red}({a}, {b});\n");
        }
        prev_prefix = format!("red{level}_");
        width /= 2;
        level += 1;
    }
    s += "  always @(posedge clock) begin\n";
    s += "    if (reset) begin ctr_outer <= 0; ctr_inner <= 0; state_outer <= 0; end\n";
    s += "    else begin\n";
    s += "      // outer loop sequences: load -> gather -> apply -> reduce -> write\n";
    s += "      state_outer <= (state_outer == 4) ? 0 : state_outer + 1;\n";
    s += "      if (state_outer == 4) ctr_inner <= ctr_inner + 1;\n";
    s += "      if (ctr_inner == 0) ctr_outer <= ctr_outer + 1;\n";
    s += "    end\n  end\n";
    s += "  assign io_done = (state_outer == 0) && (ctr_outer != 0);\nendmodule\n";
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::translator::codegen_hdl::code_lines;

    #[test]
    fn bfs_rtl_lands_near_table5() {
        let hdl = emit_hdl(&algorithms::bfs(), &ParallelismPlan::default());
        let lines = code_lines(&hdl);
        // Table V: Spatial = 128 lines for BFS
        assert!((100..=160).contains(&lines), "expected ~128 lines, got {lines}");
    }

    #[test]
    fn spatial_is_much_longer_than_jgraph() {
        let p = algorithms::bfs();
        let plan = ParallelismPlan::default();
        let sp = code_lines(&emit_hdl(&p, &plan));
        let jg = code_lines(&crate::translator::codegen_hdl::emit_jgraph(&p, &plan));
        // Table V ratio 128/35 ~ 3.7x
        let ratio = sp as f64 / jg as f64;
        assert!((2.5..=5.0).contains(&ratio), "ratio {ratio:.2}");
    }

    #[test]
    fn weights_add_registers() {
        let plan = ParallelismPlan::default();
        let bfs = code_lines(&emit_hdl(&algorithms::bfs(), &plan));
        let sssp = code_lines(&emit_hdl(&algorithms::sssp(), &plan));
        assert!(sssp > bfs, "weighted datapath must spell more registers");
    }

    #[test]
    fn unrolled_blocks_present() {
        let hdl = emit_hdl(&algorithms::wcc(), &ParallelismPlan::default());
        for u in 0..UNROLL {
            assert!(hdl.contains(&format!("x{u}_gathered")), "missing unroll {u}");
        }
    }
}
