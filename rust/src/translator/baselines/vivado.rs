//! Vivado-HLS-like baseline: a generic C-to-RTL flow. Correct but
//! structurally wasteful for graph workloads (paper §I): "each piece of
//! graph data is considered as a single-register", conservative II on the
//! vertex read-modify-write, no BRAM vertex preload, flattened FSM-style
//! RTL instead of module instantiation.

use crate::dsl::program::{FrontierPolicy, GasProgram, ReduceOp};
use crate::sched::ParallelismPlan;

use super::super::lower::alu_chain;
use super::super::codegen_hdl::sanitize;

/// Emit the HLS-style RTL: one flattened always-block state machine with
/// explicit per-stage registers — the shape `vivado_hls` produces from a
/// loop-pipelined C kernel. Lands near Table V's 54 lines for BFS.
pub fn emit_hdl(program: &GasProgram, plan: &ParallelismPlan) -> String {
    let name = sanitize(&program.name);
    let chain = alu_chain(&program.apply);
    let mut s = String::new();
    s += &format!("// vivado-hls baseline RTL for {} (II=2, no vertex BRAM)\n", program.name);
    s += &format!("module {name}_hls (\n  input ap_clk, input ap_rst, input ap_start,\n");
    s += "  output ap_done, output ap_idle,\n";
    s += "  input [511:0] m_axi_gmem_rdata, output [63:0] m_axi_gmem_araddr,\n";
    s += "  output [511:0] m_axi_gmem_wdata, output [63:0] m_axi_gmem_awaddr\n);\n";
    // the HLS scheduler's explicit FSM
    s += "  reg [3:0] ap_CS_fsm;\n";
    s += "  localparam ST_IDLE = 0, ST_LOAD_OFF = 1, ST_LOAD_EDGE = 2,\n";
    s += "             ST_GATHER = 3, ST_APPLY = 4, ST_REDUCE = 5, ST_WRITE = 6;\n";
    // register-per-variable lowering: every loop-carried value gets regs
    for i in 0..plan.pipelines {
        s += &format!("  reg [31:0] edge_buf_{i}; reg [31:0] src_val_{i}; reg [31:0] msg_{i};\n");
    }
    s += "  reg [63:0] off_lo, off_hi; reg [31:0] e_idx; reg [31:0] v_idx;\n";
    s += "  reg [31:0] upd_count; reg gmem_pending; reg [1:0] ii_stall; // II=2\n";
    if program.frontier == FrontierPolicy::Active {
        s += "  reg [31:0] queue_mem [0:65535]; reg [15:0] q_head, q_tail;\n";
    }
    s += "  always @(posedge ap_clk) begin\n";
    s += "    if (ap_rst) begin ap_CS_fsm <= ST_IDLE; e_idx <= 0; upd_count <= 0; end\n";
    s += "    else case (ap_CS_fsm)\n";
    s += "      ST_IDLE:      if (ap_start) ap_CS_fsm <= ST_LOAD_OFF;\n";
    s += "      ST_LOAD_OFF:  begin off_lo <= m_axi_gmem_rdata[63:0]; ap_CS_fsm <= ST_LOAD_EDGE; end\n";
    s += "      ST_LOAD_EDGE: begin gmem_pending <= 1; ap_CS_fsm <= ST_GATHER; end\n";
    s += "      ST_GATHER:    begin ii_stall <= ii_stall + 1; // dependence on vertex write\n";
    s += "                     if (ii_stall[0]) ap_CS_fsm <= ST_APPLY; end\n";
    s += "      ST_APPLY: begin\n";
    for i in 0..plan.pipelines {
        let expr = if chain.is_empty() {
            format!("src_val_{i}")
        } else {
            format!("alu_{}(src_val_{i}, edge_buf_{i})", chain.join("_"))
        };
        s += &format!("        msg_{i} <= {expr};\n");
    }
    s += "        ap_CS_fsm <= ST_REDUCE; end\n";
    let red = match program.reduce {
        ReduceOp::Min => "<",
        ReduceOp::Max => ">",
        ReduceOp::Sum => "+",
    };
    s += &format!("      ST_REDUCE:    begin /* serialize: acc {red} msg_i */ ap_CS_fsm <= ST_WRITE; end\n");
    s += "      ST_WRITE:     begin upd_count <= upd_count + 1;\n";
    s += "                     ap_CS_fsm <= (e_idx == 0) ? ST_IDLE : ST_LOAD_OFF; end\n";
    s += "    endcase\n  end\n";
    s += "  assign ap_done = (ap_CS_fsm == ST_IDLE);\nendmodule\n";
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::translator::codegen_hdl::code_lines;

    #[test]
    fn bfs_rtl_lands_near_table5() {
        let hdl = emit_hdl(&algorithms::bfs(), &ParallelismPlan::default());
        let lines = code_lines(&hdl);
        // Table V: Vivado HLS = 54 lines for BFS
        assert!((45..=70).contains(&lines), "expected ~54 lines, got {lines}");
    }

    #[test]
    fn registers_replicate_per_lane() {
        let a = emit_hdl(&algorithms::bfs(), &ParallelismPlan::new(4, 1));
        let b = emit_hdl(&algorithms::bfs(), &ParallelismPlan::new(8, 1));
        // unlike the jgraph emitter, lane count changes the code size
        assert!(code_lines(&b) > code_lines(&a));
    }

    #[test]
    fn fsm_shape_present() {
        let hdl = emit_hdl(&algorithms::sssp(), &ParallelismPlan::default());
        assert!(hdl.contains("ap_CS_fsm"));
        assert!(hdl.contains("ii_stall"));
        assert!(!hdl.contains("vertex_bram"), "generic flow has no vertex preload");
    }
}
