//! Lowering: [`GasProgram`] → [`ModuleGraph`]. The core of the
//! light-weight translator (paper §V-B): each DSL function maps onto a
//! pre-characterized hardware module; the Apply expression becomes a chain
//! of ALU stages; scheduling policies select the frontier/cache modules.
//! No syntax analysis, no design-space exploration — selection and wiring
//! only.

use crate::analysis::analyze;
use crate::dsl::apply::ApplyExpr;
use crate::dsl::ops::HwModule;
use crate::dsl::params::Scalar;
use crate::dsl::program::{FrontierPolicy, GasProgram, ReduceOp, StateType, Writeback};
use crate::sched::ParallelismPlan;

use super::modules::ModuleGraph;

/// Data bus width through the edge pipeline (vertex id + value + weight).
const EDGE_BUS: u32 = 96;
const VALUE_BUS: u32 = 32;

/// Lower one GAS program into the accelerator module graph for `plan`.
/// Layout (paper Fig. 4): shared infrastructure (PCIe DMA, memory
/// controller, control regs, vertex BRAM) + `pipelines × pes` edge lanes,
/// each `EdgeFetcher → GatherUnit → ApplyAlu* → [ConflictUnit] →
/// ReduceUnit → VertexWriter`, with an optional FrontierQueue feeding the
/// fetchers.
///
/// Lowering is **fact-driven** ([`crate::analysis::analyze`]):
/// * the same-destination [`HwModule::ConflictUnit`] is instantiated only
///   when the reduce is not idempotent — for min/max the analyzer proves
///   re-delivered updates harmless, so the resolver is elided per lane;
/// * the argument register file is narrowed to the **datapath-live**
///   parameters (those the Apply expression or the damped writeback read
///   on-chip). Host-consumed parameters (`tolerance`, `max_depth`) live in
///   the host superstep loop and never cost registers.
pub fn lower(program: &GasProgram, plan: &ParallelismPlan) -> ModuleGraph {
    let facts = analyze(program);
    let mut g = ModuleGraph::default();

    // --- shared infrastructure
    let dma = g.add(HwModule::PcieDma, "pcie_dma", vec![]);
    let memc = g.add(
        HwModule::MemController,
        "mem_ctrl",
        vec![("channels".into(), "4".into())],
    );
    let ctrl = g.add(
        HwModule::ControlRegs,
        "ctrl_regs",
        vec![
            ("pipelines".into(), plan.pipelines.to_string()),
            ("pes".into(), plan.pes.to_string()),
        ],
    );
    g.connect(dma, memc, 512);
    g.connect(ctrl, memc, 32);

    // Runtime-argument register file for programs whose *datapath* reads
    // declared params: the host writes bound values here before each query
    // launch, so the lowered structure — and the emitted HDL — is
    // identical for every parameter value. The register layout is the
    // analyzer's datapath-liveness set (declared order preserved), not the
    // full signature: host-loop parameters never reach the fabric.
    let args = if facts.datapath_params.is_empty() {
        None
    } else {
        let a = g.add(
            HwModule::ArgRegFile,
            "arg_regs",
            vec![("params".into(), facts.datapath_params.join(","))],
        );
        g.connect(ctrl, a, 32);
        Some(a)
    };

    // vertex state resident on chip (the paper's BRAM preload)
    let vcache = g.add(
        HwModule::BramCache,
        "vertex_bram",
        vec![(
            "elem".into(),
            match program.state {
                StateType::I32 => "i32".into(),
                StateType::F32 => "f32".into(),
            },
        )],
    );
    g.connect(memc, vcache, 512);

    let vloader = g.add(HwModule::VertexLoader, "vertex_loader", vec![]);
    g.connect(vcache, vloader, VALUE_BUS);

    // frontier queue only for active-frontier programs (BFS)
    let frontier = if program.frontier == FrontierPolicy::Active {
        let q = g.add(HwModule::FrontierQueue, "frontier_q", vec![]);
        g.connect(ctrl, q, 32);
        Some(q)
    } else {
        None
    };

    // offset fetcher resolves Edge_offset rows for the lanes
    let off = g.add(HwModule::OffsetFetcher, "offset_fetch", vec![]);
    g.connect(memc, off, 64);
    if let Some(q) = frontier {
        g.connect(q, off, 32);
    }

    // --- replicated edge lanes
    for pe in 0..plan.pes {
        for lane in 0..plan.pipelines {
            let tag = format!("pe{pe}_l{lane}");
            let fetch = g.add(
                HwModule::EdgeFetcher,
                format!("edge_fetch_{tag}"),
                vec![("weights".into(), program.uses_weights.to_string())],
            );
            g.connect(off, fetch, 64);
            g.connect(memc, fetch, 512);

            let gather = g.add(HwModule::GatherUnit, format!("gather_{tag}"), vec![]);
            g.connect(fetch, gather, EDGE_BUS);
            g.connect(vloader, gather, VALUE_BUS);

            // Apply expression → ALU chain (one module per operation;
            // terms are wiring, not logic). Parameter terms draw their
            // operand from the argument register file, not a literal.
            let mut prev = gather;
            for (i, opname) in alu_chain(&program.apply).into_iter().enumerate() {
                let alu = g.add(
                    HwModule::ApplyAlu,
                    format!("apply_{tag}_{i}"),
                    vec![("op".into(), opname)],
                );
                g.connect(prev, alu, VALUE_BUS);
                if i == 0 && program.apply.uses_params() {
                    if let Some(a) = args {
                        g.connect(a, alu, VALUE_BUS);
                    }
                }
                prev = alu;
            }

            let acc: String = match program.reduce {
                ReduceOp::Min => "min".into(),
                ReduceOp::Max => "max".into(),
                ReduceOp::Sum => "sum".into(),
            };

            // Same-destination conflict resolver in front of the reduce's
            // read-modify-write — required when the reduce is not
            // idempotent (Sum double-counts a re-delivered message),
            // elided when the analyzer certifies idempotence.
            if facts.needs_conflict_unit() {
                let cu = g.add(
                    HwModule::ConflictUnit,
                    format!("conflict_{tag}"),
                    vec![("acc".into(), acc.clone())],
                );
                g.connect(prev, cu, VALUE_BUS);
                prev = cu;
            }

            let reduce =
                g.add(HwModule::ReduceUnit, format!("reduce_{tag}"), vec![("acc".into(), acc)]);
            g.connect(prev, reduce, VALUE_BUS);

            // Writeback closes the superstep loop *through the BRAM state*,
            // which is sequential (next superstep), not a combinational
            // wire — so the module graph stays a feed-forward pipeline.
            let writer = g.add(
                HwModule::VertexWriter,
                format!("vertex_wr_{tag}"),
                vec![("feedback".into(), "vertex_bram,frontier_q".into())],
            );
            g.connect(reduce, writer, VALUE_BUS);
            // the damped writeback consumes its damping factor from the
            // argument registers (PageRank's per-query damping); a literal
            // damping elaborates into the writer, needing no register
            if let (Some(a), Writeback::DampedSum(Scalar::Param(_))) =
                (args, &program.writeback)
            {
                g.connect(a, writer, VALUE_BUS);
            }
        }
    }
    g
}

/// Flatten an apply expression into the ALU op chain (post-order), the
/// order the pipelined ALUs execute in.
pub fn alu_chain(expr: &ApplyExpr) -> Vec<String> {
    let mut ops = Vec::new();
    walk(expr, &mut ops);
    ops
}

fn walk(e: &ApplyExpr, out: &mut Vec<String>) {
    match e {
        ApplyExpr::Term(_) => {}
        ApplyExpr::Unary(op, a) => {
            walk(a, out);
            out.push(format!("{op:?}").to_lowercase());
        }
        ApplyExpr::Binary(op, a, b) => {
            walk(a, out);
            walk(b, out);
            out.push(format!("{op:?}").to_lowercase());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;

    #[test]
    fn bfs_lowering_structure() {
        let p = algorithms::bfs();
        let plan = ParallelismPlan::new(2, 1);
        let g = lower(&p, &plan);
        g.validate().unwrap();
        assert_eq!(g.count(HwModule::EdgeFetcher), 2);
        assert_eq!(g.count(HwModule::FrontierQueue), 1); // active frontier
        assert_eq!(g.count(HwModule::BramCache), 1); // shared vertex state
        assert_eq!(g.count(HwModule::PcieDma), 1);
        // BFS apply = iter+1 -> one ALU per lane
        assert_eq!(g.count(HwModule::ApplyAlu), 2);
    }

    #[test]
    fn pagerank_has_no_frontier_queue() {
        let g = lower(&algorithms::pagerank(), &ParallelismPlan::new(4, 1));
        assert_eq!(g.count(HwModule::FrontierQueue), 0);
        assert_eq!(g.count(HwModule::ReduceUnit), 4);
    }

    #[test]
    fn parameterized_programs_get_one_arg_reg_file() {
        // shared infrastructure: one register file regardless of lanes
        let g = lower(&algorithms::pagerank(), &ParallelismPlan::new(8, 2));
        assert_eq!(g.count(HwModule::ArgRegFile), 1);
        let names = &g
            .instances
            .iter()
            .find(|m| m.kind == HwModule::ArgRegFile)
            .unwrap()
            .params;
        // interval/liveness narrowing: only datapath-live params get
        // registers — `tolerance` is host-loop state, not fabric state
        assert_eq!(names[0].1, "damping", "register layout = datapath-live params");
        // a closed program carries none
        let g = lower(&algorithms::wcc(), &ParallelismPlan::new(8, 1));
        assert_eq!(g.count(HwModule::ArgRegFile), 0);
    }

    #[test]
    fn host_only_parameters_do_not_cost_registers() {
        // BFS declares `max_depth`, but it is consumed by the host
        // superstep loop (depth_limit), never by the datapath: the
        // analyzer-narrowed register file disappears entirely.
        for p in [algorithms::bfs(), algorithms::sssp()] {
            assert!(p.has_runtime_params(), "{} declares params", p.name);
            let g = lower(&p, &ParallelismPlan::new(4, 1));
            assert_eq!(g.count(HwModule::ArgRegFile), 0, "{}", p.name);
        }
    }

    #[test]
    fn conflict_unit_elided_exactly_when_reduce_is_idempotent() {
        let plan = ParallelismPlan::new(4, 1);
        // Sum (non-idempotent): one resolver per lane, in front of reduce
        for p in [algorithms::pagerank(), algorithms::spmv()] {
            let g = lower(&p, &plan);
            assert_eq!(g.count(HwModule::ConflictUnit), 4, "{}", p.name);
            g.validate().unwrap();
        }
        // Min/Max (idempotent): the analyzer proves re-delivery harmless
        for p in [algorithms::bfs(), algorithms::wcc(), algorithms::widest_path()] {
            let g = lower(&p, &plan);
            assert_eq!(g.count(HwModule::ConflictUnit), 0, "{}", p.name);
        }
    }

    #[test]
    fn conflict_unit_insertion_keeps_pipeline_depth() {
        // the resolver is forwarding-only (latency 0): a Sum design's
        // pipeline depth matches an otherwise-identical idempotent one
        let plan = ParallelismPlan::new(2, 1);
        let sum = lower(&algorithms::spmv(), &plan);
        let mut min_spmv = algorithms::spmv();
        min_spmv.reduce = crate::dsl::program::ReduceOp::Min;
        min_spmv.writeback = crate::dsl::program::Writeback::Overwrite;
        let min = lower(&min_spmv, &plan);
        assert!(sum.count(HwModule::ConflictUnit) > 0);
        assert_eq!(min.count(HwModule::ConflictUnit), 0);
        assert_eq!(sum.pipeline_depth(), min.pipeline_depth());
    }

    #[test]
    fn lanes_replicate_with_pes() {
        let g = lower(&algorithms::sssp(), &ParallelismPlan::new(4, 2));
        assert_eq!(g.count(HwModule::EdgeFetcher), 8);
        assert_eq!(g.count(HwModule::VertexWriter), 8);
        // shared infra not replicated
        assert_eq!(g.count(HwModule::MemController), 1);
    }

    #[test]
    fn alu_chain_matches_expression() {
        let p = algorithms::sssp(); // src + w -> ["add"]
        assert_eq!(alu_chain(&p.apply), vec!["add"]);
        let spmv = algorithms::spmv(); // src * w -> ["mul"]
        assert_eq!(alu_chain(&spmv.apply), vec!["mul"]);
    }

    #[test]
    fn module_graphs_are_acyclic_for_all_algorithms() {
        for p in algorithms::all() {
            let g = lower(&p, &ParallelismPlan::default());
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", p.name));
            assert!(g.pipeline_depth() > 0);
        }
    }
}
