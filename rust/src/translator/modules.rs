//! The hardware-module library the light-weight translator maps DSL
//! functions onto (paper §V-A, Fig. 4). Each module has fixed per-instance
//! resource costs and pipeline latency; the translator's job is *selection
//! and wiring*, not synthesis — that is exactly the "light-weight" trade
//! the paper makes (trade general compiling for a fixed, optimized module
//! set).


pub use crate::dsl::ops::HwModule;

/// One instantiated module in a design.
#[derive(Debug, Clone)]
pub struct ModuleInstance {
    pub id: usize,
    pub kind: HwModule,
    /// Instance name in the generated HDL.
    pub name: String,
    /// Free-form parameter annotations (lane count, operator, width...).
    pub params: Vec<(String, String)>,
}

/// A directed wire between module ports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Wire {
    pub from: usize,
    pub to: usize,
    /// Bus width in bits.
    pub width: u32,
}

/// The dataflow graph of a translated design: the paper's "execution
/// module on accelerator".
#[derive(Debug, Clone, Default)]
pub struct ModuleGraph {
    pub instances: Vec<ModuleInstance>,
    pub wires: Vec<Wire>,
}

impl ModuleGraph {
    /// Add an instance; returns its id.
    pub fn add(
        &mut self,
        kind: HwModule,
        name: impl Into<String>,
        params: Vec<(String, String)>,
    ) -> usize {
        let id = self.instances.len();
        self.instances.push(ModuleInstance { id, kind, name: name.into(), params });
        id
    }

    /// Wire `from` → `to`.
    pub fn connect(&mut self, from: usize, to: usize, width: u32) {
        debug_assert!(from < self.instances.len() && to < self.instances.len());
        self.wires.push(Wire { from, to, width });
    }

    pub fn count(&self, kind: HwModule) -> usize {
        self.instances.iter().filter(|m| m.kind == kind).count()
    }

    /// Pipeline depth = longest path through the wire DAG (stage latencies
    /// summed). The generated design is a feed-forward pipeline, so the
    /// graph is acyclic by construction; cycles would mean a translator
    /// bug and are reported as an error by `validate()`.
    pub fn pipeline_depth(&self) -> u32 {
        let n = self.instances.len();
        let mut depth = vec![0u32; n];
        // topological relaxation over wires (ids are created in dataflow
        // order by the lowerer, so a single forward pass suffices; we
        // iterate to fixpoint to stay correct for arbitrary orders).
        let mut changed = true;
        let mut rounds = 0;
        while changed && rounds <= n {
            changed = false;
            rounds += 1;
            for w in &self.wires {
                let cand = depth[w.from] + latency(self.instances[w.from].kind);
                if cand > depth[w.to] {
                    depth[w.to] = cand;
                    changed = true;
                }
            }
        }
        depth
            .iter()
            .zip(&self.instances)
            .map(|(d, m)| d + latency(m.kind))
            .max()
            .unwrap_or(0)
    }

    /// Structural checks: wires reference real instances; no cycles
    /// (pipeline must drain); at most one frontier queue per lane group.
    pub fn validate(&self) -> anyhow::Result<()> {
        for w in &self.wires {
            if w.from >= self.instances.len() || w.to >= self.instances.len() {
                anyhow::bail!("wire references missing module instance");
            }
        }
        if self.has_cycle() {
            anyhow::bail!("module graph has a combinational cycle");
        }
        Ok(())
    }

    fn has_cycle(&self) -> bool {
        let n = self.instances.len();
        let mut indeg = vec![0usize; n];
        for w in &self.wires {
            indeg[w.to] += 1;
        }
        let mut q: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(u) = q.pop() {
            seen += 1;
            for w in self.wires.iter().filter(|w| w.from == u) {
                indeg[w.to] -= 1;
                if indeg[w.to] == 0 {
                    q.push(w.to);
                }
            }
        }
        seen != n
    }
}

/// Per-instance resource cost of a module (Alveo-class estimates: LUTs,
/// flip-flops, BRAM kilobits, URAM blocks, DSP slices). These numbers are
/// the translator's "datasheet" — they size Table V's resource column and
/// the synthesis-time model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModuleCost {
    pub lut: u32,
    pub ff: u32,
    pub bram_kb: u32,
    pub uram: u32,
    pub dsp: u32,
}

/// Cost table. Single source of truth for resource estimation
/// ([`super::resource`]).
pub fn cost(kind: HwModule) -> ModuleCost {
    match kind {
        HwModule::VertexLoader => ModuleCost { lut: 1_800, ff: 2_400, bram_kb: 36, uram: 0, dsp: 0 },
        HwModule::VertexWriter => ModuleCost { lut: 1_500, ff: 2_000, bram_kb: 18, uram: 0, dsp: 0 },
        HwModule::EdgeFetcher => ModuleCost { lut: 2_200, ff: 3_000, bram_kb: 72, uram: 0, dsp: 0 },
        HwModule::OffsetFetcher => ModuleCost { lut: 1_200, ff: 1_500, bram_kb: 36, uram: 0, dsp: 0 },
        HwModule::GatherUnit => ModuleCost { lut: 2_500, ff: 3_200, bram_kb: 36, uram: 0, dsp: 0 },
        HwModule::ApplyAlu => ModuleCost { lut: 900, ff: 1_100, bram_kb: 0, uram: 0, dsp: 3 },
        // The reduce accumulator and its same-destination conflict
        // resolver are separate library entries so the translator can
        // elide the resolver when the analyzer proves the reduce
        // idempotent. Their costs sum to the pre-split ReduceUnit
        // datasheet line (2_200+800 LUT, 2_600+1_000 FF, 108+36 BRAM kb,
        // 2+0 DSP), so non-idempotent designs price identically.
        HwModule::ReduceUnit => ModuleCost { lut: 2_200, ff: 2_600, bram_kb: 108, uram: 0, dsp: 2 },
        HwModule::ConflictUnit => ModuleCost { lut: 800, ff: 1_000, bram_kb: 36, uram: 0, dsp: 0 },
        HwModule::ScatterUnit => ModuleCost { lut: 2_000, ff: 2_600, bram_kb: 36, uram: 0, dsp: 0 },
        HwModule::FrontierQueue => ModuleCost { lut: 1_600, ff: 2_200, bram_kb: 72, uram: 0, dsp: 0 },
        HwModule::BramCache => ModuleCost { lut: 2_800, ff: 3_000, bram_kb: 0, uram: 16, dsp: 0 },
        HwModule::MemController => ModuleCost { lut: 9_000, ff: 12_000, bram_kb: 144, uram: 0, dsp: 0 },
        HwModule::PcieDma => ModuleCost { lut: 12_000, ff: 16_000, bram_kb: 288, uram: 0, dsp: 0 },
        HwModule::ControlRegs => ModuleCost { lut: 800, ff: 1_200, bram_kb: 0, uram: 0, dsp: 0 },
        HwModule::ArgRegFile => ModuleCost { lut: 400, ff: 700, bram_kb: 0, uram: 0, dsp: 0 },
        HwModule::HostOnly => ModuleCost::default(),
    }
}

/// Pipeline latency (clock cycles a datum spends in the module).
pub fn latency(kind: HwModule) -> u32 {
    match kind {
        HwModule::VertexLoader => 2,
        HwModule::VertexWriter => 1,
        HwModule::EdgeFetcher => 4, // DDR burst buffer in front
        HwModule::OffsetFetcher => 2,
        HwModule::GatherUnit => 2,
        HwModule::ApplyAlu => 1,
        HwModule::ReduceUnit => 3, // read-modify-write on banked BRAM
        // combinational forwarding: combines in-flight same-vertex
        // messages inside the reduce's dispatch window, adding no stage
        HwModule::ConflictUnit => 0,
        HwModule::ScatterUnit => 2,
        HwModule::FrontierQueue => 1,
        HwModule::BramCache => 1,
        HwModule::MemController => 8,
        HwModule::PcieDma => 16,
        HwModule::ControlRegs => 1,
        HwModule::ArgRegFile => 1,
        HwModule::HostOnly => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_connect() {
        let mut g = ModuleGraph::default();
        let a = g.add(HwModule::EdgeFetcher, "fetch", vec![]);
        let b = g.add(HwModule::ApplyAlu, "alu", vec![]);
        g.connect(a, b, 64);
        assert_eq!(g.instances.len(), 2);
        assert_eq!(g.count(HwModule::ApplyAlu), 1);
        g.validate().unwrap();
    }

    #[test]
    fn pipeline_depth_is_longest_path() {
        let mut g = ModuleGraph::default();
        let a = g.add(HwModule::EdgeFetcher, "f", vec![]); // lat 4
        let b = g.add(HwModule::GatherUnit, "g", vec![]); // lat 2
        let c = g.add(HwModule::ApplyAlu, "alu", vec![]); // lat 1
        let d = g.add(HwModule::ReduceUnit, "r", vec![]); // lat 3
        g.connect(a, b, 64);
        g.connect(b, c, 32);
        g.connect(c, d, 32);
        // short parallel branch
        g.connect(a, d, 32);
        assert_eq!(g.pipeline_depth(), 4 + 2 + 1 + 3);
    }

    #[test]
    fn cycle_detected() {
        let mut g = ModuleGraph::default();
        let a = g.add(HwModule::ApplyAlu, "a", vec![]);
        let b = g.add(HwModule::ApplyAlu, "b", vec![]);
        g.connect(a, b, 32);
        g.connect(b, a, 32);
        assert!(g.validate().is_err());
    }

    #[test]
    fn costs_nonzero_for_datapath_modules() {
        for kind in [
            HwModule::VertexLoader,
            HwModule::EdgeFetcher,
            HwModule::ReduceUnit,
            HwModule::MemController,
        ] {
            assert!(cost(kind).lut > 0, "{kind:?}");
            assert!(latency(kind) > 0, "{kind:?}");
        }
        assert_eq!(cost(HwModule::HostOnly), ModuleCost::default());
    }

    #[test]
    fn conflict_split_preserves_the_combined_reduce_datasheet() {
        // ReduceUnit + ConflictUnit must sum to the pre-split datasheet
        // line so Sum designs (which instantiate both) price identically
        // to PR 5 and earlier.
        let r = cost(HwModule::ReduceUnit);
        let c = cost(HwModule::ConflictUnit);
        assert_eq!(r.lut + c.lut, 3_000);
        assert_eq!(r.ff + c.ff, 3_600);
        assert_eq!(r.bram_kb + c.bram_kb, 144);
        assert_eq!(r.dsp + c.dsp, 2);
        // ... and the resolver is forwarding-only: no pipeline stage, so
        // inserting it does not change any design's pipeline depth
        assert_eq!(latency(HwModule::ConflictUnit), 0);
        assert_eq!(latency(HwModule::ReduceUnit), 3);
    }
}
