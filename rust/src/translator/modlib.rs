//! The hardware module **library bodies** — the pre-optimized Verilog
//! definitions the light-weight translator instantiates (paper §V: "The
//! advantage is efficient build on top of sophisticated state-of-art graph
//! accelerators"; the top-level HDL stays at ~35 lines *because* these
//! bodies ship pre-written and pre-characterized, like an FPGA vendor IP
//! library).
//!
//! `emit_library` collects the definitions a design actually uses so a
//! generated project is self-contained: `jgraph translate --emit library`.

use crate::dsl::ops::HwModule;

use super::modules::ModuleGraph;

/// Verilog body for one library module. Behavioral but structurally
/// honest: each body implements the handshake and latency documented in
/// [`super::modules::latency`] (checked by tests).
pub fn module_body(kind: HwModule) -> &'static str {
    match kind {
        HwModule::VertexLoader => r#"
// vertex_loader: burst-reads vertex values from vertex_bram into the
// lane-shared operand bus. latency 2 (bram read + register).
module vertex_loader (
  input clk, input rst,
  input  [31:0] req_vid, input req_valid,
  output reg [31:0] vals, output reg vals_valid,
  input  [31:0] bram_rdata, output [31:0] bram_raddr
);
  reg [31:0] vid_q; reg valid_q;
  assign bram_raddr = req_vid;
  always @(posedge clk) begin
    if (rst) begin valid_q <= 0; vals_valid <= 0; end
    else begin
      vid_q <= req_vid; valid_q <= req_valid;      // stage 1: bram access
      vals <= bram_rdata; vals_valid <= valid_q;   // stage 2: register out
    end
  end
endmodule
"#,
        HwModule::VertexWriter => r#"
// vertex_wr: commits reduced values back to vertex_bram, applying the
// design's writeback rule in the bram's read-modify-write port. latency 1.
module vertex_wr #(parameter RULE = "OVERWRITE") (
  input clk, input rst,
  input [31:0] in_vid, input [31:0] in_val, input in_valid,
  output reg [31:0] wb_addr, output reg [31:0] wb_data, output reg wb_en
);
  always @(posedge clk) begin
    if (rst) wb_en <= 0;
    else begin wb_addr <= in_vid; wb_data <= in_val; wb_en <= in_valid; end
  end
endmodule
"#,
        HwModule::EdgeFetcher => r#"
// edge_fetch: streams the Edges array over a DDR burst buffer; one edge
// record per cycle at II=1 once the 4-deep prefetch FIFO is primed.
module edge_fetch #(parameter W = 0 /* weights present */) (
  input clk, input rst,
  input  [63:0] row_lo, input [63:0] row_hi, input row_valid,
  input  [511:0] mem_rdata, input mem_rvalid, output reg [63:0] mem_raddr, output reg mem_ren,
  output reg [95:0] edge_out, output reg edge_valid
);
  reg [511:0] burst_buf [0:3]; reg [1:0] head, tail; reg [3:0] beat_off;
  reg [63:0] cursor;
  always @(posedge clk) begin
    if (rst) begin head <= 0; tail <= 0; beat_off <= 0; edge_valid <= 0; mem_ren <= 0; end
    else begin
      if (row_valid) cursor <= row_lo;
      mem_ren <= (cursor < row_hi) && (tail - head < 3);
      mem_raddr <= cursor;
      if (mem_rvalid) begin burst_buf[tail] <= mem_rdata; tail <= tail + 1; end
      if (head != tail) begin
        edge_out <= burst_buf[head][95:0] >> (beat_off * (W ? 96 : 64));
        edge_valid <= 1;
        beat_off <= beat_off + 1;
        if (beat_off == (W ? 4 : 7)) begin head <= head + 1; beat_off <= 0; end
      end else edge_valid <= 0;
    end
  end
endmodule
"#,
        HwModule::OffsetFetcher => r#"
// offset_fetch: resolves Edge_offset rows (row_lo/row_hi pairs) for the
// lanes; latency 2 (address + data).
module offset_fetch (
  input clk, input rst,
  input [31:0] vid, input vid_valid,
  input [511:0] mem_rdata, output [63:0] mem_raddr,
  output reg [63:0] row_lo, output reg [63:0] row_hi, output reg row_valid
);
  assign mem_raddr = {29'd0, vid, 3'd0}; // offsets[v], offsets[v+1]
  reg valid_q;
  always @(posedge clk) begin
    if (rst) begin row_valid <= 0; valid_q <= 0; end
    else begin
      valid_q <= vid_valid;
      row_lo <= mem_rdata[63:0]; row_hi <= mem_rdata[127:64];
      row_valid <= valid_q;
    end
  end
endmodule
"#,
        HwModule::GatherUnit => r#"
// gather: joins the edge stream with the source-vertex value stream (the
// DSL's Receive). latency 2 (match + register).
module gather (
  input clk, input rst,
  input [95:0] edges, input edge_valid,
  input [31:0] vals, input vals_valid,
  output reg [127:0] out, output reg out_valid
);
  reg [95:0] edge_q; reg pending;
  always @(posedge clk) begin
    if (rst) begin pending <= 0; out_valid <= 0; end
    else begin
      if (edge_valid) begin edge_q <= edges; pending <= 1; end
      if (pending && vals_valid) begin
        out <= {vals, edge_q}; out_valid <= 1; pending <= 0;
      end else out_valid <= 0;
    end
  end
endmodule
"#,
        HwModule::ApplyAlu => r#"
// apply_alu: one pipelined operation of the Apply expression chain.
// latency 1. OP selects the datapath function at elaboration.
module apply_alu #(parameter OP = "add") (
  input clk, input rst,
  input [127:0] in, input in_valid,
  output reg [31:0] out, output reg out_valid
);
  wire [31:0] a = in[127:96]; // gathered src value
  wire [31:0] b = in[95:64];  // edge weight / iter operand
  reg [31:0] f;
  always @(*) case (OP)
    "add":  f = a + b;
    "sub":  f = a - b;
    "mul":  f = a * b;       // DSP48 inferred
    "min":  f = (a < b) ? a : b;
    "max":  f = (a > b) ? a : b;
    "sqrt": f = a;           // iterative unit elided in behavioral model
    default: f = a;
  endcase
  always @(posedge clk) begin
    if (rst) out_valid <= 0;
    else begin out <= f; out_valid <= in_valid; end
  end
endmodule
"#,
        HwModule::ReduceUnit => r#"
// reduce_unit: banked read-modify-write accumulator (the DSL's Reduce).
// BANKS-way interleaved BRAM; same-bank messages in one dispatch window
// serialize (the conflict the cycle model counts). latency 3.
module reduce_unit #(parameter OP = "MIN", parameter BANKS = 16) (
  input clk, input rst,
  input [31:0] in_msg, input [31:0] in_vid, input in_valid,
  output reg [31:0] out, output reg [31:0] out_vid, output reg out_valid,
  output reg conflict_stall
);
  reg [31:0] acc_bank [0:BANKS-1][0:4095];
  wire [3:0] bank = in_vid[3:0];
  reg [31:0] rmw_q; reg [31:0] vid_q; reg valid_q;
  reg [3:0] busy_bank; reg busy;
  always @(posedge clk) begin
    if (rst) begin out_valid <= 0; busy <= 0; conflict_stall <= 0; end
    else begin
      conflict_stall <= busy && in_valid && (bank == busy_bank);
      rmw_q <= acc_bank[bank][in_vid[15:4]];           // stage 1: read
      vid_q <= in_vid; valid_q <= in_valid;
      busy <= in_valid; busy_bank <= bank;
      if (valid_q) begin                               // stage 2: modify
        out <= (OP == "SUM") ? rmw_q + in_msg
             : (OP == "MAX") ? ((rmw_q > in_msg) ? rmw_q : in_msg)
             : ((rmw_q < in_msg) ? rmw_q : in_msg);
        out_vid <= vid_q; out_valid <= 1;
        acc_bank[vid_q[3:0]][vid_q[15:4]] <= out;      // stage 3: write
      end else out_valid <= 0;
    end
  end
endmodule
"#,
        HwModule::ConflictUnit => r#"
// conflict_unit: same-destination combining network in front of the
// reduce accumulator. When two in-flight messages inside the dispatch
// window target one vertex, they are merged with the reduce operator
// *before* the read-modify-write, so a non-idempotent accumulator (SUM)
// never sees the same update twice. The data path is combinational
// forwarding (latency 0); only the one-deep match window is registered.
// Elided entirely for idempotent reduces — the analyzer proves
// re-delivery harmless there (ParallelSafety certificate).
module conflict_unit #(parameter OP = "SUM") (
  input clk, input rst,
  input  [31:0] in_msg, input [31:0] in_vid, input in_valid,
  output [31:0] out_msg, output [31:0] out_vid, output out_valid
);
  reg [31:0] held_msg; reg [31:0] held_vid; reg held;
  wire match = held && in_valid && (in_vid == held_vid);
  wire [31:0] merged = (OP == "SUM") ? held_msg + in_msg
                     : (OP == "MAX") ? ((held_msg > in_msg) ? held_msg : in_msg)
                     : ((held_msg < in_msg) ? held_msg : in_msg);
  // forward combinationally; a matched pair leaves as one message
  assign out_msg   = match ? merged : in_msg;
  assign out_vid   = in_vid;
  assign out_valid = in_valid;
  always @(posedge clk) begin
    if (rst) held <= 0;
    else begin held_msg <= out_msg; held_vid <= in_vid; held <= in_valid; end
  end
endmodule
"#,
        HwModule::ScatterUnit => r#"
// scatter: routes updated messages to destination queues (the DSL's
// Send). latency 2.
module scatter (
  input clk, input rst,
  input [31:0] in_msg, input [31:0] in_dst, input in_valid,
  output reg [31:0] out_msg, output reg [31:0] out_dst, output reg out_valid
);
  reg [31:0] m_q, d_q; reg v_q;
  always @(posedge clk) begin
    if (rst) begin out_valid <= 0; v_q <= 0; end
    else begin
      m_q <= in_msg; d_q <= in_dst; v_q <= in_valid;
      out_msg <= m_q; out_dst <= d_q; out_valid <= v_q;
    end
  end
endmodule
"#,
        HwModule::FrontierQueue => r#"
// frontier_q: BRAM FIFO of active vertices (Algorithm 1's
// Get_active_vertex). push from vertex_wr, pop to offset_fetch. latency 1.
module frontier_q #(parameter DEPTH = 16384) (
  input clk, input rst,
  input [31:0] push_vid, input push_en,
  output reg [31:0] pop_vid, output reg pop_valid, input pop_ready,
  output empty
);
  reg [31:0] q [0:DEPTH-1]; reg [13:0] wptr, rptr;
  assign empty = (wptr == rptr);
  always @(posedge clk) begin
    if (rst) begin wptr <= 0; rptr <= 0; pop_valid <= 0; end
    else begin
      if (push_en) begin q[wptr] <= push_vid; wptr <= wptr + 1; end
      if (pop_ready && !empty) begin
        pop_vid <= q[rptr]; rptr <= rptr + 1; pop_valid <= 1;
      end else pop_valid <= 0;
    end
  end
endmodule
"#,
        HwModule::BramCache => r#"
// vertex_bram: the resident vertex-state store (URAM-backed), preloaded
// before traversal ("vertex value are often transfered to BRAM in
// advance"). dual-port: loader reads, writer commits. latency 1.
module vertex_bram #(parameter ELEMS = 131072) (
  input clk,
  input  [31:0] raddr, output reg [31:0] rdata,
  input  [31:0] waddr, input [31:0] wdata, input wen,
  input  [31:0] dma_addr, input [511:0] dma_data, input dma_wen
);
  (* ram_style = "ultra" *) reg [31:0] mem [0:ELEMS-1];
  integer i;
  always @(posedge clk) begin
    rdata <= mem[raddr];
    if (wen) mem[waddr] <= wdata;
    if (dma_wen) for (i = 0; i < 16; i = i + 1)
      mem[dma_addr + i] <= dma_data[i*32 +: 32];
  end
endmodule
"#,
        HwModule::MemController => r#"
// mem_ctrl: arbitration over the DDR4 channels; burst coalescing for the
// edge stream, a narrow port for offsets. latency 8 (controller + PHY).
module mem_ctrl #(parameter CHANNELS = 4) (
  input clk, input rst,
  input  [63:0] p0_addr, input p0_ren, output reg [511:0] p0_data, output reg p0_valid,
  input  [63:0] p1_addr, input p1_ren, output reg [511:0] p1_data, output reg p1_valid,
  output [63:0] ddr_addr [0:CHANNELS-1], input [511:0] ddr_data [0:CHANNELS-1],
  output reg busy
);
  // round-robin channel arbitration, 8-stage request pipeline
  reg [2:0] rr; reg [63:0] pipe_addr [0:7]; reg [7:0] pipe_valid;
  integer s;
  always @(posedge clk) begin
    if (rst) begin rr <= 0; pipe_valid <= 0; busy <= 0; end
    else begin
      rr <= rr + 1;
      pipe_addr[0] <= p0_ren ? p0_addr : p1_addr;
      pipe_valid <= {pipe_valid[6:0], p0_ren | p1_ren};
      for (s = 7; s > 0; s = s - 1) pipe_addr[s] <= pipe_addr[s-1];
      p0_valid <= pipe_valid[7]; p1_valid <= pipe_valid[7];
      p0_data <= ddr_data[rr[1:0]]; p1_data <= ddr_data[rr[1:0]];
      busy <= |pipe_valid;
    end
  end
endmodule
"#,
        HwModule::PcieDma => r#"
// pcie_dma: XDMA-class host interface; CSR mailbox + descriptor-driven
// bulk transfers into device DDR. latency 16 (TLP round trip).
module pcie_dma (
  input clk, input rst,
  input [31:0] csr, output reg [31:0] status,
  output reg [63:0] dma_addr, output reg [511:0] dma_data, output reg dma_wen
);
  reg [15:0] tlp_pipe;
  always @(posedge clk) begin
    if (rst) begin tlp_pipe <= 0; status <= 0; dma_wen <= 0; end
    else begin
      tlp_pipe <= {tlp_pipe[14:0], csr[0]};
      dma_wen <= tlp_pipe[15];
      status <= {30'd0, |tlp_pipe, csr[0]};
    end
  end
endmodule
"#,
        HwModule::ControlRegs => r#"
// ctrl_regs: the runtime scheduler's CSR file (Set_Pipeline, Set_PE,
// launch doorbell, status). latency 1.
module ctrl_regs (
  input clk, input rst,
  input [31:0] wr_data, input [3:0] wr_addr, input wr_en,
  output reg [31:0] pipelines, output reg [31:0] pes,
  output reg launch, output reg [31:0] iter
);
  always @(posedge clk) begin
    if (rst) begin pipelines <= 8; pes <= 1; launch <= 0; iter <= 0; end
    else begin
      launch <= 0;
      if (wr_en) case (wr_addr)
        4'd0: pipelines <= wr_data;
        4'd1: pes <= wr_data;
        4'd2: begin launch <= 1; iter <= wr_data; end
      endcase
    end
  end
endmodule
"#,
        HwModule::ArgRegFile => r#"
// arg_regs: host-written runtime-argument register file (Set_Argument).
// One 32-bit register per declared program parameter, written over the
// CSR mailbox before each query launch — the reason one synthesized
// design serves every parameter value. latency 1.
module arg_regs #(parameter N = 1) (
  input clk, input rst,
  input [31:0] wr_data, input [$clog2(N):0] wr_idx, input wr_en,
  output reg [31:0] args [0:N-1]
);
  integer i;
  always @(posedge clk) begin
    if (rst) for (i = 0; i < N; i = i + 1) args[i] <= 32'd0;
    else if (wr_en) args[wr_idx] <= wr_data;
  end
endmodule
"#,
        HwModule::HostOnly => "",
    }
}

/// Collect the deduplicated library definitions a design uses.
pub fn emit_library(graph: &ModuleGraph) -> String {
    let mut kinds: Vec<HwModule> = graph.instances.iter().map(|m| m.kind).collect();
    kinds.sort_by_key(|k| format!("{k:?}"));
    kinds.dedup();
    let mut out = String::from(
        "// jgraph pre-optimized hardware module library (paper §V-A)\n\
         // one definition per module kind used by this design\n",
    );
    for k in kinds {
        out += module_body(k);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::sched::ParallelismPlan;
    use crate::translator::codegen_hdl::code_lines;
    use crate::translator::lower::lower;

    #[test]
    fn every_datapath_module_has_a_body() {
        for kind in [
            HwModule::VertexLoader,
            HwModule::VertexWriter,
            HwModule::EdgeFetcher,
            HwModule::OffsetFetcher,
            HwModule::GatherUnit,
            HwModule::ApplyAlu,
            HwModule::ReduceUnit,
            HwModule::ConflictUnit,
            HwModule::ScatterUnit,
            HwModule::FrontierQueue,
            HwModule::BramCache,
            HwModule::MemController,
            HwModule::PcieDma,
            HwModule::ControlRegs,
            HwModule::ArgRegFile,
        ] {
            let body = module_body(kind);
            assert!(body.contains("module "), "{kind:?} missing module decl");
            assert!(body.contains("endmodule"), "{kind:?} missing endmodule");
            assert!(body.contains("posedge clk"), "{kind:?} not clocked");
        }
        assert!(module_body(HwModule::HostOnly).is_empty());
    }

    #[test]
    fn library_collects_used_kinds_once() {
        let g = lower(&algorithms::bfs(), &ParallelismPlan::new(8, 1));
        let lib = emit_library(&g);
        // 8 lanes but exactly one edge_fetch definition
        assert_eq!(lib.matches("module edge_fetch").count(), 1);
        assert_eq!(lib.matches("module frontier_q").count(), 1);
        // PR design has no frontier queue -> no definition
        let g2 = lower(&algorithms::pagerank(), &ParallelismPlan::new(8, 1));
        let lib2 = emit_library(&g2);
        assert_eq!(lib2.matches("module frontier_q").count(), 0);
        // ... but it declares runtime params -> one arg_regs definition
        assert_eq!(lib2.matches("module arg_regs").count(), 1);
    }

    #[test]
    fn library_is_substantial_but_top_level_stays_small() {
        // the paper's premise: code the user sees stays ~35 lines because
        // the complexity lives in the pre-written library
        let g = lower(&algorithms::bfs(), &ParallelismPlan::default());
        let lib_lines = code_lines(&emit_library(&g));
        let top_lines = crate::translator::codegen_hdl::emit_jgraph(
            &algorithms::bfs(),
            &ParallelismPlan::default(),
        );
        assert!(lib_lines > 5 * code_lines(&top_lines), "library {lib_lines} lines");
    }

    #[test]
    fn reduce_unit_documents_conflict_stall() {
        assert!(module_body(HwModule::ReduceUnit).contains("conflict_stall"));
        assert!(module_body(HwModule::BramCache).contains("ultra"), "URAM hint");
    }
}
