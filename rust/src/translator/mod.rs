//! The **light-weight translator** (paper §V): lowers a DSL program onto
//! the hardware-module library, emits HDL + host C, estimates resources,
//! and fixes the pipeline schedule. Baseline translators reproduce the
//! general-purpose flows of Table V for comparison.
//!
//! "We choose to trade off general compiling capabilities ... in exchange
//! for much higher performance" — concretely: [`lower`] is a fixed
//! structural mapping (no IR, no DSE), which is why `translate()` runs in
//! microseconds while the modeled Vivado/Spatial flows take seconds.

pub mod baselines;
pub mod codegen_chisel;
pub mod codegen_hdl;
pub mod codegen_host;
pub mod lower;
pub mod modlib;
pub mod modules;
pub mod pipeline;
pub mod resource;

use std::time::Instant;

use anyhow::Result;

use crate::accel::device::DeviceModel;
use crate::dsl::program::GasProgram;
use crate::sched::ParallelismPlan;

use modules::ModuleGraph;
use pipeline::PipelineSpec;
use resource::ResourceEstimate;

/// Which translation flow produced a design.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TranslatorKind {
    /// The paper's light-weight flow ("FAgraph" in Table V).
    JGraph,
    /// Generic HLS baseline (Vivado-HLS-like).
    VivadoHls,
    /// Accelerator-DSL baseline (Spatial-like).
    Spatial,
}

impl TranslatorKind {
    pub fn label(&self) -> &'static str {
        match self {
            TranslatorKind::JGraph => "FAgraph",
            TranslatorKind::VivadoHls => "Vivado HLS",
            TranslatorKind::Spatial => "Spatial",
        }
    }

    pub fn all() -> [TranslatorKind; 3] {
        [TranslatorKind::Spatial, TranslatorKind::VivadoHls, TranslatorKind::JGraph]
    }
}

/// A fully-translated design: everything downstream consumers need —
/// the simulator ([`crate::accel`]), the engine, and the reports.
#[derive(Debug, Clone)]
pub struct Design {
    pub kind: TranslatorKind,
    pub program_name: String,
    pub module_graph: ModuleGraph,
    pub pipeline: PipelineSpec,
    pub resources: ResourceEstimate,
    pub hdl: String,
    pub host_c: String,
    /// The Chisel intermediate (JGraph flow only — the paper's §III
    /// "conversion from Chisel HDL to Verilog").
    pub chisel: Option<String>,
    /// Table V metric: non-blank, non-comment HDL lines.
    pub hdl_lines: usize,
    pub host_lines: usize,
    /// Actual wall time of `translate()` (the light-weight claim).
    pub translate_seconds: f64,
    /// Modeled synthesis/P&R time (DESIGN.md §2: Vivado substitute).
    pub synthesis_seconds: f64,
}

impl Design {
    /// Does this design fit a device?
    pub fn fits(&self, device: &DeviceModel) -> bool {
        self.resources.fits(device)
    }

    /// Total compile-path seconds (translate + modeled synthesis) — the
    /// compilation period of Fig. 5.
    pub fn compile_seconds(&self) -> f64 {
        self.translate_seconds + self.synthesis_seconds
    }
}

/// Translator facade.
#[derive(Debug, Clone, Copy)]
pub struct Translator {
    pub kind: TranslatorKind,
    pub plan: ParallelismPlan,
    pub device: ClockSource,
}

/// Where the kernel clock comes from (device model choice).
#[derive(Debug, Clone, Copy)]
pub enum ClockSource {
    U200,
    Small,
}

impl ClockSource {
    pub fn device(&self) -> DeviceModel {
        match self {
            ClockSource::U200 => DeviceModel::u200(),
            ClockSource::Small => DeviceModel::small(),
        }
    }
}

impl Translator {
    /// The light-weight flow with the paper's default plan (8 pipelines,
    /// 1 PE, U200).
    pub fn jgraph() -> Self {
        Self { kind: TranslatorKind::JGraph, plan: ParallelismPlan::default(), device: ClockSource::U200 }
    }

    pub fn vivado_hls() -> Self {
        Self { kind: TranslatorKind::VivadoHls, plan: ParallelismPlan::default(), device: ClockSource::U200 }
    }

    pub fn spatial() -> Self {
        Self { kind: TranslatorKind::Spatial, plan: ParallelismPlan::default(), device: ClockSource::U200 }
    }

    pub fn of_kind(kind: TranslatorKind) -> Self {
        Self { kind, plan: ParallelismPlan::default(), device: ClockSource::U200 }
    }

    pub fn with_plan(mut self, plan: ParallelismPlan) -> Self {
        self.plan = plan;
        self
    }

    pub fn on_small_device(mut self) -> Self {
        self.device = ClockSource::Small;
        self
    }

    /// Translate a program into a [`Design`].
    ///
    /// All three flows share the same *module graph* lowering (they build
    /// the same datapath semantically) but differ in code generation,
    /// schedule quality, resource multipliers, and modeled synthesis time
    /// — which is exactly the paper's claim: the algorithm is identical,
    /// the flow determines the efficiency.
    pub fn translate(&self, program: &GasProgram) -> Result<Design> {
        let t0 = Instant::now();
        crate::dsl::validate::check(program)?;
        let device = self.device.device();
        let graph = lower::lower(program, &self.plan);
        graph.validate()?;

        let base = ResourceEstimate::of(&graph);
        // flow-dependent structural overhead (register/logic waste)
        let resources = match self.kind {
            TranslatorKind::JGraph => base,
            TranslatorKind::VivadoHls => inflate(&base, 1.9),
            TranslatorKind::Spatial => inflate(&base, 3.2),
        };

        let depth = graph.pipeline_depth();
        let pipeline = pipeline::schedule(self.kind, self.plan, depth, device.clock_hz);

        // The JGraph flow goes DSL -> Chisel generator -> Verilog (the
        // paper's pipeline); the baselines emit their RTL directly.
        let chisel = match self.kind {
            TranslatorKind::JGraph => {
                Some(codegen_chisel::emit_chisel(program, &self.plan))
            }
            _ => None,
        };
        let (hdl, host_c) = match self.kind {
            TranslatorKind::JGraph => (
                codegen_chisel::chisel_to_verilog(program, &self.plan).verilog,
                codegen_host::emit_host_c(program, &self.plan),
            ),
            TranslatorKind::VivadoHls => (
                baselines::vivado::emit_hdl(program, &self.plan),
                codegen_host::emit_host_c(program, &self.plan),
            ),
            TranslatorKind::Spatial => (
                baselines::spatial::emit_hdl(program, &self.plan),
                codegen_host::emit_host_c(program, &self.plan),
            ),
        };

        let synthesis_seconds = resource::synthesis_seconds(self.kind, &resources);
        Ok(Design {
            kind: self.kind,
            program_name: program.name.clone(),
            hdl_lines: codegen_hdl::code_lines(&hdl),
            host_lines: codegen_hdl::code_lines(&host_c),
            module_graph: graph,
            pipeline,
            resources,
            hdl,
            host_c,
            chisel,
            translate_seconds: t0.elapsed().as_secs_f64(),
            synthesis_seconds,
        })
    }
}

fn inflate(r: &ResourceEstimate, factor: f64) -> ResourceEstimate {
    ResourceEstimate {
        lut: (r.lut as f64 * factor) as u64,
        ff: (r.ff as f64 * factor * 1.2) as u64, // register waste dominates
        bram_kb: (r.bram_kb as f64 * factor.sqrt()) as u64,
        uram: r.uram,
        dsp: (r.dsp as f64 * factor) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;

    #[test]
    fn table5_code_line_ordering() {
        let p = algorithms::bfs();
        let j = Translator::jgraph().translate(&p).unwrap();
        let v = Translator::vivado_hls().translate(&p).unwrap();
        let s = Translator::spatial().translate(&p).unwrap();
        assert!(j.hdl_lines < v.hdl_lines, "{} < {}", j.hdl_lines, v.hdl_lines);
        assert!(v.hdl_lines < s.hdl_lines, "{} < {}", v.hdl_lines, s.hdl_lines);
    }

    #[test]
    fn translate_is_fast_and_synthesis_modeled_slow() {
        let d = Translator::jgraph().translate(&algorithms::bfs()).unwrap();
        assert!(d.translate_seconds < 0.5, "light-weight translate took {}s", d.translate_seconds);
        assert!(d.synthesis_seconds > 1.0);
        let v = Translator::vivado_hls().translate(&algorithms::bfs()).unwrap();
        assert!(v.compile_seconds() > d.compile_seconds());
    }

    #[test]
    fn resource_inflation_ordering() {
        let p = algorithms::sssp();
        let j = Translator::jgraph().translate(&p).unwrap();
        let v = Translator::vivado_hls().translate(&p).unwrap();
        let s = Translator::spatial().translate(&p).unwrap();
        assert!(j.resources.lut < v.resources.lut);
        assert!(v.resources.lut < s.resources.lut);
    }

    #[test]
    fn all_algorithms_fit_u200_with_default_plan() {
        let dev = DeviceModel::u200();
        for p in algorithms::all() {
            for kind in TranslatorKind::all() {
                let d = Translator::of_kind(kind).translate(&p).unwrap();
                assert!(d.fits(&dev), "{} via {:?} does not fit", p.name, kind);
            }
        }
    }

    #[test]
    fn invalid_program_rejected_before_lowering() {
        use crate::dsl::builder::GasProgramBuilder;
        use crate::dsl::program::{ReduceOp, StateType, Writeback};
        let bad = GasProgramBuilder::new("x")
            .state(StateType::F32)
            .apply(crate::dsl::apply::ApplyExpr::src())
            .reduce(ReduceOp::Sum)
            .writeback(Writeback::Overwrite)
            .build()
            .unwrap();
        // hand-corrupt to bypass builder validation
        let mut evil = bad;
        evil.writeback = Writeback::IfUnvisited;
        assert!(Translator::jgraph().translate(&evil).is_err());
    }
}
