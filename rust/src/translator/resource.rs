//! Resource estimation + synthesis-time model. The light-weight translator
//! prices a design by summing its module datasheet costs (no place-and-
//! route — that is the point); the synthesis-time model stands in for
//! Vivado, calibrated so the *relative* compile costs in Table V and
//! Fig. 5 hold (DESIGN.md §2).


use super::modules::{cost, ModuleGraph};
use crate::accel::device::DeviceModel;

/// Aggregate FPGA resources of a design (or of one lane, before scaling).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceEstimate {
    pub lut: u64,
    pub ff: u64,
    pub bram_kb: u64,
    pub uram: u64,
    pub dsp: u64,
}

impl ResourceEstimate {
    /// Sum module costs over a module graph.
    pub fn of(graph: &ModuleGraph) -> Self {
        let mut r = ResourceEstimate::default();
        for m in &graph.instances {
            let c = cost(m.kind);
            r.lut += c.lut as u64;
            r.ff += c.ff as u64;
            r.bram_kb += c.bram_kb as u64;
            r.uram += c.uram as u64;
            r.dsp += c.dsp as u64;
        }
        r
    }

    /// Scale by a lane count (replicated datapaths).
    pub fn scaled(&self, lanes: u32) -> Self {
        let k = lanes as u64;
        ResourceEstimate {
            lut: self.lut * k,
            ff: self.ff * k,
            bram_kb: self.bram_kb * k,
            uram: self.uram * k,
            dsp: self.dsp * k,
        }
    }

    /// Elementwise add (shared infrastructure + lanes).
    pub fn plus(&self, other: &ResourceEstimate) -> Self {
        ResourceEstimate {
            lut: self.lut + other.lut,
            ff: self.ff + other.ff,
            bram_kb: self.bram_kb + other.bram_kb,
            uram: self.uram + other.uram,
            dsp: self.dsp + other.dsp,
        }
    }

    /// Does the design fit the device?
    pub fn fits(&self, device: &DeviceModel) -> bool {
        self.lut <= device.luts
            && self.ff <= device.registers
            && self.bram_kb <= device.bram_kb
            && self.uram <= device.urams
            && self.dsp <= device.dsps
    }

    /// Utilization fractions (LUT, FF, BRAM, URAM, DSP) for reports.
    pub fn utilization(&self, device: &DeviceModel) -> [f64; 5] {
        [
            self.lut as f64 / device.luts as f64,
            self.ff as f64 / device.registers as f64,
            self.bram_kb as f64 / device.bram_kb as f64,
            self.uram as f64 / device.urams as f64,
            self.dsp as f64 / device.dsps as f64,
        ]
    }
}

/// Synthesis/implementation wall-time model (seconds). Table V's RT column
/// includes compile time; we cannot run Vivado, so we model it:
/// a flow-dependent base (syntax/IR overhead, design-space exploration)
/// plus a term growing with the LUT count (place-and-route effort). The
/// constants are calibrated against Table V's running-time column
/// (FAgraph 5.3 s / Vivado 12.6 s / Spatial 11.8 s on the small graph —
/// the paper's "tens of seconds" regime; see EXPERIMENTS.md).
pub fn synthesis_seconds(kind: super::TranslatorKind, res: &ResourceEstimate) -> f64 {
    use super::TranslatorKind::*;
    let (base, per_mlut) = match kind {
        // light-weight: pre-characterized module library, no DSE
        JGraph => (3.0, 8.0),
        // generic HLS: scheduling/binding + pragma exploration
        VivadoHls => (9.0, 18.0),
        // Spatial: staged IR, banking/DSE search, longest front end
        Spatial => (8.0, 30.0),
    };
    base + per_mlut * (res.lut as f64 / 1.0e6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::ops::HwModule;
    use crate::translator::modules::ModuleGraph;
    use crate::translator::TranslatorKind;

    fn sample_graph() -> ModuleGraph {
        let mut g = ModuleGraph::default();
        g.add(HwModule::EdgeFetcher, "f", vec![]);
        g.add(HwModule::ApplyAlu, "a", vec![]);
        g.add(HwModule::ReduceUnit, "r", vec![]);
        g
    }

    #[test]
    fn estimate_sums_module_costs() {
        let r = ResourceEstimate::of(&sample_graph());
        assert_eq!(r.lut, 2_200 + 900 + 2_200);
        assert_eq!(r.dsp, 3 + 2);
    }

    #[test]
    fn scaling_and_addition() {
        let r = ResourceEstimate::of(&sample_graph());
        let s = r.scaled(4);
        assert_eq!(s.lut, r.lut * 4);
        let t = r.plus(&s);
        assert_eq!(t.lut, r.lut * 5);
    }

    #[test]
    fn fit_check_against_devices() {
        let r = ResourceEstimate::of(&sample_graph()).scaled(8);
        assert!(r.fits(&DeviceModel::u200()));
        let huge = r.scaled(10_000);
        assert!(!huge.fits(&DeviceModel::u200()));
    }

    #[test]
    fn utilization_fractions() {
        let r = ResourceEstimate::of(&sample_graph());
        let u = r.utilization(&DeviceModel::u200());
        assert!(u.iter().all(|&f| (0.0..1.0).contains(&f)));
    }

    #[test]
    fn synthesis_model_ordering() {
        // same design: light-weight flow must model fastest, Spatial slowest
        let r = ResourceEstimate { lut: 200_000, ..Default::default() };
        let j = synthesis_seconds(TranslatorKind::JGraph, &r);
        let v = synthesis_seconds(TranslatorKind::VivadoHls, &r);
        let s = synthesis_seconds(TranslatorKind::Spatial, &r);
        assert!(j < v && v < s + 5.0, "j={j} v={v} s={s}");
        assert!(j > 0.0);
    }
}
