//! [`ProgramFacts`] — the algebraic facts the static analyzer derives from
//! a [`GasProgram`]. Everything downstream consumers used to hard-code at
//! scattered sites (pull early-exit legality, damped-iteration dispatch,
//! conflict-unit need, argument-register liveness) is derived here once
//! and read everywhere:
//!
//! * the **engine** dispatches on [`ProgramFacts::damped_iteration`] and
//!   gates pull early-exit on [`ProgramFacts::pull_early_exit`];
//! * the **translator** elides the reduce conflict-resolution unit when
//!   the reduce is idempotent and narrows the argument register file to
//!   [`ProgramFacts::datapath_params`];
//! * the **lint engine** ([`super::lint`]) turns impossible combinations
//!   into stable `JG***` diagnostics;
//! * [`crate::engine::CompiledPipeline`] carries the
//!   [`ParallelSafety`] certificate future sharded execution must check.

use crate::dsl::apply::CompiledApply;
use crate::dsl::params::Scalar;
use crate::dsl::program::{Convergence, GasProgram, ReduceOp, StateType, Writeback};

/// Direction of monotone state evolution under a reduce operator: applying
/// the operator can only move a value this way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Monotonicity {
    /// `op(a, b) <= min(a, b)` — values only shrink (Min).
    Decreasing,
    /// `op(a, b) >= max(a, b)` — values only grow (Max).
    Increasing,
    /// Neither bound holds (Sum).
    NonMonotone,
}

/// The algebraic profile of a [`ReduceOp`] over the program's state type.
/// These flags are what correctness arguments actually rest on: pull
/// early-exit needs idempotence, parallel bit-exactness needs
/// associativity, and any parallel scatter at all needs commutativity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReduceAlgebra {
    /// `op(a, a) == a`: re-delivering a message cannot change the result.
    pub idempotent: bool,
    /// `op(a, b) == op(b, a)`: message arrival order within one reduction
    /// is free.
    pub commutative: bool,
    /// `op(op(a, b), c) == op(a, op(b, c))` **bit-exactly** for the
    /// program's state type. Float summation fails this (rounding depends
    /// on grouping); integer and min/max reductions hold it.
    pub associative: bool,
    pub monotonicity: Monotonicity,
}

impl ReduceAlgebra {
    /// The algebra of `op` over `state`. Associativity is judged at the
    /// bit-exact level the engine's push/pull identity pin demands, so
    /// `Sum` over F32 is *not* associative.
    pub fn of(op: ReduceOp, state: StateType) -> Self {
        match op {
            ReduceOp::Min => ReduceAlgebra {
                idempotent: true,
                commutative: true,
                associative: true,
                monotonicity: Monotonicity::Decreasing,
            },
            ReduceOp::Max => ReduceAlgebra {
                idempotent: true,
                commutative: true,
                associative: true,
                monotonicity: Monotonicity::Increasing,
            },
            ReduceOp::Sum => ReduceAlgebra {
                idempotent: false,
                commutative: true,
                associative: state == StateType::I32,
                monotonicity: Monotonicity::NonMonotone,
            },
        }
    }

    /// One-word rendering for reports (`translate --emit stats`).
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.idempotent {
            parts.push("idempotent");
        }
        if self.commutative {
            parts.push("commutative");
        }
        if self.associative {
            parts.push("associative");
        }
        let mono = match self.monotonicity {
            Monotonicity::Decreasing => "monotone-decreasing",
            Monotonicity::Increasing => "monotone-increasing",
            Monotonicity::NonMonotone => "non-monotone",
        };
        parts.push(mono);
        parts.join(", ")
    }
}

/// How a program terminates — with the previously-hidden internal
/// iteration bound of the delta path surfaced as a fact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvergenceClass {
    /// Frontier/fixpoint detection (`EmptyFrontier` / `NoChange`): bounded
    /// by the graph diameter, at most `V` supersteps.
    FixpointByDepth,
    /// Exactly this many supersteps (SpMV's single sweep).
    FixedIterations(u32),
    /// Contraction mapping driven by an L1-delta threshold (PageRank).
    /// `iteration_bound` is the scheduler's safety net: hitting it without
    /// meeting the delta condition is an **error**, never a silent
    /// truncation (see
    /// [`crate::dsl::program::DELTA_CONVERGENCE_SUPERSTEP_BOUND`]).
    ContractionByDelta { iteration_bound: u32 },
}

impl ConvergenceClass {
    pub fn describe(&self) -> String {
        match self {
            ConvergenceClass::FixpointByDepth => "fixpoint-by-depth".into(),
            ConvergenceClass::FixedIterations(k) => format!("fixed-iterations({k})"),
            ConvergenceClass::ContractionByDelta { iteration_bound } => {
                format!("contraction-by-delta(bound {iteration_bound})")
            }
        }
    }
}

/// A closed interval over the values a [`Scalar`] can take at query time:
/// a literal is a point, a parameter reference spans its declared range,
/// and an undeclared reference (a deny lint of its own) spans everything.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    pub const FULL: Interval = Interval { lo: f64::NEG_INFINITY, hi: f64::INFINITY };

    pub fn point(v: f64) -> Self {
        Interval { lo: v, hi: v }
    }

    /// The interval a scalar can bind to under `p`'s declared signature.
    pub fn of_scalar(s: &Scalar, p: &GasProgram) -> Interval {
        match s {
            Scalar::Lit(v) => Interval::point(*v),
            Scalar::Param(name) => match p.params.get(name) {
                Some(spec) => Interval {
                    lo: spec.min.unwrap_or(f64::NEG_INFINITY),
                    hi: spec.max.unwrap_or(f64::INFINITY),
                },
                None => Interval::FULL,
            },
        }
    }

    pub fn render(&self) -> String {
        if self.lo == self.hi {
            format!("{}", self.lo)
        } else {
            format!("[{}, {}]", self.lo, self.hi)
        }
    }
}

/// The parallel-execution certificate stamped on every
/// [`crate::engine::CompiledPipeline`]. Future sharded/threaded execution
/// must check it before reordering scatter writes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelSafety {
    /// Any scatter order produces bit-identical results (idempotent +
    /// commutative + associative reduce): shard freely.
    BitExact,
    /// Results are order-dependent at the ULP level (float summation):
    /// parallel execution needs a fixed reduction order to stay
    /// reproducible.
    OrderSensitive,
    /// Concurrent writebacks race (a non-reducible writeback such as a
    /// visited-gate over a non-idempotent accumulator): parallel scatter
    /// is a data race, not merely a reordering.
    Racy,
}

impl ParallelSafety {
    pub fn describe(&self) -> &'static str {
        match self {
            ParallelSafety::BitExact => "bit-exact",
            ParallelSafety::OrderSensitive => "order-sensitive",
            ParallelSafety::Racy => "racy",
        }
    }
}

/// Everything the analyzer can prove about one program. Derived by
/// [`analyze`]; immutable; cheap to clone.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramFacts {
    /// Algebra of the declared reduce over the declared state type.
    pub reduce: ReduceAlgebra,
    /// Termination class with the internal iteration bound surfaced.
    pub convergence: ConvergenceClass,
    /// The parallel-scatter certificate.
    pub parallel_safety: ParallelSafety,
    /// May a pull superstep stop scanning a destination's in-edges at the
    /// first frontier neighbor? Legal iff the message is constant within a
    /// superstep, the writeback is a visited-gate, and the reduce is
    /// idempotent-monotone — any one frontier message equals their
    /// reduction.
    pub pull_early_exit: bool,
    /// Does this program run the damped (PageRank-shaped) engine
    /// iteration? Driven by the writeback shape, never by the `kind` tag.
    pub damped_iteration: bool,
    /// Value interval of the damping factor, when the writeback is damped.
    pub damping: Option<Interval>,
    /// Value interval of the depth limit, when one is declared.
    pub depth_interval: Option<Interval>,
    /// Declared parameters the **datapath** consumes (Apply operands and
    /// the damped writeback's factor): these need argument registers.
    pub datapath_params: Vec<String>,
    /// Declared parameters only the **host loop** reads (convergence
    /// threshold, depth horizon, init values): no datapath register.
    pub host_params: Vec<String>,
    /// Declared parameters nothing references.
    pub unused_params: Vec<String>,
}

impl ProgramFacts {
    /// Does the lowered reduce stage need a conflict-resolution unit in
    /// front of the banked accumulator? Idempotent reduces tolerate
    /// same-bank replays, so the unit is elided.
    pub fn needs_conflict_unit(&self) -> bool {
        !self.reduce.idempotent
    }
}

/// Derive the full fact record for a program. Pure structural analysis —
/// no graph, no bindings; parameter references are judged by their
/// declared intervals.
pub fn analyze(p: &GasProgram) -> ProgramFacts {
    let reduce = ReduceAlgebra::of(p.reduce, p.state);

    let convergence = match &p.convergence {
        Convergence::FixedIterations(k) => ConvergenceClass::FixedIterations(*k),
        Convergence::DeltaBelow(_) => {
            ConvergenceClass::ContractionByDelta { iteration_bound: p.delta_bound() }
        }
        Convergence::EmptyFrontier | Convergence::NoChange => ConvergenceClass::FixpointByDepth,
    };

    // Scatter-race check: every concurrent write to a destination must
    // flow through the declared reduce. A visited-gate over a
    // non-idempotent accumulator double-counts on replay — a data race,
    // not a reordering. (A non-commutative reduce would race too; none of
    // the current operators is, but the derivation keeps the condition.)
    let parallel_safety = if !reduce.commutative
        || (p.writeback == Writeback::IfUnvisited && !reduce.idempotent)
    {
        ParallelSafety::Racy
    } else if !reduce.associative {
        ParallelSafety::OrderSensitive
    } else {
        ParallelSafety::BitExact
    };

    // Pull early-exit: with a per-superstep-constant message, a
    // visited-gate writeback and an idempotent-monotone reduce, the first
    // frontier in-neighbor's message already equals the reduction of all
    // of them — the scan may stop. (Property-tested equivalent to the
    // engine's previous `ConstPerIter && IfUnvisited && reduce != Sum`.)
    let pull_early_exit = CompiledApply::compile(&p.apply) == CompiledApply::ConstPerIter
        && p.writeback == Writeback::IfUnvisited
        && reduce.idempotent
        && reduce.monotonicity != Monotonicity::NonMonotone;

    let damped_iteration = matches!(p.writeback, Writeback::DampedSum(_));
    let damping = match &p.writeback {
        Writeback::DampedSum(d) => Some(Interval::of_scalar(d, p)),
        _ => None,
    };
    let depth_interval = p.depth_limit.as_ref().map(|s| Interval::of_scalar(s, p));

    // Parameter liveness: datapath operands (Apply terms, the damped
    // factor the writer consumes) vs host-loop scalars (thresholds,
    // horizons, init values) vs declared-but-unreferenced.
    let mut datapath: Vec<&str> = Vec::new();
    p.apply.param_names(&mut datapath);
    if let Writeback::DampedSum(Scalar::Param(name)) = &p.writeback {
        datapath.push(name);
    }
    let referenced = p.param_refs();
    let mut datapath_params = Vec::new();
    let mut host_params = Vec::new();
    let mut unused_params = Vec::new();
    for spec in p.params.iter() {
        let name = spec.name.as_str();
        if datapath.contains(&name) {
            datapath_params.push(name.to_string());
        } else if referenced.contains(&name) {
            host_params.push(name.to_string());
        } else {
            unused_params.push(name.to_string());
        }
    }

    ProgramFacts {
        reduce,
        convergence,
        parallel_safety,
        pull_early_exit,
        damped_iteration,
        damping,
        depth_interval,
        datapath_params,
        host_params,
        unused_params,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::dsl::program::DELTA_CONVERGENCE_SUPERSTEP_BOUND;

    #[test]
    fn reduce_algebra_table() {
        let min = ReduceAlgebra::of(ReduceOp::Min, StateType::I32);
        assert!(min.idempotent && min.commutative && min.associative);
        assert_eq!(min.monotonicity, Monotonicity::Decreasing);
        let max = ReduceAlgebra::of(ReduceOp::Max, StateType::F32);
        assert!(max.idempotent && max.associative);
        assert_eq!(max.monotonicity, Monotonicity::Increasing);
        // float summation is commutative but not bit-exactly associative
        let fsum = ReduceAlgebra::of(ReduceOp::Sum, StateType::F32);
        assert!(!fsum.idempotent && fsum.commutative && !fsum.associative);
        let isum = ReduceAlgebra::of(ReduceOp::Sum, StateType::I32);
        assert!(isum.associative, "integer addition is associative");
    }

    #[test]
    fn library_certificates() {
        // traversals: idempotent min/max reduces shard bit-exactly
        for p in [algorithms::bfs(), algorithms::sssp(), algorithms::wcc()] {
            let f = analyze(&p);
            assert_eq!(f.parallel_safety, ParallelSafety::BitExact, "{}", p.name);
            assert!(!f.needs_conflict_unit(), "{}", p.name);
        }
        // float sums are order-sensitive and keep the conflict unit
        for p in [algorithms::pagerank(), algorithms::spmv()] {
            let f = analyze(&p);
            assert_eq!(f.parallel_safety, ParallelSafety::OrderSensitive, "{}", p.name);
            assert!(f.needs_conflict_unit(), "{}", p.name);
        }
    }

    #[test]
    fn pull_early_exit_only_for_visited_gate_traversals() {
        assert!(analyze(&algorithms::bfs()).pull_early_exit);
        assert!(analyze(&algorithms::reachability()).pull_early_exit);
        for p in [
            algorithms::sssp(),
            algorithms::wcc(),
            algorithms::pagerank(),
            algorithms::spmv(),
            algorithms::widest_path(),
        ] {
            assert!(!analyze(&p).pull_early_exit, "{}", p.name);
        }
    }

    #[test]
    fn convergence_class_surfaces_internal_bound() {
        let f = analyze(&algorithms::pagerank());
        assert_eq!(
            f.convergence,
            ConvergenceClass::ContractionByDelta {
                iteration_bound: DELTA_CONVERGENCE_SUPERSTEP_BOUND
            }
        );
        assert!(f.damped_iteration);
        assert_eq!(analyze(&algorithms::spmv()).convergence, ConvergenceClass::FixedIterations(1));
        assert_eq!(analyze(&algorithms::bfs()).convergence, ConvergenceClass::FixpointByDepth);
    }

    #[test]
    fn damping_interval_comes_from_declared_range() {
        let f = analyze(&algorithms::pagerank());
        assert_eq!(f.damping, Some(Interval { lo: 0.0, hi: 1.0 }));
        assert!(analyze(&algorithms::bfs()).damping.is_none());
    }

    #[test]
    fn parameter_liveness_split() {
        // pagerank: damping feeds the writer (datapath), tolerance only
        // the host convergence loop
        let f = analyze(&algorithms::pagerank());
        assert_eq!(f.datapath_params, vec!["damping"]);
        assert_eq!(f.host_params, vec!["tolerance"]);
        assert!(f.unused_params.is_empty());
        // bfs: max_depth is a host-side horizon — no datapath register
        let f = analyze(&algorithms::bfs());
        assert!(f.datapath_params.is_empty());
        assert_eq!(f.host_params, vec!["max_depth"]);
    }
}
