//! The clippy-style lint engine over [`GasProgram`]s: every diagnostic
//! carries a stable `JG***` code, a deny/warn level, and a message naming
//! the *user's* interface (Reduce, Writeback::DampedSum, depth_limit, …)
//! rather than translator internals.
//!
//! Deny-level lints are programs that cannot execute correctly — they are
//! what [`crate::dsl::validate::check`] (and therefore every compile path)
//! rejects, and they are **not suppressible**. Warn-level lints flag
//! legal-but-noteworthy shapes (order-sensitive float sums, unused
//! parameters) and can be silenced per program with
//! [`GasProgramBuilder::allow`].
//!
//! The full catalog with rationale lives in the [module docs of
//! `analysis`](super). Run it from the CLI: `jgraph lint [--emit json]`.
//!
//! [`GasProgramBuilder::allow`]: crate::dsl::builder::GasProgramBuilder::allow

use crate::dsl::apply::{ApplyExpr, BinOp};
use crate::dsl::program::{Convergence, GasProgram, InitPolicy, ReduceOp, StateType, Writeback};

use super::facts::{analyze, Interval};

/// Severity of a diagnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LintLevel {
    /// The program cannot execute correctly; compilation rejects it.
    /// Never suppressible.
    Deny,
    /// Legal but noteworthy; suppressible via `GasProgramBuilder::allow`.
    Warn,
}

/// Stable lint codes. The numeric ranges are part of the contract:
/// `JG0**` = deny, `JG1**` = warn. Codes are never reused or renumbered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LintCode {
    /// `Reduce(Sum)` driving `Writeback::IfUnvisited` double-counts.
    Jg001SumGatesVisited,
    /// `Writeback::DampedSum` without `Reduce(Sum)`.
    Jg002DampedNeedsSumReduce,
    /// `Writeback::DampedSum` over I32 state.
    Jg003DampedNeedsF32,
    /// `Writeback::DampedSum` combined with a `depth_limit`.
    Jg004DampedWithDepthLimit,
    /// A structural reference to a parameter the signature never declares.
    Jg005UndeclaredParam,
    /// A declared default outside the parameter's own range.
    Jg006DefaultOutsideRange,
    /// A `depth_limit` that is below one superstep for every allowed
    /// binding.
    Jg007DepthLimitNeverRuns,
    /// Division in the Apply expression over I32 state.
    Jg008IntDivision,
    /// `Convergence::DeltaBelow` over I32 state.
    Jg009DeltaNeedsF32,
    /// An infinite init default with I32 state.
    Jg010InfiniteIntInit,
    /// `Convergence::FixedIterations(0)`.
    Jg011ZeroIterations,
    /// A damping factor that is `>= 1` for every allowed binding: the
    /// damped iteration is statically divergent.
    Jg012DivergentDamping,
    /// A declared parameter nothing references.
    Jg101UnusedParam,
    /// `Reduce(Sum)` over F32 state: parallel execution is
    /// order-sensitive, not bit-exact.
    Jg102FloatSumOrderSensitive,
    /// A damping range that *admits* divergent (`> 1`) bindings.
    Jg103DampingRangeAdmitsDivergent,
    /// `EdgeOpKind::Pr` tag on a program whose writeback is not damped:
    /// dispatch follows the writeback shape, so the tag is misleading.
    Jg104PrKindNotDamped,
}

impl LintCode {
    /// The stable code string (`"JG001"`).
    pub fn code(&self) -> &'static str {
        match self {
            LintCode::Jg001SumGatesVisited => "JG001",
            LintCode::Jg002DampedNeedsSumReduce => "JG002",
            LintCode::Jg003DampedNeedsF32 => "JG003",
            LintCode::Jg004DampedWithDepthLimit => "JG004",
            LintCode::Jg005UndeclaredParam => "JG005",
            LintCode::Jg006DefaultOutsideRange => "JG006",
            LintCode::Jg007DepthLimitNeverRuns => "JG007",
            LintCode::Jg008IntDivision => "JG008",
            LintCode::Jg009DeltaNeedsF32 => "JG009",
            LintCode::Jg010InfiniteIntInit => "JG010",
            LintCode::Jg011ZeroIterations => "JG011",
            LintCode::Jg012DivergentDamping => "JG012",
            LintCode::Jg101UnusedParam => "JG101",
            LintCode::Jg102FloatSumOrderSensitive => "JG102",
            LintCode::Jg103DampingRangeAdmitsDivergent => "JG103",
            LintCode::Jg104PrKindNotDamped => "JG104",
        }
    }

    pub fn level(&self) -> LintLevel {
        if self.code().as_bytes()[2] == b'0' {
            LintLevel::Deny
        } else {
            LintLevel::Warn
        }
    }

    /// One-line summary for the catalog and `jgraph lint` listings.
    pub fn summary(&self) -> &'static str {
        match self {
            LintCode::Jg001SumGatesVisited => {
                "Reduce(Sum) cannot drive Writeback::IfUnvisited (not idempotent)"
            }
            LintCode::Jg002DampedNeedsSumReduce => "Writeback::DampedSum requires Reduce(Sum)",
            LintCode::Jg003DampedNeedsF32 => "Writeback::DampedSum requires F32 state",
            LintCode::Jg004DampedWithDepthLimit => {
                "Writeback::DampedSum cannot combine with a depth_limit"
            }
            LintCode::Jg005UndeclaredParam => "reference to an undeclared runtime parameter",
            LintCode::Jg006DefaultOutsideRange => "parameter default outside its declared range",
            LintCode::Jg007DepthLimitNeverRuns => "depth_limit below one superstep",
            LintCode::Jg008IntDivision => "Apply divides but the I32 datapath has no divider",
            LintCode::Jg009DeltaNeedsF32 => "Convergence::DeltaBelow requires F32 state",
            LintCode::Jg010InfiniteIntInit => "infinite init default with I32 state",
            LintCode::Jg011ZeroIterations => "FixedIterations(0) never runs",
            LintCode::Jg012DivergentDamping => "damping >= 1 for every binding (divergent)",
            LintCode::Jg101UnusedParam => "declared parameter is never referenced",
            LintCode::Jg102FloatSumOrderSensitive => {
                "float Sum reduce: parallel execution is order-sensitive"
            }
            LintCode::Jg103DampingRangeAdmitsDivergent => {
                "damping range admits divergent (> 1) bindings"
            }
            LintCode::Jg104PrKindNotDamped => {
                "EdgeOpKind::Pr tag on a non-damped writeback (generic dispatch)"
            }
        }
    }

    /// Every code, catalog order.
    pub fn all() -> [LintCode; 16] {
        [
            LintCode::Jg001SumGatesVisited,
            LintCode::Jg002DampedNeedsSumReduce,
            LintCode::Jg003DampedNeedsF32,
            LintCode::Jg004DampedWithDepthLimit,
            LintCode::Jg005UndeclaredParam,
            LintCode::Jg006DefaultOutsideRange,
            LintCode::Jg007DepthLimitNeverRuns,
            LintCode::Jg008IntDivision,
            LintCode::Jg009DeltaNeedsF32,
            LintCode::Jg010InfiniteIntInit,
            LintCode::Jg011ZeroIterations,
            LintCode::Jg012DivergentDamping,
            LintCode::Jg101UnusedParam,
            LintCode::Jg102FloatSumOrderSensitive,
            LintCode::Jg103DampingRangeAdmitsDivergent,
            LintCode::Jg104PrKindNotDamped,
        ]
    }
}

/// One diagnostic: a code, its level, the user-facing interface it is
/// anchored to, and a full message (which always ends with the `[JG***]`
/// code so log greps stay stable).
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: LintCode,
    pub level: LintLevel,
    /// The DSL interface the finding is anchored to (the "span").
    pub interface: &'static str,
    pub message: String,
}

impl Diagnostic {
    fn new(code: LintCode, interface: &'static str, message: String) -> Self {
        let message = format!("{message} [{}]", code.code());
        Diagnostic { code, level: code.level(), interface, message }
    }
}

/// Run every lint over a program. Deny diagnostics come first, in the
/// stable catalog order compilation error messages rely on; warn
/// diagnostics follow, with the program's
/// [`allowed_lints`](GasProgram::allowed_lints) suppressed (deny lints
/// ignore the allow list).
pub fn lint(p: &GasProgram) -> Vec<Diagnostic> {
    let facts = analyze(p);
    let mut out = Vec::new();

    // --- deny lints, in the order the legacy validator checked them
    if p.reduce == ReduceOp::Sum && p.writeback == Writeback::IfUnvisited {
        out.push(Diagnostic::new(
            LintCode::Jg001SumGatesVisited,
            "Reduce",
            format!(
                "program {:?}: Reduce(Sum) cannot drive Writeback::IfUnvisited — \
                 accumulated sums are not idempotent across supersteps",
                p.name
            ),
        ));
    }

    if let Writeback::DampedSum(_) = &p.writeback {
        if p.reduce != ReduceOp::Sum {
            out.push(Diagnostic::new(
                LintCode::Jg002DampedNeedsSumReduce,
                "Writeback::DampedSum",
                format!(
                    "program {:?}: Writeback::DampedSum requires Reduce(Sum) — \
                     damping redistributes summed rank mass",
                    p.name
                ),
            ));
        }
        if p.state == StateType::I32 {
            out.push(Diagnostic::new(
                LintCode::Jg003DampedNeedsF32,
                "Writeback::DampedSum",
                format!("program {:?}: Writeback::DampedSum requires F32 state", p.name),
            ));
        }
        if p.depth_limit.is_some() {
            out.push(Diagnostic::new(
                LintCode::Jg004DampedWithDepthLimit,
                "Writeback::DampedSum",
                format!(
                    "program {:?}: Writeback::DampedSum cannot combine with a \
                     depth_limit — damped iteration converges on delta, not depth",
                    p.name
                ),
            ));
        }
    }

    for name in p.param_refs() {
        if p.params.get(name).is_none() {
            out.push(Diagnostic::new(
                LintCode::Jg005UndeclaredParam,
                "GasProgramBuilder::param",
                format!(
                    "program {:?}: references undeclared parameter {:?} — declare \
                     it with GasProgramBuilder::param (declared: {})",
                    p.name,
                    name,
                    if p.params.is_empty() {
                        "none".to_string()
                    } else {
                        p.params.names().join(", ")
                    }
                ),
            ));
        }
    }

    for spec in p.params.iter() {
        if let Some(default) = spec.default {
            let lo = spec.min.unwrap_or(f64::NEG_INFINITY);
            let hi = spec.max.unwrap_or(f64::INFINITY);
            if default < lo || default > hi {
                out.push(Diagnostic::new(
                    LintCode::Jg006DefaultOutsideRange,
                    "ParamSpec",
                    format!(
                        "program {:?}: parameter {:?} default {} outside its own \
                         range [{}, {}]",
                        p.name, spec.name, default, lo, hi
                    ),
                ));
            }
        }
    }

    // Interval analysis over the depth horizon: a limit whose *entire*
    // allowed range sits below one superstep can never run — for a
    // literal this is the legacy check, for a parameter it rejects the
    // declaration whose every binding is impossible.
    if let (Some(limit), Some(iv)) = (&p.depth_limit, facts.depth_interval) {
        if iv.hi < 1.0 {
            out.push(Diagnostic::new(
                LintCode::Jg007DepthLimitNeverRuns,
                "depth_limit",
                format!(
                    "program {:?}: depth_limit {} would never run a superstep",
                    p.name,
                    limit.render()
                ),
            ));
        }
    }

    if p.state == StateType::I32 && expr_has_div(&p.apply) {
        out.push(Diagnostic::new(
            LintCode::Jg008IntDivision,
            "Apply",
            format!(
                "program {:?}: Apply uses division but state is I32 — the integer \
                 datapath has no divider; use F32 state",
                p.name
            ),
        ));
    }

    if matches!(p.convergence, Convergence::DeltaBelow(_)) && p.state == StateType::I32 {
        out.push(Diagnostic::new(
            LintCode::Jg009DeltaNeedsF32,
            "Convergence::DeltaBelow",
            format!("program {:?}: Convergence::DeltaBelow requires F32 state", p.name),
        ));
    }

    if let InitPolicy::RootAndDefault { default, .. } = &p.init {
        if default.as_lit().is_some_and(f64::is_infinite) && p.state == StateType::I32 {
            out.push(Diagnostic::new(
                LintCode::Jg010InfiniteIntInit,
                "InitPolicy",
                format!(
                    "program {:?}: infinite init default with I32 state; use -1 \
                     (unvisited sentinel) instead",
                    p.name
                ),
            ));
        }
    }

    if p.convergence == Convergence::FixedIterations(0) {
        out.push(Diagnostic::new(
            LintCode::Jg011ZeroIterations,
            "Convergence::FixedIterations",
            format!("program {:?}: FixedIterations(0) would never run", p.name),
        ));
    }

    // Interval analysis over the damping factor: when every allowed
    // binding is >= 1 the contraction factor is >= 1 and the delta
    // condition can never be met — statically divergent.
    if let (Writeback::DampedSum(d), Some(iv)) = (&p.writeback, facts.damping.as_ref()) {
        if iv.lo >= 1.0 {
            out.push(Diagnostic::new(
                LintCode::Jg012DivergentDamping,
                "Writeback::DampedSum",
                format!(
                    "program {:?}: Writeback::DampedSum damping {} is >= 1 for \
                     every allowed binding — the damped iteration cannot converge",
                    p.name,
                    d.render()
                ),
            ));
        }
    }

    // --- warn lints (suppressible)
    for name in &facts.unused_params {
        out.push(Diagnostic::new(
            LintCode::Jg101UnusedParam,
            "GasProgramBuilder::param",
            format!(
                "program {:?}: parameter {:?} is declared but nothing references \
                 it — bindings will be accepted and ignored",
                p.name, name
            ),
        ));
    }

    if p.reduce == ReduceOp::Sum && p.state == StateType::F32 {
        out.push(Diagnostic::new(
            LintCode::Jg102FloatSumOrderSensitive,
            "Reduce",
            format!(
                "program {:?}: Reduce(Sum) over F32 state accumulates in traversal \
                 order — parallel scatter is certified order-sensitive, not bit-exact",
                p.name
            ),
        ));
    }

    if let (Writeback::DampedSum(d), Some(iv)) = (&p.writeback, facts.damping.as_ref()) {
        if iv.hi > 1.0 && iv.lo < 1.0 {
            out.push(Diagnostic::new(
                LintCode::Jg103DampingRangeAdmitsDivergent,
                "Writeback::DampedSum",
                format!(
                    "program {:?}: damping {} admits bindings > 1, which diverge — \
                     tighten the declared range",
                    p.name,
                    d.render()
                ),
            ));
        }
    }

    if p.kind == Some(crate::dsl::program::EdgeOpKind::Pr) && !facts.damped_iteration {
        out.push(Diagnostic::new(
            LintCode::Jg104PrKindNotDamped,
            "GasProgramBuilder::kind",
            format!(
                "program {:?}: tagged EdgeOpKind::Pr but the writeback is {:?} — \
                 engine dispatch follows the writeback shape, so this program runs \
                 the generic path, not the damped iteration",
                p.name, p.writeback
            ),
        ));
    }

    // Suppression: warns named in the program's allow list drop out; deny
    // lints are never suppressible.
    out.retain(|d| {
        d.level == LintLevel::Deny || !p.allowed_lints.iter().any(|a| a == d.code.code())
    });
    out
}

/// The first deny-level diagnostic, if any — what `validate::check` (and
/// through it every compile path) reports.
pub fn first_deny(p: &GasProgram) -> Option<Diagnostic> {
    lint(p).into_iter().find(|d| d.level == LintLevel::Deny)
}

fn expr_has_div(e: &ApplyExpr) -> bool {
    match e {
        ApplyExpr::Term(_) => false,
        ApplyExpr::Unary(_, a) => expr_has_div(a),
        ApplyExpr::Binary(op, a, b) => *op == BinOp::Div || expr_has_div(a) || expr_has_div(b),
    }
}

/// Escape a string for JSON embedding (no external deps).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render one program's diagnostics as a JSON object (the `--emit json`
/// payload element).
pub fn diagnostics_json(program: &str, diags: &[Diagnostic]) -> String {
    let items: Vec<String> = diags
        .iter()
        .map(|d| {
            format!(
                "{{ \"code\": \"{}\", \"level\": \"{}\", \"interface\": \"{}\", \"message\": \"{}\" }}",
                d.code.code(),
                match d.level {
                    LintLevel::Deny => "deny",
                    LintLevel::Warn => "warn",
                },
                json_escape(d.interface),
                json_escape(&d.message)
            )
        })
        .collect();
    format!(
        "{{ \"program\": \"{}\", \"diagnostics\": [{}] }}",
        json_escape(program),
        items.join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::apply::ApplyExpr;
    use crate::dsl::params::{ParamSignature, ParamSpec, Scalar};
    use crate::dsl::program::{Direction, EdgeOpKind, FrontierPolicy};

    /// A minimal well-formed program to corrupt per test. Hand-assembled
    /// (not via the builder) so deny-level shapes can be constructed.
    fn base() -> GasProgram {
        GasProgram {
            name: "lint-case".into(),
            state: StateType::F32,
            init: InitPolicy::Constant(0.0.into()),
            apply: ApplyExpr::src(),
            reduce: ReduceOp::Min,
            writeback: Writeback::MinCombine,
            frontier: FrontierPolicy::All,
            direction: Direction::Push,
            convergence: Convergence::NoChange,
            uses_weights: false,
            kind: None,
            params: ParamSignature::default(),
            depth_limit: None,
            delta_iteration_bound: None,
            allowed_lints: Vec::new(),
        }
    }

    fn codes(p: &GasProgram) -> Vec<&'static str> {
        lint(p).iter().map(|d| d.code.code()).collect()
    }

    #[test]
    fn jg001_sum_gates_visited() {
        let mut p = base();
        p.reduce = ReduceOp::Sum;
        p.writeback = Writeback::IfUnvisited;
        assert!(codes(&p).contains(&"JG001"), "{:?}", codes(&p));
        let d = first_deny(&p).unwrap();
        assert!(d.message.contains("not idempotent") && d.message.ends_with("[JG001]"));
        assert_eq!(d.interface, "Reduce");
    }

    #[test]
    fn jg002_jg003_jg004_damped_shape() {
        let mut p = base();
        p.writeback = Writeback::DampedSum(0.85.into());
        assert!(codes(&p).contains(&"JG002"), "Min reduce under DampedSum");
        p.reduce = ReduceOp::Sum;
        p.state = StateType::I32;
        assert!(codes(&p).contains(&"JG003"));
        p.state = StateType::F32;
        p.depth_limit = Some(3.0.into());
        assert!(codes(&p).contains(&"JG004"));
    }

    #[test]
    fn jg005_undeclared_param() {
        let mut p = base();
        p.apply = ApplyExpr::src().mul(ApplyExpr::param("beta"));
        let d = first_deny(&p).unwrap();
        assert_eq!(d.code.code(), "JG005");
        assert!(d.message.contains("undeclared parameter \"beta\""));
    }

    #[test]
    fn jg006_default_outside_range() {
        let mut p = base();
        p.params.declare(ParamSpec::new("alpha", 2.0).with_range(0.0, 1.0));
        p.apply = ApplyExpr::src().mul(ApplyExpr::param("alpha"));
        assert_eq!(first_deny(&p).unwrap().code.code(), "JG006");
    }

    #[test]
    fn jg007_depth_limit_never_runs_literal_and_interval() {
        let mut p = base();
        p.depth_limit = Some(0.0.into());
        assert_eq!(first_deny(&p).unwrap().code.code(), "JG007");
        // parameter whose whole declared range is below one superstep
        let mut p = base();
        p.params.declare(ParamSpec::new("h", 0.5).with_range(0.0, 0.9));
        p.depth_limit = Some(Scalar::param("h"));
        let d = first_deny(&p).unwrap();
        assert_eq!(d.code.code(), "JG007");
        assert!(d.message.contains("would never run a superstep"));
    }

    #[test]
    fn jg008_int_division() {
        let mut p = base();
        p.state = StateType::I32;
        p.apply = ApplyExpr::bin(BinOp::Div, ApplyExpr::src(), ApplyExpr::constant(2.0));
        assert_eq!(first_deny(&p).unwrap().code.code(), "JG008");
    }

    #[test]
    fn jg009_delta_needs_f32() {
        let mut p = base();
        p.state = StateType::I32;
        p.convergence = Convergence::DeltaBelow(0.1.into());
        assert_eq!(first_deny(&p).unwrap().code.code(), "JG009");
    }

    #[test]
    fn jg010_infinite_int_init() {
        let mut p = base();
        p.state = StateType::I32;
        p.init = InitPolicy::root_and_default(0.0, f64::INFINITY);
        assert_eq!(first_deny(&p).unwrap().code.code(), "JG010");
    }

    #[test]
    fn jg011_zero_iterations() {
        let mut p = base();
        p.convergence = Convergence::FixedIterations(0);
        assert_eq!(first_deny(&p).unwrap().code.code(), "JG011");
    }

    #[test]
    fn jg012_statically_divergent_damping() {
        // literal damping >= 1
        let mut p = base();
        p.reduce = ReduceOp::Sum;
        p.writeback = Writeback::DampedSum(1.5.into());
        assert_eq!(first_deny(&p).unwrap().code.code(), "JG012");
        // parameter whose whole declared range is >= 1
        let mut p = base();
        p.reduce = ReduceOp::Sum;
        p.params.declare(ParamSpec::new("d", 1.2).with_range(1.1, 2.0));
        p.writeback = Writeback::DampedSum(Scalar::param("d"));
        let d = first_deny(&p).unwrap();
        assert_eq!(d.code.code(), "JG012");
        assert!(d.message.contains("cannot converge"));
    }

    #[test]
    fn jg101_unused_param_warns_and_suppresses() {
        let mut p = base();
        p.params.declare(ParamSpec::new("ghost", 1.0));
        let diags = lint(&p);
        let w = diags.iter().find(|d| d.code.code() == "JG101").unwrap();
        assert_eq!(w.level, LintLevel::Warn);
        assert!(first_deny(&p).is_none(), "unused param is warn, not deny");
        p.allowed_lints.push("JG101".into());
        assert!(!codes(&p).contains(&"JG101"), "allow list suppresses warns");
    }

    #[test]
    fn jg102_float_sum_warns_library_pagerank() {
        let diags = lint(&crate::dsl::algorithms::pagerank());
        assert!(diags.iter().any(|d| d.code.code() == "JG102"));
        assert!(diags.iter().all(|d| d.level == LintLevel::Warn), "{diags:?}");
    }

    #[test]
    fn jg103_damping_range_admitting_divergence() {
        let mut p = base();
        p.reduce = ReduceOp::Sum;
        p.params.declare(ParamSpec::new("d", 0.9).with_range(0.0, 1.5));
        p.writeback = Writeback::DampedSum(Scalar::param("d"));
        let diags = lint(&p);
        let w = diags.iter().find(|d| d.code.code() == "JG103").unwrap();
        assert_eq!(w.level, LintLevel::Warn);
        assert!(first_deny(&p).is_none());
    }

    #[test]
    fn jg104_pr_kind_without_damped_writeback() {
        let mut p = base();
        p.reduce = ReduceOp::Sum;
        p.writeback = Writeback::Overwrite;
        p.kind = Some(EdgeOpKind::Pr);
        let diags = lint(&p);
        assert!(diags.iter().any(|d| d.code.code() == "JG104"), "{diags:?}");
    }

    #[test]
    fn deny_lints_are_not_suppressible() {
        let mut p = base();
        p.reduce = ReduceOp::Sum;
        p.writeback = Writeback::IfUnvisited;
        p.allowed_lints.push("JG001".into());
        assert!(first_deny(&p).is_some(), "deny ignores the allow list");
    }

    #[test]
    fn library_algorithms_have_zero_deny_diagnostics() {
        for p in crate::dsl::algorithms::all() {
            assert!(first_deny(&p).is_none(), "{}: {:?}", p.name, first_deny(&p));
        }
    }

    #[test]
    fn code_levels_follow_numbering() {
        for c in LintCode::all() {
            let expect =
                if c.code().starts_with("JG0") { LintLevel::Deny } else { LintLevel::Warn };
            assert_eq!(c.level(), expect, "{}", c.code());
            assert!(!c.summary().is_empty());
        }
        assert_eq!(LintCode::all().iter().filter(|c| c.level() == LintLevel::Deny).count(), 12);
    }

    #[test]
    fn json_payload_escapes_quotes() {
        let mut p = base();
        p.reduce = ReduceOp::Sum;
        p.writeback = Writeback::IfUnvisited;
        let js = diagnostics_json(&p.name, &lint(&p));
        assert!(js.contains("\"code\": \"JG001\""));
        assert!(js.contains("\\\"lint-case\\\""), "program name quotes escaped: {js}");
    }
}
