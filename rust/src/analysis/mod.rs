//! # Static program analysis — facts, certificates, and the lint catalog
//!
//! The paper's light-weight translator "deliberately skips general-purpose
//! semantic analysis" (§V); this module is the *domain-specific* analysis
//! that replaces it. [`analyze`] derives a [`ProgramFacts`] record from a
//! [`GasProgram`](crate::dsl::program::GasProgram) — reduce algebra,
//! convergence class, parameter intervals, parallel-scatter safety — and
//! three consumers read it:
//!
//! 1. the **lint engine** ([`lint`]) turns impossible or suspicious
//!    combinations into stable `JG***` diagnostics (run inside
//!    `Session::compile` and by the `jgraph lint` CLI subcommand);
//! 2. the **engine** dispatches the damped iteration and gates pull
//!    early-exit on derived facts instead of hard-coded shape checks, and
//!    stamps the [`ParallelSafety`] certificate on every
//!    `CompiledPipeline`;
//! 3. the **translator** elides the reduce conflict-resolution unit for
//!    idempotent reduces and narrows the argument register file to
//!    datapath-live parameters (visible in `translate --emit stats`).
//!
//! ## Lint catalog
//!
//! Codes are stable: never reused, never renumbered. `JG0**` are
//! **deny**-level — the program cannot execute correctly, compilation
//! rejects it, and the diagnostic cannot be suppressed. `JG1**` are
//! **warn**-level — legal but noteworthy, suppressible per program with
//! [`GasProgramBuilder::allow`]`("JG1xx")`.
//!
//! | Code | Level | What it detects | Why |
//! |------|-------|-----------------|-----|
//! | JG001 | deny | `Reduce(Sum)` driving `Writeback::IfUnvisited` | a sum is not idempotent: re-delivery across supersteps double-counts behind the visited gate — a data race, not a reordering |
//! | JG002 | deny | `Writeback::DampedSum` without `Reduce(Sum)` | damping redistributes *summed* rank mass; min/max reductions have no mass to redistribute |
//! | JG003 | deny | `Writeback::DampedSum` over I32 state | the damped update `(1-d)/N + d·x` needs the float datapath |
//! | JG004 | deny | `Writeback::DampedSum` with a `depth_limit` | damped iteration converges on delta, not depth; a horizon would truncate, not converge |
//! | JG005 | deny | reference to an undeclared parameter | `GasProgramBuilder::param` is the single declaration site; undeclared names cannot be bound or register-allocated |
//! | JG006 | deny | a declared default outside its own range | a default-only query would immediately violate the declared contract |
//! | JG007 | deny | a `depth_limit` below one superstep for **every** allowed binding | interval analysis over the declared range: the program can never run a superstep |
//! | JG008 | deny | division in Apply over I32 state | the integer datapath has no divider |
//! | JG009 | deny | `Convergence::DeltaBelow` over I32 state | L1 deltas are float quantities |
//! | JG010 | deny | infinite init default with I32 state | i32 has no infinity; use the `-1` unvisited sentinel |
//! | JG011 | deny | `Convergence::FixedIterations(0)` | the program would never run |
//! | JG012 | deny | damping `>= 1` for **every** allowed binding | interval analysis: the contraction factor is ≥ 1, so the delta condition can never be met — statically divergent |
//! | JG101 | warn | a declared parameter nothing references | bindings are accepted and silently ignored |
//! | JG102 | warn | `Reduce(Sum)` over F32 state | float summation is not bit-exactly associative: the parallel certificate is order-sensitive, not bit-exact |
//! | JG103 | warn | a damping range that *admits* `> 1` bindings | some legal bindings diverge; tighten the declared range |
//! | JG104 | warn | `EdgeOpKind::Pr` tag with a non-damped writeback | engine dispatch follows the writeback shape; the tag is misleading and the program runs the generic path |
//!
//! To suppress a warn:
//! `GasProgramBuilder::new("x")....allow("JG101").build()`. Deny codes
//! ignore the allow list by design.
//!
//! [`GasProgramBuilder::allow`]: crate::dsl::builder::GasProgramBuilder::allow

pub mod facts;
pub mod lint;

pub use facts::{
    analyze, ConvergenceClass, Interval, Monotonicity, ParallelSafety, ProgramFacts,
    ReduceAlgebra,
};
pub use lint::{lint, Diagnostic, LintCode, LintLevel};
