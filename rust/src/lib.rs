//! # JGraph — a light-weight FPGA programming framework for graph applications
//!
//! Reproduction of *"On The Design of a Light-weight FPGA Programming
//! Framework for Graph Applications"* (Wang, Guo, Li — SJTU, cs.AR 2022) as a
//! three-layer rust + JAX + Pallas system. See `DESIGN.md` for the full
//! inventory and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! The paper's two contributions map onto this crate as:
//!
//! * **the graph DSL** ([`dsl`]) — 25+ atomic operators in three abstraction
//!   levels (atomic op / function / algorithm), GAS programming model,
//!   preprocessing primitives ([`prep`]);
//! * **the light-weight translator** ([`translator`]) — lowers DSL programs
//!   onto a fixed hardware-module library, emits compact HDL + host-C code,
//!   estimates FPGA resources, and schedules pipelines × PEs ([`sched`]),
//!   assisted by a host↔FPGA communication manager ([`comm`]).
//!
//! Between the two sits the **program-facts analyzer** ([`analysis`]): a
//! static pass deriving reduce algebra, convergence class, parameter
//! intervals, and the parallel-safety certificate from every program. It
//! powers a clippy-style lint engine with stable `JG***` codes (see the
//! [lint catalog](analysis#lint-catalog), or run `jgraph lint`), drives
//! engine dispatch, and lets the translator elide hardware a proven-safe
//! program does not need.
//!
//! Because no FPGA is attached, the Alveo U200 target is **simulated**:
//! [`accel`] is a cycle-level model of the generated design (pipelines, BRAM
//! vertex cache, DDR4 channels), while the design's *numeric behaviour* runs
//! as AOT-compiled XLA — JAX supersteps with a Pallas edge-program kernel,
//! lowered to HLO text at build time (`make artifacts`) and executed from
//! [`runtime`] via PJRT. Python is never on the request path.
//!
//! ```text
//!   DSL program ──translate──▶ ModuleGraph ──▶ HDL + host C   (translator)
//!        │                          │
//!        │                          ├──▶ cycle model ─▶ MTEPS  (accel)
//!        └──────── engine ──────────┴──▶ XLA superstep loop    (runtime)
//! ```
//!
//! The API follows the paper's economics — tens of seconds to generate a
//! design, then many fast traversals — as a **compile-once / run-many
//! lifecycle**: a [`engine::Session`] owns process-wide state, `compile`
//! pays the per-program costs (translate, schedule, modeled synthesis +
//! flash, XLA artifact lookup) exactly once, `load` pays the per-graph
//! costs (Reorder/Partition/Layout, transport) exactly once, and `run` is
//! the cheap per-query call. The [`serve`] subsystem (`jgraph serve`)
//! keeps that lifecycle resident: an always-on daemon with a
//! graph/pipeline registry, arrival batching into parallel sweeps,
//! tail-latency accounting, and a fault-tolerant query core — per-query
//! deadlines ([`sched::Deadline`]), panic isolation, retry with seeded
//! backoff, and a deterministic fault-injection harness
//! ([`sched::FaultPlan`]) for chaos drills.
//!
//! Quickstart (see `examples/quickstart.rs`; `examples/multi_query.rs`
//! shows the amortization):
//!
//! ```no_run
//! use jgraph::prelude::*;
//!
//! let session = Session::new(SessionConfig::default());
//! let pipeline = session.compile(&algorithms::bfs()).unwrap(); // once
//!
//! let graph = jgraph::graph::generate::email_eu_core_like(1);
//! let mut bound = pipeline
//!     .load(&graph, PrepOptions::named("email-Eu-core")) // once per graph
//!     .unwrap();
//!
//! for root in [0, 7, 42] {
//!     let report = bound.run(&RunOptions::from_root(root)).unwrap(); // cheap
//!     println!("BFS from {root}: {:.1} simulated MTEPS", report.simulated_mteps);
//! }
//! ```

pub mod accel;
pub mod analysis;
pub mod comm;
pub mod dsl;
pub mod engine;
pub mod graph;
pub mod prep;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod translator;

/// Convenience re-exports for the common flow: build graph → author DSL →
/// `Session::compile` → `CompiledPipeline::load` → `BoundPipeline::run` →
/// report.
pub mod prelude {
    pub use crate::accel::device::DeviceModel;
    pub use crate::analysis::{analyze, ParallelSafety, ProgramFacts};
    pub use crate::dsl::algorithms;
    pub use crate::dsl::builder::GasProgramBuilder;
    pub use crate::dsl::params::{ParamError, ParamSet, ParamSpec, Scalar};
    pub use crate::dsl::program::GasProgram;
    #[allow(deprecated)]
    pub use crate::engine::{Executor, ExecutorConfig};
    pub use crate::engine::{
        BoundPipeline, CompileError, CompiledPipeline, DirectionPolicy, FunctionalPath,
        QueryFailure, RunOptions, RunReport, Session, SessionConfig,
    };
    pub use crate::graph::csr::Csr;
    pub use crate::graph::edgelist::EdgeList;
    pub use crate::prep::prepared::{PrepOptions, PreparedGraph};
    pub use crate::sched::{Deadline, FaultPlan, ParallelismPlan};
    pub use crate::translator::{Translator, TranslatorKind};
}
