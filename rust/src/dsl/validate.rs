//! Semantic validation of [`GasProgram`]s — the DSL's compile-time checks.
//! The light-weight translator deliberately skips general-purpose semantic
//! analysis (paper §V), so these few domain rules are the *entire* front
//! end; each rejects a program that cannot be mapped onto the hardware
//! module library.

use anyhow::{bail, Result};

use super::program::GasProgram;

/// Check a program. Errors name the offending interface so that DSL users
/// see "their" function names, not translator internals.
///
/// Since PR 6 this is a thin shim over the static analyzer: the domain
/// rules live in [`crate::analysis::lint`] as deny-level diagnostics with
/// stable `JG***` codes, and `check` reports the first one. The legacy
/// message texts are preserved verbatim (with the code appended), so
/// existing error handling and tests keep matching.
pub fn check(p: &GasProgram) -> Result<()> {
    if let Some(d) = crate::analysis::lint::first_deny(p) {
        bail!("{}", d.message);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::apply::{ApplyExpr, BinOp};
    use crate::dsl::builder::GasProgramBuilder;
    use crate::dsl::program::{Convergence, InitPolicy, ReduceOp, StateType, Writeback};

    #[test]
    fn sum_with_ifunvisited_rejected() {
        let err = GasProgramBuilder::new("bad")
            .apply(ApplyExpr::src())
            .reduce(ReduceOp::Sum)
            .writeback(Writeback::IfUnvisited)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("not idempotent"));
    }

    #[test]
    fn i32_division_rejected() {
        let err = GasProgramBuilder::new("bad-div")
            .state(StateType::I32)
            .apply(ApplyExpr::bin(BinOp::Div, ApplyExpr::src(), ApplyExpr::constant(2.0)))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("no divider"));
    }

    #[test]
    fn delta_convergence_needs_f32() {
        let err = GasProgramBuilder::new("bad-delta")
            .state(StateType::I32)
            .apply(ApplyExpr::src())
            .convergence(Convergence::DeltaBelow(0.1.into()))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("requires F32"));
    }

    #[test]
    fn infinite_i32_default_rejected() {
        let err = GasProgramBuilder::new("bad-init")
            .state(StateType::I32)
            .init(InitPolicy::root_and_default(0.0, f64::INFINITY))
            .apply(ApplyExpr::src())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unvisited sentinel"));
    }

    #[test]
    fn undeclared_param_reference_rejected() {
        let err = GasProgramBuilder::new("bad-param")
            .apply(ApplyExpr::src().mul(ApplyExpr::param("beta")))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("undeclared parameter \"beta\""), "{err}");
    }

    #[test]
    fn default_outside_declared_range_rejected() {
        use crate::dsl::params::ParamSpec;
        let err = GasProgramBuilder::new("bad-default")
            .apply(ApplyExpr::src())
            .param(ParamSpec::new("alpha", 2.0).with_range(0.0, 1.0))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("outside its own range"), "{err}");
    }

    #[test]
    fn damped_sum_requires_sum_reduce_and_f32() {
        use crate::dsl::program::Writeback;
        let err = GasProgramBuilder::new("bad-damp")
            .apply(ApplyExpr::src())
            .reduce(ReduceOp::Min)
            .writeback(Writeback::DampedSum(0.85.into()))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("requires Reduce(Sum)"), "{err}");
    }

    #[test]
    fn damped_sum_with_depth_limit_rejected() {
        use crate::dsl::program::Writeback;
        let err = GasProgramBuilder::new("bad-damp-depth")
            .apply(ApplyExpr::src())
            .writeback(Writeback::DampedSum(0.85.into()))
            .depth_limit(3.0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("depth_limit"), "{err}");
    }

    #[test]
    fn literal_zero_depth_limit_rejected() {
        let err = GasProgramBuilder::new("bad-depth")
            .apply(ApplyExpr::src())
            .depth_limit(0.0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("never run"), "{err}");
    }

    #[test]
    fn zero_iterations_rejected() {
        let err = GasProgramBuilder::new("bad-iters")
            .apply(ApplyExpr::src())
            .convergence(Convergence::FixedIterations(0))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("never run"));
    }

    #[test]
    fn rejections_carry_stable_lint_codes() {
        let err = GasProgramBuilder::new("bad")
            .apply(ApplyExpr::src())
            .reduce(ReduceOp::Sum)
            .writeback(Writeback::IfUnvisited)
            .build()
            .unwrap_err();
        assert!(err.to_string().ends_with("[JG001]"), "{err}");
    }

    #[test]
    fn canonical_algorithms_all_validate() {
        use crate::dsl::algorithms;
        for p in algorithms::all_canonical() {
            check(&p).unwrap();
        }
    }
}
