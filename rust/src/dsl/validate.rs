//! Semantic validation of [`GasProgram`]s — the DSL's compile-time checks.
//! The light-weight translator deliberately skips general-purpose semantic
//! analysis (paper §V), so these few domain rules are the *entire* front
//! end; each rejects a program that cannot be mapped onto the hardware
//! module library.

use anyhow::{bail, Result};

use super::program::{Convergence, GasProgram, InitPolicy, ReduceOp, StateType, Writeback};

/// Check a program. Errors name the offending interface so that DSL users
/// see "their" function names, not translator internals.
pub fn check(p: &GasProgram) -> Result<()> {
    // Reduce/writeback compatibility: a Sum accumulator cannot feed the
    // visited-gate (it would double-count on revisits).
    if p.reduce == ReduceOp::Sum && p.writeback == Writeback::IfUnvisited {
        bail!(
            "program {:?}: Reduce(Sum) cannot drive Writeback::IfUnvisited — \
             accumulated sums are not idempotent across supersteps",
            p.name
        );
    }

    // Integer state with division: the fixed-point datapath has no divider.
    if p.state == StateType::I32 && expr_has_div(&p.apply) {
        bail!(
            "program {:?}: Apply uses division but state is I32 — the integer \
             datapath has no divider; use F32 state",
            p.name
        );
    }

    // Delta-based convergence needs float state.
    if matches!(p.convergence, Convergence::DeltaBelow(_)) && p.state == StateType::I32 {
        bail!(
            "program {:?}: Convergence::DeltaBelow requires F32 state",
            p.name
        );
    }

    // Infinity defaults only make sense for f32 state; the i32 datapath
    // uses the INF_I32 sentinel internally but the DSL surfaces -1/INF.
    if let InitPolicy::RootAndDefault { default, .. } = p.init {
        if default.is_infinite() && p.state == StateType::I32 {
            bail!(
                "program {:?}: infinite init default with I32 state; use -1 \
                 (unvisited sentinel) instead",
                p.name
            );
        }
    }

    // Fixed iteration counts of 0 do nothing.
    if p.convergence == Convergence::FixedIterations(0) {
        bail!("program {:?}: FixedIterations(0) would never run", p.name);
    }

    Ok(())
}

fn expr_has_div(e: &super::apply::ApplyExpr) -> bool {
    use super::apply::{ApplyExpr, BinOp};
    match e {
        ApplyExpr::Term(_) => false,
        ApplyExpr::Unary(_, a) => expr_has_div(a),
        ApplyExpr::Binary(op, a, b) => {
            *op == BinOp::Div || expr_has_div(a) || expr_has_div(b)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::apply::{ApplyExpr, BinOp};
    use crate::dsl::builder::GasProgramBuilder;
    use crate::dsl::program::{Convergence, InitPolicy, ReduceOp, StateType, Writeback};

    #[test]
    fn sum_with_ifunvisited_rejected() {
        let err = GasProgramBuilder::new("bad")
            .apply(ApplyExpr::src())
            .reduce(ReduceOp::Sum)
            .writeback(Writeback::IfUnvisited)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("not idempotent"));
    }

    #[test]
    fn i32_division_rejected() {
        let err = GasProgramBuilder::new("bad-div")
            .state(StateType::I32)
            .apply(ApplyExpr::bin(BinOp::Div, ApplyExpr::src(), ApplyExpr::constant(2.0)))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("no divider"));
    }

    #[test]
    fn delta_convergence_needs_f32() {
        let err = GasProgramBuilder::new("bad-delta")
            .state(StateType::I32)
            .apply(ApplyExpr::src())
            .convergence(Convergence::DeltaBelow(0.1))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("requires F32"));
    }

    #[test]
    fn infinite_i32_default_rejected() {
        let err = GasProgramBuilder::new("bad-init")
            .state(StateType::I32)
            .init(InitPolicy::RootAndDefault { root_value: 0.0, default: f64::INFINITY })
            .apply(ApplyExpr::src())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unvisited sentinel"));
    }

    #[test]
    fn zero_iterations_rejected() {
        let err = GasProgramBuilder::new("bad-iters")
            .apply(ApplyExpr::src())
            .convergence(Convergence::FixedIterations(0))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("never run"));
    }

    #[test]
    fn canonical_algorithms_all_validate() {
        use crate::dsl::algorithms;
        for p in algorithms::all_canonical() {
            check(&p).unwrap();
        }
    }
}
