//! Semantic validation of [`GasProgram`]s — the DSL's compile-time checks.
//! The light-weight translator deliberately skips general-purpose semantic
//! analysis (paper §V), so these few domain rules are the *entire* front
//! end; each rejects a program that cannot be mapped onto the hardware
//! module library.

use anyhow::{bail, Result};

use super::program::{Convergence, GasProgram, InitPolicy, ReduceOp, StateType, Writeback};

/// Check a program. Errors name the offending interface so that DSL users
/// see "their" function names, not translator internals.
pub fn check(p: &GasProgram) -> Result<()> {
    // Reduce/writeback compatibility: a Sum accumulator cannot feed the
    // visited-gate (it would double-count on revisits).
    if p.reduce == ReduceOp::Sum && p.writeback == Writeback::IfUnvisited {
        bail!(
            "program {:?}: Reduce(Sum) cannot drive Writeback::IfUnvisited — \
             accumulated sums are not idempotent across supersteps",
            p.name
        );
    }

    // The damped-sum writeback is PageRank-shaped: it redistributes the
    // un-damped mass over a Sum of float contributions.
    if let Writeback::DampedSum(_) = &p.writeback {
        if p.reduce != ReduceOp::Sum {
            bail!(
                "program {:?}: Writeback::DampedSum requires Reduce(Sum) — \
                 damping redistributes summed rank mass",
                p.name
            );
        }
        if p.state == StateType::I32 {
            bail!("program {:?}: Writeback::DampedSum requires F32 state", p.name);
        }
        // The damped (PageRank) engine path iterates to its delta
        // condition and has no frontier horizon to truncate at.
        if p.depth_limit.is_some() {
            bail!(
                "program {:?}: Writeback::DampedSum cannot combine with a \
                 depth_limit — damped iteration converges on delta, not depth",
                p.name
            );
        }
    }

    // Every parameter the structure references must be declared in the
    // signature — the builder's `.param()` is the single declaration site.
    for name in p.param_refs() {
        if p.params.get(name).is_none() {
            bail!(
                "program {:?}: references undeclared parameter {:?} — declare \
                 it with GasProgramBuilder::param (declared: {})",
                p.name,
                name,
                if p.params.is_empty() { "none".to_string() } else { p.params.names().join(", ") }
            );
        }
    }

    // Declared defaults must themselves satisfy the declared range, so a
    // default-only query can never produce an out-of-range value.
    for spec in p.params.iter() {
        if let Some(default) = spec.default {
            let lo = spec.min.unwrap_or(f64::NEG_INFINITY);
            let hi = spec.max.unwrap_or(f64::INFINITY);
            if default < lo || default > hi {
                bail!(
                    "program {:?}: parameter {:?} default {} outside its own \
                     range [{}, {}]",
                    p.name,
                    spec.name,
                    default,
                    lo,
                    hi
                );
            }
        }
    }

    // A literal depth limit below one superstep would never run.
    if let Some(limit) = &p.depth_limit {
        if let Some(v) = limit.as_lit() {
            if v < 1.0 {
                bail!("program {:?}: depth_limit {} would never run a superstep", p.name, v);
            }
        }
    }

    // Integer state with division: the fixed-point datapath has no divider.
    if p.state == StateType::I32 && expr_has_div(&p.apply) {
        bail!(
            "program {:?}: Apply uses division but state is I32 — the integer \
             datapath has no divider; use F32 state",
            p.name
        );
    }

    // Delta-based convergence needs float state.
    if matches!(p.convergence, Convergence::DeltaBelow(_)) && p.state == StateType::I32 {
        bail!(
            "program {:?}: Convergence::DeltaBelow requires F32 state",
            p.name
        );
    }

    // Infinity defaults only make sense for f32 state; the i32 datapath
    // uses the INF_I32 sentinel internally but the DSL surfaces -1/INF.
    if let InitPolicy::RootAndDefault { default, .. } = &p.init {
        if default.as_lit().is_some_and(f64::is_infinite) && p.state == StateType::I32 {
            bail!(
                "program {:?}: infinite init default with I32 state; use -1 \
                 (unvisited sentinel) instead",
                p.name
            );
        }
    }

    // Fixed iteration counts of 0 do nothing.
    if p.convergence == Convergence::FixedIterations(0) {
        bail!("program {:?}: FixedIterations(0) would never run", p.name);
    }

    Ok(())
}

fn expr_has_div(e: &super::apply::ApplyExpr) -> bool {
    use super::apply::{ApplyExpr, BinOp};
    match e {
        ApplyExpr::Term(_) => false,
        ApplyExpr::Unary(_, a) => expr_has_div(a),
        ApplyExpr::Binary(op, a, b) => {
            *op == BinOp::Div || expr_has_div(a) || expr_has_div(b)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::apply::{ApplyExpr, BinOp};
    use crate::dsl::builder::GasProgramBuilder;
    use crate::dsl::program::{Convergence, InitPolicy, ReduceOp, StateType, Writeback};

    #[test]
    fn sum_with_ifunvisited_rejected() {
        let err = GasProgramBuilder::new("bad")
            .apply(ApplyExpr::src())
            .reduce(ReduceOp::Sum)
            .writeback(Writeback::IfUnvisited)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("not idempotent"));
    }

    #[test]
    fn i32_division_rejected() {
        let err = GasProgramBuilder::new("bad-div")
            .state(StateType::I32)
            .apply(ApplyExpr::bin(BinOp::Div, ApplyExpr::src(), ApplyExpr::constant(2.0)))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("no divider"));
    }

    #[test]
    fn delta_convergence_needs_f32() {
        let err = GasProgramBuilder::new("bad-delta")
            .state(StateType::I32)
            .apply(ApplyExpr::src())
            .convergence(Convergence::DeltaBelow(0.1.into()))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("requires F32"));
    }

    #[test]
    fn infinite_i32_default_rejected() {
        let err = GasProgramBuilder::new("bad-init")
            .state(StateType::I32)
            .init(InitPolicy::root_and_default(0.0, f64::INFINITY))
            .apply(ApplyExpr::src())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unvisited sentinel"));
    }

    #[test]
    fn undeclared_param_reference_rejected() {
        let err = GasProgramBuilder::new("bad-param")
            .apply(ApplyExpr::src().mul(ApplyExpr::param("beta")))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("undeclared parameter \"beta\""), "{err}");
    }

    #[test]
    fn default_outside_declared_range_rejected() {
        use crate::dsl::params::ParamSpec;
        let err = GasProgramBuilder::new("bad-default")
            .apply(ApplyExpr::src())
            .param(ParamSpec::new("alpha", 2.0).with_range(0.0, 1.0))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("outside its own range"), "{err}");
    }

    #[test]
    fn damped_sum_requires_sum_reduce_and_f32() {
        use crate::dsl::program::Writeback;
        let err = GasProgramBuilder::new("bad-damp")
            .apply(ApplyExpr::src())
            .reduce(ReduceOp::Min)
            .writeback(Writeback::DampedSum(0.85.into()))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("requires Reduce(Sum)"), "{err}");
    }

    #[test]
    fn damped_sum_with_depth_limit_rejected() {
        use crate::dsl::program::Writeback;
        let err = GasProgramBuilder::new("bad-damp-depth")
            .apply(ApplyExpr::src())
            .writeback(Writeback::DampedSum(0.85.into()))
            .depth_limit(3.0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("depth_limit"), "{err}");
    }

    #[test]
    fn literal_zero_depth_limit_rejected() {
        let err = GasProgramBuilder::new("bad-depth")
            .apply(ApplyExpr::src())
            .depth_limit(0.0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("never run"), "{err}");
    }

    #[test]
    fn zero_iterations_rejected() {
        let err = GasProgramBuilder::new("bad-iters")
            .apply(ApplyExpr::src())
            .convergence(Convergence::FixedIterations(0))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("never run"));
    }

    #[test]
    fn canonical_algorithms_all_validate() {
        use crate::dsl::algorithms;
        for p in algorithms::all_canonical() {
            check(&p).unwrap();
        }
    }
}
