//! [`GasProgram`] — the translatable unit: a graph algorithm expressed in
//! the GAS model with scheduling decoupled from the algorithm (paper §IV:
//! "The decoupling of graph scheduling and graph algorithm is convenient
//! for translator optimization").


use super::apply::ApplyExpr;

/// Vertex-state element type carried through the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateType {
    I32,
    F32,
}

/// How vertex state is initialized before iteration 0.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitPolicy {
    /// Root gets `root_value`, everyone else `default` (BFS/SSSP).
    RootAndDefault { root_value: f64, default: f64 },
    /// Every vertex gets its own id (WCC labels).
    VertexId,
    /// Every vertex gets `1 / num_vertices` (PageRank).
    UniformFraction,
    /// Every vertex gets a constant.
    Constant(f64),
}

/// The Reduce accumulator combining multiple messages for one vertex
/// (paper §IV-B: "we should reduce these message with accumulator").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Min,
    Max,
    Sum,
}

/// Which vertices emit messages each superstep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontierPolicy {
    /// Only vertices updated last superstep (BFS frontier queue).
    Active,
    /// Every vertex every superstep (PR/WCC/SpMV sweeps).
    All,
}

/// Message direction: push along out-edges or pull along in-edges. The
/// paper's BFS pseudocode pulls over CSC; push over CSR is equivalent for
/// our purposes and maps to the same module graph with src/dst swapped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Push,
    Pull,
}

/// Convergence test evaluated by the runtime scheduler after each superstep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Convergence {
    /// Stop when no vertex joined the frontier (BFS).
    EmptyFrontier,
    /// Stop when no vertex value changed (WCC/SSSP).
    NoChange,
    /// Fixed superstep count (SpMV = 1).
    FixedIterations(u32),
    /// Stop when the L1 delta drops below the threshold (PageRank).
    DeltaBelow(f64),
}

/// The five canonical algorithm kinds with AOT-compiled Pallas kernels.
/// Custom programs (`kind == None`) run on the software GAS engine; the
/// translator handles both identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgeOpKind {
    Bfs,
    Pr,
    Sssp,
    Wcc,
    Spmv,
}

impl EdgeOpKind {
    /// Artifact name prefix (matches python/compile/aot.py output files).
    pub fn artifact_name(&self) -> &'static str {
        match self {
            EdgeOpKind::Bfs => "bfs",
            EdgeOpKind::Pr => "pr",
            EdgeOpKind::Sssp => "sssp",
            EdgeOpKind::Wcc => "wcc",
            EdgeOpKind::Spmv => "spmv",
        }
    }

    pub fn all() -> [EdgeOpKind; 5] {
        [EdgeOpKind::Bfs, EdgeOpKind::Pr, EdgeOpKind::Sssp, EdgeOpKind::Wcc, EdgeOpKind::Spmv]
    }
}

/// A complete GAS program: what the user authors (directly or through the
/// algorithm library) and what the translator consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct GasProgram {
    /// Human-readable name (appears in generated HDL module names).
    pub name: String,
    /// Vertex state element type.
    pub state: StateType,
    /// Initial state.
    pub init: InitPolicy,
    /// The per-edge message expression (the `Apply` interface).
    pub apply: ApplyExpr,
    /// Message accumulator (the `Reduce` interface).
    pub reduce: ReduceOp,
    /// Writeback: does a *smaller* (Min), *larger* (Max) or *any* reduced
    /// message replace the vertex value? Derived from `reduce` by default;
    /// kept explicit so e.g. PR can overwrite unconditionally.
    pub writeback: Writeback,
    /// Which vertices send each superstep.
    pub frontier: FrontierPolicy,
    /// Push or pull.
    pub direction: Direction,
    /// Termination rule.
    pub convergence: Convergence,
    /// Does the datapath need edge weights?
    pub uses_weights: bool,
    /// Canonical kind if this program matches an AOT kernel.
    pub kind: Option<EdgeOpKind>,
}

/// How the reduced message updates the vertex value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Writeback {
    /// Keep min(old, reduced) — SSSP/WCC relaxations.
    MinCombine,
    /// Keep max(old, reduced).
    MaxCombine,
    /// Overwrite only if the vertex was unvisited (BFS level write).
    IfUnvisited,
    /// Unconditional overwrite (PR power iteration, SpMV).
    Overwrite,
}

impl GasProgram {
    /// Supersteps upper bound the scheduler enforces as a safety net
    /// (diameter can be at most V-1; PR uses the convergence delta).
    pub fn max_supersteps(&self, num_vertices: usize) -> u32 {
        match self.convergence {
            Convergence::FixedIterations(k) => k,
            Convergence::DeltaBelow(_) => 200,
            _ => num_vertices.max(2) as u32,
        }
    }

    /// Whether the engine can offload this program to an AOT artifact.
    pub fn has_aot_kernel(&self) -> bool {
        self.kind.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;

    #[test]
    fn artifact_names_match_python_side() {
        let names: Vec<_> = EdgeOpKind::all().iter().map(|k| k.artifact_name()).collect();
        assert_eq!(names, vec!["bfs", "pr", "sssp", "wcc", "spmv"]);
    }

    #[test]
    fn max_supersteps_bounds() {
        let bfs = algorithms::bfs();
        assert_eq!(bfs.max_supersteps(100), 100);
        let pr = algorithms::pagerank(0.85, 1e-6);
        assert_eq!(pr.max_supersteps(100), 200);
        let spmv = algorithms::spmv();
        assert_eq!(spmv.max_supersteps(100), 1);
    }

}
