//! [`GasProgram`] — the translatable unit: a graph algorithm expressed in
//! the GAS model with scheduling decoupled from the algorithm (paper §IV:
//! "The decoupling of graph scheduling and graph algorithm is convenient
//! for translator optimization").


use super::apply::ApplyExpr;
use super::params::{ParamError, ParamSet, ParamSignature, ResolvedParams, Scalar};

/// Default superstep safety net for [`Convergence::DeltaBelow`] programs.
///
/// A contraction-by-delta iteration (PageRank) has no structural depth
/// bound the way frontier algorithms do, so the scheduler caps it here.
/// Hitting the cap without meeting the delta condition is an **error**
/// surfaced by the query layer ("iteration cap hit"), never a silent
/// truncation. The bound is surfaced as a fact through
/// [`crate::analysis::ConvergenceClass::ContractionByDelta`] and can be
/// overridden per program with
/// [`GasProgramBuilder::delta_iteration_bound`].
///
/// [`GasProgramBuilder::delta_iteration_bound`]:
///     super::builder::GasProgramBuilder::delta_iteration_bound
pub const DELTA_CONVERGENCE_SUPERSTEP_BOUND: u32 = 200;

/// Vertex-state element type carried through the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StateType {
    I32,
    F32,
}

/// How vertex state is initialized before iteration 0. Scalars may be
/// literals or references to declared runtime parameters
/// ([`Scalar::Param`]), bound per query.
#[derive(Debug, Clone, PartialEq)]
pub enum InitPolicy {
    /// Root gets `root_value`, everyone else `default` (BFS/SSSP).
    RootAndDefault { root_value: Scalar, default: Scalar },
    /// Every vertex gets its own id (WCC labels).
    VertexId,
    /// Every vertex gets `1 / num_vertices` (PageRank).
    UniformFraction,
    /// Every vertex gets a constant.
    Constant(Scalar),
}

impl InitPolicy {
    /// Literal-valued `RootAndDefault` (the common case).
    pub fn root_and_default(root_value: f64, default: f64) -> Self {
        InitPolicy::RootAndDefault { root_value: root_value.into(), default: default.into() }
    }
}

/// The Reduce accumulator combining multiple messages for one vertex
/// (paper §IV-B: "we should reduce these message with accumulator").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Min,
    Max,
    Sum,
}

/// Which vertices emit messages each superstep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontierPolicy {
    /// Only vertices updated last superstep (BFS frontier queue).
    Active,
    /// Every vertex every superstep (PR/WCC/SpMV sweeps).
    All,
}

/// Message direction: push along out-edges or pull along in-edges. The
/// paper's BFS pseudocode pulls over CSC; push over CSR is equivalent for
/// our purposes and maps to the same module graph with src/dst swapped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Push,
    Pull,
}

/// Convergence test evaluated by the runtime scheduler after each superstep.
#[derive(Debug, Clone, PartialEq)]
pub enum Convergence {
    /// Stop when no vertex joined the frontier (BFS).
    EmptyFrontier,
    /// Stop when no vertex value changed (WCC/SSSP).
    NoChange,
    /// Fixed superstep count (SpMV = 1).
    FixedIterations(u32),
    /// Stop when the L1 delta drops below the threshold (PageRank). The
    /// threshold may be a runtime parameter (`Scalar::param("tolerance")`)
    /// compared against an argument register by the generated host loop.
    DeltaBelow(Scalar),
}

/// The five canonical algorithm kinds with AOT-compiled Pallas kernels.
/// Custom programs (`kind == None`) run on the software GAS engine; the
/// translator handles both identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgeOpKind {
    Bfs,
    Pr,
    Sssp,
    Wcc,
    Spmv,
}

impl EdgeOpKind {
    /// Artifact name prefix (matches python/compile/aot.py output files).
    pub fn artifact_name(&self) -> &'static str {
        match self {
            EdgeOpKind::Bfs => "bfs",
            EdgeOpKind::Pr => "pr",
            EdgeOpKind::Sssp => "sssp",
            EdgeOpKind::Wcc => "wcc",
            EdgeOpKind::Spmv => "spmv",
        }
    }

    pub fn all() -> [EdgeOpKind; 5] {
        [EdgeOpKind::Bfs, EdgeOpKind::Pr, EdgeOpKind::Sssp, EdgeOpKind::Wcc, EdgeOpKind::Spmv]
    }
}

/// A complete GAS program: what the user authors (directly or through the
/// algorithm library) and what the translator consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct GasProgram {
    /// Human-readable name (appears in generated HDL module names).
    pub name: String,
    /// Vertex state element type.
    pub state: StateType,
    /// Initial state.
    pub init: InitPolicy,
    /// The per-edge message expression (the `Apply` interface).
    pub apply: ApplyExpr,
    /// Message accumulator (the `Reduce` interface).
    pub reduce: ReduceOp,
    /// Writeback: does a *smaller* (Min), *larger* (Max) or *any* reduced
    /// message replace the vertex value? Derived from `reduce` by default;
    /// kept explicit so e.g. PR can overwrite unconditionally.
    pub writeback: Writeback,
    /// Which vertices send each superstep.
    pub frontier: FrontierPolicy,
    /// Push or pull.
    pub direction: Direction,
    /// Termination rule.
    pub convergence: Convergence,
    /// Does the datapath need edge weights?
    pub uses_weights: bool,
    /// Canonical kind if this program matches an AOT kernel.
    pub kind: Option<EdgeOpKind>,
    /// Declared runtime-parameter signature (names + defaults + ranges).
    /// Collected by the builder, enforced by `validate`, bound per query
    /// through a [`ParamSet`]; empty after [`GasProgram::instantiate`].
    pub params: ParamSignature,
    /// Optional superstep horizon (bounded-depth traversal): the run
    /// converges once `supersteps >= depth_limit`, even if the frontier is
    /// non-empty. Typically `Scalar::param("max_depth")`.
    pub depth_limit: Option<Scalar>,
    /// Override of [`DELTA_CONVERGENCE_SUPERSTEP_BOUND`] for
    /// [`Convergence::DeltaBelow`] programs; `None` uses the default.
    pub delta_iteration_bound: Option<u32>,
    /// Warn-level lint codes (`"JG101"`, ...) suppressed for this program
    /// — the builder's `#[allow]` analogue. Deny-level lints ignore this
    /// list.
    pub allowed_lints: Vec<String>,
}

/// How the reduced message updates the vertex value.
#[derive(Debug, Clone, PartialEq)]
pub enum Writeback {
    /// Keep min(old, reduced) — SSSP/WCC relaxations.
    MinCombine,
    /// Keep max(old, reduced).
    MaxCombine,
    /// Overwrite only if the vertex was unvisited (BFS level write).
    IfUnvisited,
    /// Unconditional overwrite (SpMV).
    Overwrite,
    /// PageRank's damped overwrite: `new = (1-d)/N + d·(reduced +
    /// dangling/N)` with damping `d` — a [`Scalar`], so the damping factor
    /// is a host-written argument register, not a synthesized constant.
    /// Requires `Reduce(Sum)` + F32 state (enforced by validation).
    DampedSum(Scalar),
}

impl GasProgram {
    /// Supersteps upper bound the scheduler enforces as a safety net
    /// (diameter can be at most V-1; PR uses the convergence delta).
    pub fn max_supersteps(&self, num_vertices: usize) -> u32 {
        match &self.convergence {
            Convergence::FixedIterations(k) => *k,
            Convergence::DeltaBelow(_) => self.delta_bound(),
            _ => num_vertices.max(2) as u32,
        }
    }

    /// The superstep safety net a [`Convergence::DeltaBelow`] iteration
    /// runs under: the per-program override, or
    /// [`DELTA_CONVERGENCE_SUPERSTEP_BOUND`].
    pub fn delta_bound(&self) -> u32 {
        self.delta_iteration_bound.unwrap_or(DELTA_CONVERGENCE_SUPERSTEP_BOUND)
    }

    /// Whether the engine can offload this program to an AOT artifact.
    pub fn has_aot_kernel(&self) -> bool {
        self.kind.is_some()
    }

    /// Whether this program executes on the damped-PageRank engine path
    /// (`gas::run_pagerank`): any program with a [`Writeback::DampedSum`]
    /// writeback. Dispatch follows the writeback *shape*, never the
    /// `kind` tag — a hand-built program tagged `EdgeOpKind::Pr` with a
    /// plain `Overwrite` writeback runs the generic path (and gets a
    /// `JG104` warn from the lint pass). The query layer uses the same
    /// fact to attach the cached full-sweep pull trace only where it
    /// will be read.
    pub fn is_damped_pagerank(&self) -> bool {
        matches!(self.writeback, Writeback::DampedSum(_))
    }

    /// Does this program declare runtime parameters that still need
    /// binding before it can run?
    pub fn has_runtime_params(&self) -> bool {
        !self.params.is_empty()
    }

    /// Resolve a query's [`ParamSet`] against the declared signature —
    /// defaults filled in, unknown/unbound/out-of-range bindings rejected
    /// with typed [`ParamError`]s.
    pub fn resolve_params(&self, set: &ParamSet) -> Result<ResolvedParams, ParamError> {
        self.params.resolve(set)
    }

    /// Every parameter name the program's structure references (Apply
    /// terms plus the scalars in init/convergence/writeback/depth-limit).
    /// Validation checks each against the declared signature.
    pub fn param_refs(&self) -> Vec<&str> {
        let mut names = Vec::new();
        self.apply.param_names(&mut names);
        let mut scalars: Vec<&Scalar> = Vec::new();
        match &self.init {
            InitPolicy::RootAndDefault { root_value, default } => {
                scalars.push(root_value);
                scalars.push(default);
            }
            InitPolicy::Constant(c) => scalars.push(c),
            _ => {}
        }
        if let Convergence::DeltaBelow(t) = &self.convergence {
            scalars.push(t);
        }
        if let Writeback::DampedSum(d) = &self.writeback {
            scalars.push(d);
        }
        if let Some(s) = &self.depth_limit {
            scalars.push(s);
        }
        for s in scalars {
            if let Some(name) = s.param_name() {
                names.push(name);
            }
        }
        names
    }

    /// Specialize this program for one query: resolve `set` against the
    /// declared signature and substitute every parameter reference with
    /// its bound value. The result is **closed** — empty signature, no
    /// `Param` scalars or terms — and is what the engines actually run.
    /// The program's `name` is untouched: the design, its sanitized
    /// kernel name, and the AOT artifact key are parameter-independent.
    pub fn instantiate(&self, set: &ParamSet) -> Result<GasProgram, ParamError> {
        if self.params.is_empty() {
            // A closed program accepts no bindings: naming one is a typo.
            if let Some((name, _)) = set.iter().next() {
                return Err(ParamError::Unknown { name: name.clone(), declared: vec![] });
            }
            return Ok(self.clone());
        }
        let resolved = self.resolve_params(set)?;
        self.instantiate_resolved(&resolved)
    }

    /// [`GasProgram::instantiate`] for callers that already resolved the
    /// signature (the engine's per-query path resolves exactly once).
    pub fn instantiate_resolved(
        &self,
        resolved: &ResolvedParams,
    ) -> Result<GasProgram, ParamError> {
        let mut p = self.clone();
        p.apply = p.apply.bind_params(resolved)?;
        p.init = match &self.init {
            InitPolicy::RootAndDefault { root_value, default } => InitPolicy::RootAndDefault {
                root_value: root_value.bind(resolved)?,
                default: default.bind(resolved)?,
            },
            InitPolicy::Constant(c) => InitPolicy::Constant(c.bind(resolved)?),
            other => other.clone(),
        };
        p.convergence = match &self.convergence {
            Convergence::DeltaBelow(t) => Convergence::DeltaBelow(t.bind(resolved)?),
            other => other.clone(),
        };
        p.writeback = match &self.writeback {
            Writeback::DampedSum(d) => Writeback::DampedSum(d.bind(resolved)?),
            other => other.clone(),
        };
        p.depth_limit = match &self.depth_limit {
            Some(s) => Some(s.bind(resolved)?),
            None => None,
        };
        p.params = ParamSignature::default();
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;

    #[test]
    fn artifact_names_match_python_side() {
        let names: Vec<_> = EdgeOpKind::all().iter().map(|k| k.artifact_name()).collect();
        assert_eq!(names, vec!["bfs", "pr", "sssp", "wcc", "spmv"]);
    }

    #[test]
    fn max_supersteps_bounds() {
        let bfs = algorithms::bfs();
        assert_eq!(bfs.max_supersteps(100), 100);
        let pr = algorithms::pagerank();
        assert_eq!(pr.max_supersteps(100), DELTA_CONVERGENCE_SUPERSTEP_BOUND);
        let spmv = algorithms::spmv();
        assert_eq!(spmv.max_supersteps(100), 1);
    }

    #[test]
    fn delta_bound_is_overridable_per_program() {
        let mut pr = algorithms::pagerank();
        assert_eq!(pr.delta_bound(), DELTA_CONVERGENCE_SUPERSTEP_BOUND);
        pr.delta_iteration_bound = Some(7);
        assert_eq!(pr.delta_bound(), 7);
        assert_eq!(pr.max_supersteps(1_000_000), 7);
        // the override is scoped to delta convergence
        let bfs = algorithms::bfs();
        assert_eq!(bfs.max_supersteps(100), 100);
    }

    #[test]
    fn instantiate_closes_every_param_reference() {
        use crate::dsl::params::ParamSet;
        let pr = algorithms::pagerank();
        assert!(pr.has_runtime_params());
        assert!(pr.param_refs().contains(&"damping"));
        assert!(pr.param_refs().contains(&"tolerance"));
        let closed = pr.instantiate(&ParamSet::new().bind("damping", 0.9)).unwrap();
        assert!(!closed.has_runtime_params());
        assert!(closed.param_refs().is_empty());
        assert_eq!(closed.name, pr.name, "instantiation must not rename the kernel");
        match &closed.writeback {
            Writeback::DampedSum(d) => assert_eq!(d.as_lit(), Some(0.9)),
            other => panic!("expected DampedSum, got {other:?}"),
        }
        match &closed.convergence {
            Convergence::DeltaBelow(t) => assert_eq!(t.as_lit(), Some(1e-6)),
            other => panic!("expected DeltaBelow, got {other:?}"),
        }
    }

    #[test]
    fn instantiate_of_closed_program_rejects_bindings() {
        use crate::dsl::params::{ParamError, ParamSet};
        let wcc = algorithms::wcc();
        let err = wcc.instantiate(&ParamSet::new().bind("damping", 0.9)).unwrap_err();
        assert!(matches!(err, ParamError::Unknown { .. }));
        // and with no bindings it is the identity
        assert_eq!(wcc.instantiate(&ParamSet::new()).unwrap(), wcc);
    }

}
