//! The atomic-operator catalogue — the paper's Figure 3: every programming
//! interface JGraph exposes, with its abstraction level, interface family,
//! parameters, and the hardware module the translator maps it to.
//!
//! This table *is* the DSL surface: the function-level entries correspond
//! 1:1 to methods on [`crate::graph::csr::Csr`], [`crate::prep`] and
//! [`crate::dsl::program::GasProgram`]; the registry ([`super::registry`])
//! counts it for Table IV.


/// Interface family (Figure 3's three boxes + the control commands).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// `Graph data`: vertices / edge_offset / edges array access.
    GraphData,
    /// `Graph operation`: the GAS quartet and frontier control.
    GraphOperation,
    /// `Preprocessing`: FIFO / Layout / Partition / Reorder.
    Preprocessing,
    /// Communication & runtime control (comm. manager + scheduler).
    Control,
}

/// Abstraction level (paper §IV-D's three-level encapsulation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Level {
    /// Fine-grained: instruction-like atomic operations.
    Atomic,
    /// Middle: graph functions (the programmable GAS interfaces).
    Function,
    /// Coarse: whole-algorithm templates with parameters.
    Algorithm,
}

/// The hardware module the light-weight translator maps an interface onto
/// (paper §V-B: "we map functions with hardware modules correspondingly").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HwModule {
    VertexLoader,
    VertexWriter,
    EdgeFetcher,
    OffsetFetcher,
    GatherUnit,
    ApplyAlu,
    ReduceUnit,
    /// Same-destination conflict resolution in front of the reduce
    /// accumulator: combines in-flight updates to one vertex before the
    /// read-modify-write. Only instantiated for **non-idempotent** reduces
    /// (`Sum`) — for min/max the analyzer proves re-delivery harmless and
    /// the translator elides this unit entirely.
    ConflictUnit,
    ScatterUnit,
    FrontierQueue,
    BramCache,
    MemController,
    PcieDma,
    ControlRegs,
    /// Host-written runtime-argument register file: the landing zone for
    /// per-query parameter bindings (damping, tolerance, max_depth, …) so
    /// the synthesized design is identical across parameter values.
    ArgRegFile,
    HostOnly,
}

/// One row of the interface catalogue.
#[derive(Debug, Clone)]
pub struct InterfaceSpec {
    /// Interface name as the paper spells it.
    pub name: &'static str,
    pub category: Category,
    pub level: Level,
    /// Hardware module the translator instantiates for it.
    pub module: HwModule,
    /// Parameter list (documentation; the paper stresses "user-defined
    /// functions with parameters").
    pub params: &'static str,
    pub doc: &'static str,
}

macro_rules! iface {
    ($name:literal, $cat:ident, $lvl:ident, $module:ident, $params:literal, $doc:literal) => {
        InterfaceSpec {
            name: $name,
            category: Category::$cat,
            level: Level::$lvl,
            module: HwModule::$module,
            params: $params,
            doc: $doc,
        }
    };
}

/// The full catalogue. Order follows Figure 3: graph data, vertex, edge,
/// operations, preprocessing, control, then algorithm templates.
pub const INTERFACES: &[InterfaceSpec] = &[
    // --- Graph data: the three CSR arrays (paper §IV-A1)
    iface!("Get_Vertices", GraphData, Function, VertexLoader, "(v_id)",
           "read a vertex value from the Vertices array"),
    iface!("Set_Vertex_value", GraphData, Function, VertexWriter, "(v_id, value)",
           "write a vertex value (Algorithm 1 line 19)"),
    iface!("Update_Vertex", GraphData, Function, VertexWriter, "(v_id, value)",
           "combine-and-write via the active writeback rule (§IV-A2)"),
    iface!("Get_edge_offset", GraphData, Function, OffsetFetcher, "(v_id)",
           "row range of v in the Edge_offset array"),
    iface!("Get_edge", GraphData, Function, EdgeFetcher, "(e_id)",
           "fetch one edge record from the Edges array"),
    // --- Graph data: vertex neighborhood views (§IV-A2)
    iface!("Get_out_edges_list", GraphData, Function, EdgeFetcher, "(v_id)",
           "out-edge (id, weight) list of a vertex"),
    iface!("Get_in_edges_list", GraphData, Function, EdgeFetcher, "(v_id)",
           "in-edge (id, weight) list (CSC view)"),
    iface!("Get_dest_V_list", GraphData, Function, EdgeFetcher, "(v_id)",
           "out-neighbor id list"),
    iface!("Get_src_V_list", GraphData, Function, EdgeFetcher, "(v_id)",
           "in-neighbor id list"),
    // --- Graph data: edge accessors (§IV-A3)
    iface!("Get_src_V_id", GraphData, Function, OffsetFetcher, "(e_id)",
           "source vertex of an edge (offset binary search)"),
    iface!("Get_dest_V_id", GraphData, Function, EdgeFetcher, "(e_id)",
           "destination vertex of an edge"),
    iface!("Get_edge_V_weight", GraphData, Function, EdgeFetcher, "(e_id)",
           "weight of an edge"),
    iface!("Update_edge_weight", GraphData, Function, EdgeFetcher, "(e_id, w)",
           "overwrite an edge weight"),
    iface!("Get_active_vertex", GraphData, Function, FrontierQueue, "()",
           "pop the next frontier vertex (Algorithm 1 loop head)"),
    // --- Graph operations: the GAS quartet (§IV-B)
    iface!("Receive", GraphOperation, Function, GatherUnit, "(src_list, data_loc)",
           "gather neighbor data for a vertex"),
    iface!("Apply", GraphOperation, Function, ApplyAlu, "(expr, operands...)",
           "per-edge/vertex computation; pluggable operator expression"),
    iface!("Reduce", GraphOperation, Function, ReduceUnit, "(acc, msgs...)",
           "combine concurrent messages with an accumulator"),
    iface!("Send", GraphOperation, Function, ScatterUnit, "(dst_list, data)",
           "emit updated messages to neighbors"),
    // --- Preprocessing (§IV-C)
    iface!("FIFO_read", Preprocessing, Function, HostOnly, "(path|db)",
           "read graph file / database into the edge-list form"),
    iface!("FIFO_write", Preprocessing, Function, HostOnly, "(graph, path)",
           "write results / graphs back out"),
    iface!("Layout", Preprocessing, Function, HostOnly, "(graph, CSR|CSC|ADJ|EL)",
           "convert between data layouts"),
    iface!("Partition", Preprocessing, Function, HostOnly, "(graph, k, strategy)",
           "split the graph across PEs (range/hash/degree/bfs-grow)"),
    iface!("Reorder", Preprocessing, Function, HostOnly, "(graph, strategy)",
           "relabel vertices for locality (degree/dfs/bfs/hub)"),
    // --- Control: communication manager + runtime scheduler (§V-C)
    iface!("Get_FPGA_Message", Control, Function, ControlRegs, "()",
           "query device status through the (simulated) XRT shell"),
    iface!("Transport", Control, Function, PcieDma, "(cpu_ip, fpga_ip, data)",
           "move graph data host→device over PCIe"),
    iface!("Set_Pipeline", Control, Function, ControlRegs, "(count)",
           "configure parallel pipeline lanes"),
    iface!("Set_PE", Control, Function, ControlRegs, "(count)",
           "configure processing-element count"),
    iface!("Set_Argument", Control, Function, ArgRegFile, "(name, value)",
           "bind a declared runtime parameter into the argument register file"),
    iface!("Get_Argument", Control, Function, ArgRegFile, "(name)",
           "read back a bound runtime-parameter register"),
    // --- Atomic level (§IV-D level 3): instruction-like ops
    iface!("load_Vertices", GraphData, Atomic, BramCache, "(base, len)",
           "burst-load vertex values into BRAM ahead of traversal"),
    iface!("store_Vertices", GraphData, Atomic, BramCache, "(base, len)",
           "burst-store BRAM vertex values back to DRAM"),
    iface!("get_address", GraphData, Atomic, MemController, "(array, index)",
           "compute a DRAM address for an array element"),
    iface!("burst_read", GraphData, Atomic, MemController, "(addr, beats)",
           "issue a DDR burst read"),
    iface!("acc_merge", GraphOperation, Atomic, ReduceUnit, "(a, b)",
           "single accumulator merge step"),
    iface!("queue_push", GraphOperation, Atomic, FrontierQueue, "(v_id)",
           "push a vertex into the frontier FIFO"),
    iface!("queue_pop", GraphOperation, Atomic, FrontierQueue, "()",
           "pop a vertex from the frontier FIFO"),
    // --- Algorithm level (§IV-D level 1): templates with parameters
    iface!("BFS", GraphOperation, Algorithm, ApplyAlu, "(graph, root, pipelineNum, peNum)",
           "breadth-first search template"),
    iface!("PageRank", GraphOperation, Algorithm, ApplyAlu, "(graph, damping, tol, ...)",
           "PageRank power iteration template"),
    iface!("SSSP", GraphOperation, Algorithm, ApplyAlu, "(graph, root, ...)",
           "single-source shortest paths template"),
    iface!("WCC", GraphOperation, Algorithm, ApplyAlu, "(graph, ...)",
           "weakly-connected components template"),
    iface!("SpMV", GraphOperation, Algorithm, ApplyAlu, "(matrix, x, ...)",
           "sparse matrix-vector product template"),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_has_25_plus_interfaces() {
        // the paper's Table IV headline: "FAgraph 25+"
        assert!(INTERFACES.len() >= 25, "only {} interfaces", INTERFACES.len());
    }

    #[test]
    fn names_are_unique() {
        let mut set = std::collections::HashSet::new();
        for i in INTERFACES {
            assert!(set.insert(i.name), "duplicate interface {}", i.name);
        }
    }

    #[test]
    fn all_three_levels_present() {
        for lvl in [Level::Atomic, Level::Function, Level::Algorithm] {
            assert!(
                INTERFACES.iter().any(|i| i.level == lvl),
                "missing level {lvl:?}"
            );
        }
    }

    #[test]
    fn gas_quartet_present() {
        for name in ["Receive", "Apply", "Reduce", "Send"] {
            assert!(INTERFACES.iter().any(|i| i.name == name), "missing {name}");
        }
    }

    #[test]
    fn preprocessing_families_present() {
        for name in ["FIFO_read", "Layout", "Partition", "Reorder"] {
            assert!(INTERFACES.iter().any(|i| i.name == name), "missing {name}");
        }
    }
}
