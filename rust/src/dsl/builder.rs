//! Fluent builder for [`GasProgram`] — the "function level" authoring API.
//! Validation happens at `build()` via [`super::validate`].

use anyhow::Result;

use super::apply::ApplyExpr;
use super::params::{ParamSignature, ParamSpec, Scalar};
use super::program::{
    Convergence, Direction, EdgeOpKind, FrontierPolicy, GasProgram, InitPolicy, ReduceOp,
    StateType, Writeback,
};
use super::validate;

/// Builder with sane defaults: f32 state, push direction, all-active
/// frontier, no-change convergence, sum reduce, overwrite writeback.
///
/// Runtime parameters are **declared** here ([`GasProgramBuilder::param`])
/// and **referenced** symbolically ([`ApplyExpr::param`],
/// [`Scalar::param`]); values bind per query, after compilation, so one
/// synthesized design serves the whole parameter family:
///
/// ```
/// use jgraph::dsl::builder::GasProgramBuilder;
/// use jgraph::dsl::params::{ParamSet, ParamSpec};
/// use jgraph::dsl::apply::ApplyExpr;
///
/// // "scaled SSSP": message = src + scale * w, with `scale` bound per query
/// let program = GasProgramBuilder::new("scaled-sssp")
///     .init(jgraph::dsl::program::InitPolicy::root_and_default(0.0, f64::INFINITY))
///     .apply(ApplyExpr::src().add(ApplyExpr::param("scale").mul(ApplyExpr::weight())))
///     .reduce(jgraph::dsl::program::ReduceOp::Min)
///     .param(ParamSpec::new("scale", 1.0).with_min(0.0))
///     .build()
///     .unwrap();
///
/// assert!(program.has_runtime_params());
/// // bind at query time: the default (1.0) or an explicit value
/// let closed = program.instantiate(&ParamSet::new().bind("scale", 2.5)).unwrap();
/// assert_eq!(closed.apply.render(), "(src + (2.5 * w))");
/// // a typo'd name is a typed error listing the declared signature
/// assert!(program.instantiate(&ParamSet::new().bind("scael", 2.5)).is_err());
/// ```
#[derive(Debug, Clone)]
pub struct GasProgramBuilder {
    name: String,
    state: StateType,
    init: InitPolicy,
    apply: Option<ApplyExpr>,
    reduce: ReduceOp,
    writeback: Option<Writeback>,
    frontier: FrontierPolicy,
    direction: Direction,
    convergence: Convergence,
    kind: Option<EdgeOpKind>,
    params: ParamSignature,
    depth_limit: Option<Scalar>,
    delta_iteration_bound: Option<u32>,
    allowed_lints: Vec<String>,
}

impl GasProgramBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            state: StateType::F32,
            init: InitPolicy::Constant(0.0.into()),
            apply: None,
            reduce: ReduceOp::Sum,
            writeback: None,
            frontier: FrontierPolicy::All,
            direction: Direction::Push,
            convergence: Convergence::NoChange,
            kind: None,
            params: ParamSignature::default(),
            depth_limit: None,
            delta_iteration_bound: None,
            allowed_lints: Vec::new(),
        }
    }

    pub fn state(mut self, s: StateType) -> Self {
        self.state = s;
        self
    }

    pub fn init(mut self, i: InitPolicy) -> Self {
        self.init = i;
        self
    }

    /// The `Apply` interface (required).
    pub fn apply(mut self, e: ApplyExpr) -> Self {
        self.apply = Some(e);
        self
    }

    /// The `Reduce` accumulator.
    pub fn reduce(mut self, r: ReduceOp) -> Self {
        self.reduce = r;
        self
    }

    pub fn writeback(mut self, w: Writeback) -> Self {
        self.writeback = Some(w);
        self
    }

    pub fn frontier(mut self, f: FrontierPolicy) -> Self {
        self.frontier = f;
        self
    }

    pub fn direction(mut self, d: Direction) -> Self {
        self.direction = d;
        self
    }

    pub fn convergence(mut self, c: Convergence) -> Self {
        self.convergence = c;
        self
    }

    /// Declare a runtime parameter (name + default + range). Parameters
    /// bind **per query** via `RunOptions::bind`; the design and its
    /// kernel name stay identical across values. Redeclaring a name
    /// replaces the earlier spec.
    pub fn param(mut self, spec: ParamSpec) -> Self {
        self.params.declare(spec);
        self
    }

    /// Bound the traversal depth: the run converges once this many
    /// supersteps have executed, frontier or not. Usually a parameter
    /// reference (`Scalar::param("max_depth")`).
    pub fn depth_limit(mut self, limit: impl Into<Scalar>) -> Self {
        self.depth_limit = Some(limit.into());
        self
    }

    /// Override the superstep safety net a `Convergence::DeltaBelow`
    /// program runs under (default:
    /// [`DELTA_CONVERGENCE_SUPERSTEP_BOUND`]). Hitting the bound without
    /// converging is still an error at the query layer, never a
    /// truncation.
    ///
    /// [`DELTA_CONVERGENCE_SUPERSTEP_BOUND`]:
    ///     super::program::DELTA_CONVERGENCE_SUPERSTEP_BOUND
    pub fn delta_iteration_bound(mut self, bound: u32) -> Self {
        self.delta_iteration_bound = Some(bound);
        self
    }

    /// Suppress a **warn-level** lint for this program — the builder's
    /// `#[allow(...)]` analogue (`.allow("JG101")`). Deny-level lints
    /// describe programs that cannot execute correctly and are not
    /// suppressible; allowing a `JG0**` code has no effect. See the
    /// lint catalog in [`crate::analysis`].
    pub fn allow(mut self, code: impl Into<String>) -> Self {
        self.allowed_lints.push(code.into());
        self
    }

    /// Tag as a canonical kind (enables the AOT kernel path). The
    /// algorithm library sets this; custom programs normally leave it
    /// unset and run on the software engine.
    pub fn kind(mut self, k: EdgeOpKind) -> Self {
        self.kind = Some(k);
        self
    }

    /// Finalize and compile against a session in one step — the terminal
    /// of the fluent chain under the compile-once lifecycle. Validation
    /// failures surface as typed [`CompileError::InvalidProgram`] values
    /// instead of panics or stringly errors.
    ///
    /// [`CompileError::InvalidProgram`]: crate::engine::CompileError
    pub fn compile(
        self,
        session: &crate::engine::Session,
    ) -> Result<crate::engine::CompiledPipeline, crate::engine::CompileError> {
        let name = self.name.clone();
        let program = self.build().map_err(|e| crate::engine::CompileError::InvalidProgram {
            program: name,
            reason: e.to_string(),
        })?;
        session.compile(&program)
    }

    /// Finalize. Fails with a descriptive error when the combination is
    /// not implementable (see [`validate::check`]).
    pub fn build(self) -> Result<GasProgram> {
        let apply = self
            .apply
            .ok_or_else(|| anyhow::anyhow!("program {:?}: apply expression is required", self.name))?;
        let writeback = self.writeback.unwrap_or(match self.reduce {
            ReduceOp::Min => Writeback::MinCombine,
            ReduceOp::Max => Writeback::MaxCombine,
            ReduceOp::Sum => Writeback::Overwrite,
        });
        let uses_weights = apply.uses_weight();
        let p = GasProgram {
            name: self.name,
            state: self.state,
            init: self.init,
            apply,
            reduce: self.reduce,
            writeback,
            frontier: self.frontier,
            direction: self.direction,
            convergence: self.convergence,
            uses_weights,
            kind: self.kind,
            params: self.params,
            depth_limit: self.depth_limit,
            delta_iteration_bound: self.delta_iteration_bound,
            allowed_lints: self.allowed_lints,
        };
        validate::check(&p)?;
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::apply::{ApplyExpr, BinOp};

    #[test]
    fn builder_defaults_and_derived_writeback() {
        let p = GasProgramBuilder::new("custom")
            .apply(ApplyExpr::src().add(ApplyExpr::weight()))
            .reduce(ReduceOp::Min)
            .build()
            .unwrap();
        assert_eq!(p.writeback, Writeback::MinCombine);
        assert!(p.uses_weights);
        assert!(p.kind.is_none());
    }

    #[test]
    fn missing_apply_fails() {
        let err = GasProgramBuilder::new("nope").build().unwrap_err();
        assert!(err.to_string().contains("apply expression is required"));
    }

    #[test]
    fn custom_algorithm_composes() {
        // "degree-weighted distance": min(src + sqrt(w))
        let e = ApplyExpr::bin(
            BinOp::Add,
            ApplyExpr::src(),
            ApplyExpr::un(super::super::apply::UnOp::Sqrt, ApplyExpr::weight()),
        );
        let p = GasProgramBuilder::new("sqrt-sssp")
            .state(StateType::F32)
            .init(InitPolicy::root_and_default(0.0, f64::INFINITY))
            .apply(e)
            .reduce(ReduceOp::Min)
            .convergence(Convergence::NoChange)
            .build()
            .unwrap();
        assert_eq!(p.name, "sqrt-sssp");
        assert!(!p.has_aot_kernel());
    }
}
