//! **Runtime parameters** — the paper's "user-defined functions *with
//! parameters*" (§IV-D: `BFS(graph, input, pipelineNum, etc.)`) made a
//! first-class DSL surface.
//!
//! A [`GasProgram`] *declares* its parameters (a [`ParamSignature`] of
//! named [`ParamSpec`]s with defaults and valid ranges) and *references*
//! them symbolically — as [`Scalar::Param`] inside `InitPolicy` /
//! `Convergence` / `Writeback`, or as `Term::Param` inside the Apply
//! expression. Values are bound **per query** through a [`ParamSet`]
//! (`RunOptions::bind("damping", 0.9)`), never at compile time: the
//! translator lowers every parameter to a host-written argument register,
//! so one synthesized design serves the whole parameter family — the
//! compile-once/run-many lifecycle extended to its natural conclusion.
//!
//! Binding failures are **typed** ([`ParamError`]): unknown names list the
//! declared signature, unbound required parameters are named, and
//! out-of-range values report the violated bounds.
//!
//! [`GasProgram`]: super::program::GasProgram

use std::fmt;

/// A scalar the DSL can hold either as a literal or as a reference to a
/// declared runtime parameter. `From<f64>` keeps literal call sites terse
/// (`Convergence::DeltaBelow(1e-6.into())`).
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    /// A compile-time literal.
    Lit(f64),
    /// A reference to a declared runtime parameter, bound per query.
    Param(String),
}

impl From<f64> for Scalar {
    fn from(v: f64) -> Self {
        Scalar::Lit(v)
    }
}

impl Scalar {
    /// Reference a declared runtime parameter by name.
    pub fn param(name: impl Into<String>) -> Self {
        Scalar::Param(name.into())
    }

    /// The literal value, if this scalar is one.
    pub fn as_lit(&self) -> Option<f64> {
        match self {
            Scalar::Lit(v) => Some(*v),
            Scalar::Param(_) => None,
        }
    }

    /// The referenced parameter name, if this scalar is a reference.
    pub fn param_name(&self) -> Option<&str> {
        match self {
            Scalar::Lit(_) => None,
            Scalar::Param(name) => Some(name),
        }
    }

    /// The literal value of an **instantiated** scalar. Panics on an
    /// unresolved parameter reference — engine paths always run
    /// [`instantiate`](super::program::GasProgram::instantiate)d programs,
    /// so hitting this is a lifecycle bug, not a user error.
    pub fn lit(&self) -> f64 {
        match self {
            Scalar::Lit(v) => *v,
            Scalar::Param(name) => panic!(
                "parameter {name:?} is unresolved — instantiate the program \
                 (bind its ParamSet) before evaluating"
            ),
        }
    }

    /// Resolve against a set of bound values: literals pass through,
    /// references look up their binding.
    pub fn resolve(&self, resolved: &ResolvedParams) -> Result<f64, ParamError> {
        match self {
            Scalar::Lit(v) => Ok(*v),
            Scalar::Param(name) => resolved
                .get(name)
                .ok_or_else(|| ParamError::Unbound { name: name.clone() }),
        }
    }

    /// Substitute: a resolved copy where parameter references become
    /// literals.
    pub fn bind(&self, resolved: &ResolvedParams) -> Result<Scalar, ParamError> {
        Ok(Scalar::Lit(self.resolve(resolved)?))
    }

    /// Human-readable rendering (codegen comments, reports).
    pub fn render(&self) -> String {
        match self {
            Scalar::Lit(v) => format!("{v}"),
            Scalar::Param(name) => format!("${name}"),
        }
    }
}

/// Declaration of one runtime parameter: its name, optional default (a
/// parameter without a default is **required** at query time), and
/// optional inclusive range.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    /// Value used when the query binds nothing; `None` = required.
    pub default: Option<f64>,
    /// Inclusive lower bound.
    pub min: Option<f64>,
    /// Inclusive upper bound.
    pub max: Option<f64>,
    /// One-line description (CLI listings, generated host-code comments).
    pub doc: String,
}

impl ParamSpec {
    /// A required parameter (no default, unbounded).
    pub fn required(name: impl Into<String>) -> Self {
        Self { name: name.into(), default: None, min: None, max: None, doc: String::new() }
    }

    /// An optional parameter with a default value.
    pub fn new(name: impl Into<String>, default: f64) -> Self {
        Self { name: name.into(), default: Some(default), min: None, max: None, doc: String::new() }
    }

    /// Constrain to the inclusive range `[min, max]`.
    pub fn with_range(mut self, min: f64, max: f64) -> Self {
        self.min = Some(min);
        self.max = Some(max);
        self
    }

    /// Constrain to `value >= min`.
    pub fn with_min(mut self, min: f64) -> Self {
        self.min = Some(min);
        self
    }

    pub fn with_doc(mut self, doc: impl Into<String>) -> Self {
        self.doc = doc.into();
        self
    }

    fn check_range(&self, value: f64) -> Result<(), ParamError> {
        let lo = self.min.unwrap_or(f64::NEG_INFINITY);
        let hi = self.max.unwrap_or(f64::INFINITY);
        // NaN is outside every range (and `v < lo || v > hi` would let it
        // through — the comparisons are false for NaN).
        if value.is_nan() || value < lo || value > hi {
            return Err(ParamError::OutOfRange {
                name: self.name.clone(),
                value,
                min: lo,
                max: hi,
            });
        }
        Ok(())
    }
}

/// The declared parameter signature of a program: what the builder
/// collects and `validate` enforces. Order-preserving (it is also the
/// argument-register layout the translator emits).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamSignature {
    specs: Vec<ParamSpec>,
}

impl ParamSignature {
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// Declare a parameter. A redeclaration with the same name replaces
    /// the earlier spec (last wins — how the deprecated constructors
    /// pre-bind their argument values as defaults).
    pub fn declare(&mut self, spec: ParamSpec) {
        match self.specs.iter_mut().find(|s| s.name == spec.name) {
            Some(slot) => *slot = spec,
            None => self.specs.push(spec),
        }
    }

    pub fn get(&self, name: &str) -> Option<&ParamSpec> {
        self.specs.iter().find(|s| s.name == name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ParamSpec> {
        self.specs.iter()
    }

    /// Declared names, in register order.
    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    /// Override the default of an already-declared parameter (the
    /// deprecated pre-binding shims). No-op for unknown names.
    pub fn set_default(&mut self, name: &str, value: f64) {
        if let Some(s) = self.specs.iter_mut().find(|s| s.name == name) {
            s.default = Some(value);
        }
    }

    /// Resolve a query's bindings against this signature:
    ///
    /// 1. every binding must name a declared parameter
    ///    ([`ParamError::Unknown`] lists the signature on a typo);
    /// 2. bound values must sit inside the declared range
    ///    ([`ParamError::OutOfRange`]);
    /// 3. every declared parameter must end up with a value — its binding
    ///    or its default ([`ParamError::Unbound`] names the missing one).
    pub fn resolve(&self, set: &ParamSet) -> Result<ResolvedParams, ParamError> {
        for (name, value) in set.iter() {
            let spec = self.get(name).ok_or_else(|| ParamError::Unknown {
                name: name.clone(),
                declared: self.names().iter().map(|s| s.to_string()).collect(),
            })?;
            spec.check_range(*value)?;
        }
        let mut values = Vec::with_capacity(self.specs.len());
        for spec in &self.specs {
            let value = set
                .get(&spec.name)
                .or(spec.default)
                .ok_or_else(|| ParamError::Unbound { name: spec.name.clone() })?;
            values.push((spec.name.clone(), value));
        }
        Ok(ResolvedParams { values })
    }
}

/// Per-query parameter bindings — the host side of the argument register
/// file. Built fluently: `ParamSet::new().bind("damping", 0.9)`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamSet {
    bindings: Vec<(String, f64)>,
}

impl ParamSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind `name` to `value` (replacing an earlier binding of the same
    /// name), builder-style.
    pub fn bind(mut self, name: impl Into<String>, value: f64) -> Self {
        self.set(name, value);
        self
    }

    /// Bind in place.
    pub fn set(&mut self, name: impl Into<String>, value: f64) {
        let name = name.into();
        match self.bindings.iter_mut().find(|(n, _)| *n == name) {
            Some((_, v)) => *v = value,
            None => self.bindings.push((name, value)),
        }
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.bindings.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn is_empty(&self) -> bool {
        self.bindings.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &(String, f64)> {
        self.bindings.iter()
    }
}

/// The effective values of every declared parameter for one query:
/// defaults filled in, ranges checked. What the engines read and what the
/// host driver writes into the argument registers.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResolvedParams {
    values: Vec<(String, f64)>,
}

impl ResolvedParams {
    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &(String, f64)> {
        self.values.iter()
    }

    /// `(name, value)` pairs in register order (report surfaces).
    pub fn to_vec(&self) -> Vec<(String, f64)> {
        self.values.clone()
    }
}

/// Typed parameter-binding errors. `Display` messages are written for the
/// CLI: an unknown name lists the declared signature so typos are
/// self-diagnosing.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamError {
    /// The query bound a name the program does not declare.
    Unknown { name: String, declared: Vec<String> },
    /// A required parameter (no default) was left unbound.
    Unbound { name: String },
    /// A bound value violates the declared range.
    OutOfRange { name: String, value: f64, min: f64, max: f64 },
}

impl fmt::Display for ParamError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamError::Unknown { name, declared } => {
                if declared.is_empty() {
                    write!(f, "unknown parameter {name:?}: the program declares no parameters")
                } else {
                    write!(
                        f,
                        "unknown parameter {name:?}; declared parameters: {}",
                        declared.join(", ")
                    )
                }
            }
            ParamError::Unbound { name } => {
                write!(f, "required parameter {name:?} is unbound (no default declared)")
            }
            ParamError::OutOfRange { name, value, min, max } => {
                write!(f, "parameter {name:?} = {value} outside the declared range [{min}, {max}]")
            }
        }
    }
}

impl std::error::Error for ParamError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig() -> ParamSignature {
        let mut s = ParamSignature::default();
        s.declare(ParamSpec::new("damping", 0.85).with_range(0.0, 1.0));
        s.declare(ParamSpec::new("tolerance", 1e-6));
        s.declare(ParamSpec::required("alpha"));
        s
    }

    #[test]
    fn defaults_fill_in_and_bindings_override() {
        let r = sig().resolve(&ParamSet::new().bind("alpha", 2.0)).unwrap();
        assert_eq!(r.get("damping"), Some(0.85));
        assert_eq!(r.get("alpha"), Some(2.0));
        let r = sig()
            .resolve(&ParamSet::new().bind("alpha", 2.0).bind("damping", 0.9))
            .unwrap();
        assert_eq!(r.get("damping"), Some(0.9));
    }

    #[test]
    fn unknown_binding_lists_declared_names() {
        let err = sig()
            .resolve(&ParamSet::new().bind("alpha", 1.0).bind("dampng", 0.9))
            .unwrap_err();
        match &err {
            ParamError::Unknown { name, declared } => {
                assert_eq!(name, "dampng");
                assert_eq!(declared, &["damping", "tolerance", "alpha"]);
            }
            other => panic!("expected Unknown, got {other:?}"),
        }
        let msg = err.to_string();
        assert!(msg.contains("damping, tolerance, alpha"), "{msg}");
    }

    #[test]
    fn required_param_must_be_bound() {
        let err = sig().resolve(&ParamSet::new()).unwrap_err();
        assert_eq!(err, ParamError::Unbound { name: "alpha".into() });
        assert!(err.to_string().contains("\"alpha\""));
    }

    #[test]
    fn range_is_enforced_inclusively() {
        let set = ParamSet::new().bind("alpha", 0.0).bind("damping", 1.5);
        match sig().resolve(&set).unwrap_err() {
            ParamError::OutOfRange { name, value, min, max } => {
                assert_eq!((name.as_str(), value, min, max), ("damping", 1.5, 0.0, 1.0));
            }
            other => panic!("expected OutOfRange, got {other:?}"),
        }
        // the bounds themselves are legal
        sig().resolve(&ParamSet::new().bind("alpha", 0.0).bind("damping", 1.0)).unwrap();
        // NaN never satisfies a range, declared or not
        let err = sig()
            .resolve(&ParamSet::new().bind("alpha", 0.0).bind("damping", f64::NAN))
            .unwrap_err();
        assert!(matches!(err, ParamError::OutOfRange { .. }), "{err:?}");
    }

    #[test]
    fn scalar_resolution_and_substitution() {
        let r = sig().resolve(&ParamSet::new().bind("alpha", 3.0)).unwrap();
        assert_eq!(Scalar::param("damping").resolve(&r).unwrap(), 0.85);
        assert_eq!(Scalar::Lit(7.0).resolve(&r).unwrap(), 7.0);
        assert_eq!(Scalar::param("alpha").bind(&r).unwrap(), Scalar::Lit(3.0));
        assert_eq!(
            Scalar::param("nope").resolve(&r).unwrap_err(),
            ParamError::Unbound { name: "nope".into() }
        );
        assert_eq!(Scalar::param("damping").render(), "$damping");
        assert_eq!(Scalar::Lit(0.5).render(), "0.5");
    }

    #[test]
    fn redeclare_replaces_and_set_default_prebinds() {
        let mut s = sig();
        s.declare(ParamSpec::new("alpha", 9.0));
        assert_eq!(s.len(), 3, "redeclaration must not duplicate");
        let r = s.resolve(&ParamSet::new()).unwrap();
        assert_eq!(r.get("alpha"), Some(9.0));
        s.set_default("damping", 0.5);
        assert_eq!(s.resolve(&ParamSet::new()).unwrap().get("damping"), Some(0.5));
    }
}
