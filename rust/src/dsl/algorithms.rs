//! The **algorithm level** (paper §IV-D): "coarse-grained encapsulation...
//! providing algorithm functions with parameters, such as BFS(graph,
//! input, pipelineNum, etc.)". Each function returns a ready
//! [`GasProgram`]; parallelism parameters (pipelines/PEs) live in
//! [`crate::sched::ParallelismPlan`], passed at execution — the paper's
//! `Set Pipeline = 8, PE = 1` line of Algorithm 1.

use super::apply::{ApplyExpr, BinOp};
use super::builder::GasProgramBuilder;
use super::program::{
    Convergence, Direction, EdgeOpKind, FrontierPolicy, GasProgram, InitPolicy, ReduceOp,
    StateType, Writeback,
};

/// Breadth-first search: level = iter + 1, min-reduced, written to
/// unvisited vertices only; active frontier; stops when the frontier
/// empties. The paper's running example (Algorithm 1).
pub fn bfs() -> GasProgram {
    GasProgramBuilder::new("bfs")
        .state(StateType::I32)
        .init(InitPolicy::RootAndDefault { root_value: 0.0, default: -1.0 })
        .apply(ApplyExpr::iter().add(ApplyExpr::constant(1.0)))
        .reduce(ReduceOp::Min)
        .writeback(Writeback::IfUnvisited)
        .frontier(FrontierPolicy::Active)
        .direction(Direction::Push)
        .convergence(Convergence::EmptyFrontier)
        .kind(EdgeOpKind::Bfs)
        .build()
        .expect("bfs template must validate")
}

/// PageRank power iteration: message = src contribution (pre-divided by
/// out-degree on the vertex-loader module), sum-reduced, overwritten with
/// damping applied by the writeback stage.
pub fn pagerank(damping: f64, tolerance: f64) -> GasProgram {
    assert!((0.0..1.0).contains(&damping), "damping must be in (0,1)");
    GasProgramBuilder::new(format!("pagerank(d={damping})"))
        .state(StateType::F32)
        .init(InitPolicy::UniformFraction)
        .apply(ApplyExpr::src()) // contribution gather; scale in writeback
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::Overwrite)
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::DeltaBelow(tolerance))
        .kind(EdgeOpKind::Pr)
        .build()
        .expect("pagerank template must validate")
}

/// Single-source shortest paths (Bellman-Ford): message = src + w,
/// min-reduced and min-combined; sweeps all vertices until no change.
pub fn sssp() -> GasProgram {
    GasProgramBuilder::new("sssp")
        .state(StateType::F32)
        .init(InitPolicy::RootAndDefault { root_value: 0.0, default: f64::INFINITY })
        .apply(ApplyExpr::src().add(ApplyExpr::weight()))
        .reduce(ReduceOp::Min)
        .writeback(Writeback::MinCombine)
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::NoChange)
        .kind(EdgeOpKind::Sssp)
        .build()
        .expect("sssp template must validate")
}

/// Weakly-connected components by min-label propagation.
pub fn wcc() -> GasProgram {
    GasProgramBuilder::new("wcc")
        .state(StateType::I32)
        .init(InitPolicy::VertexId)
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Min)
        .writeback(Writeback::MinCombine)
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::NoChange)
        .kind(EdgeOpKind::Wcc)
        .build()
        .expect("wcc template must validate")
}

/// One sparse matrix-vector product: message = src * w, sum-reduced,
/// single iteration.
pub fn spmv() -> GasProgram {
    GasProgramBuilder::new("spmv")
        .state(StateType::F32)
        .init(InitPolicy::Constant(1.0))
        .apply(ApplyExpr::src().mul(ApplyExpr::weight()))
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::Overwrite)
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::FixedIterations(1))
        .kind(EdgeOpKind::Spmv)
        .build()
        .expect("spmv template must validate")
}

/// In-degree count: message = 1, sum-reduced, one sweep. A "trivial but
/// custom" template showing extensibility beyond the canonical five; runs
/// on the software engine (no AOT kernel tag).
pub fn degree_count() -> GasProgram {
    GasProgramBuilder::new("degree-count")
        .state(StateType::F32)
        .init(InitPolicy::Constant(0.0))
        .apply(ApplyExpr::constant(1.0))
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::Overwrite)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::FixedIterations(1))
        .build()
        .expect("degree-count template must validate")
}

/// Widest-path (maximum-bottleneck): message = min(src, w), max-reduced.
/// Another extensibility demo: a real algorithm the paper's comparators
/// cannot express without new RTL.
pub fn widest_path() -> GasProgram {
    GasProgramBuilder::new("widest-path")
        .state(StateType::F32)
        .init(InitPolicy::RootAndDefault { root_value: f64::MAX, default: 0.0 })
        .apply(ApplyExpr::bin(BinOp::Min, ApplyExpr::src(), ApplyExpr::weight()))
        .reduce(ReduceOp::Max)
        .writeback(Writeback::MaxCombine)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::NoChange)
        .build()
        .expect("widest-path template must validate")
}

/// Reachability flag propagation: which vertices can the root reach?
/// Visited = 1 propagates along out-edges; active frontier like BFS but
/// without level arithmetic — the cheapest traversal template.
pub fn reachability() -> GasProgram {
    GasProgramBuilder::new("reachability")
        .state(StateType::I32)
        .init(InitPolicy::RootAndDefault { root_value: 1.0, default: 0.0 })
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Max)
        .writeback(Writeback::MaxCombine)
        .frontier(FrontierPolicy::Active)
        .convergence(Convergence::EmptyFrontier)
        .build()
        .expect("reachability template must validate")
}

/// Max-label propagation ("influence"): every vertex learns the largest
/// vertex id in its reachable-from set — the max-dual of WCC, another
/// template the paper's fixed-function comparators cannot express.
pub fn max_label() -> GasProgram {
    GasProgramBuilder::new("max-label")
        .state(StateType::I32)
        .init(InitPolicy::VertexId)
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Max)
        .writeback(Writeback::MaxCombine)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::NoChange)
        .build()
        .expect("max-label template must validate")
}

/// The canonical programs with AOT kernels (used by tests and reports).
pub fn all_canonical() -> Vec<GasProgram> {
    vec![bfs(), pagerank(0.85, 1e-6), sssp(), wcc(), spmv()]
}

/// Every library algorithm, canonical + extension templates.
pub fn all() -> Vec<GasProgram> {
    let mut v = all_canonical();
    v.push(degree_count());
    v.push(widest_path());
    v.push(reachability());
    v.push(max_label());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_kinds_are_distinct_and_tagged() {
        let kinds: Vec<_> = all_canonical().iter().map(|p| p.kind.unwrap()).collect();
        let set: std::collections::HashSet<_> = kinds.iter().collect();
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn bfs_matches_paper_description() {
        let p = bfs();
        // "the Apply function is the current value plus one after traversal"
        assert_eq!(p.apply.render(), "(iter + 1)");
        assert_eq!(p.reduce, ReduceOp::Min);
        assert_eq!(p.frontier, FrontierPolicy::Active);
        assert!(!p.uses_weights);
    }

    #[test]
    fn sssp_uses_weights_bfs_does_not() {
        assert!(sssp().uses_weights);
        assert!(!bfs().uses_weights);
        assert!(spmv().uses_weights);
    }

    #[test]
    fn extension_templates_have_no_kernel() {
        assert!(!degree_count().has_aot_kernel());
        assert!(!widest_path().has_aot_kernel());
        assert!(!reachability().has_aot_kernel());
        assert!(!max_label().has_aot_kernel());
    }

    #[test]
    fn reachability_marks_reachable_set() {
        use crate::engine::gas;
        use crate::graph::{csr::Csr, edgelist::EdgeList};
        let mut el = EdgeList::from_pairs([(0, 1), (1, 2)]);
        el.num_vertices = 4; // vertex 3 unreachable
        let r = gas::run(&reachability(), &Csr::from_edgelist(&el), 0, |_| {}).unwrap();
        assert_eq!(r.values, vec![1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn max_label_is_dual_of_wcc() {
        use crate::engine::gas;
        use crate::graph::{csr::Csr, edgelist::EdgeList};
        let mut el = EdgeList::from_pairs([(0, 1), (1, 0), (2, 3), (3, 2)]);
        el.num_vertices = 4;
        let r = gas::run(&max_label(), &Csr::from_edgelist(&el), 0, |_| {}).unwrap();
        assert_eq!(r.values, vec![1.0, 1.0, 3.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "damping")]
    fn pagerank_rejects_bad_damping() {
        pagerank(1.5, 1e-6);
    }
}
