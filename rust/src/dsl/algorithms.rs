//! The **algorithm level** (paper §IV-D): "coarse-grained encapsulation...
//! providing algorithm functions with parameters, such as BFS(graph,
//! input, pipelineNum, etc.)". Each function returns a ready
//! [`GasProgram`] that **declares** its parameters (name + default +
//! range) and references them symbolically; values bind per query via
//! `RunOptions::bind`, so one compiled design serves the whole parameter
//! family. Parallelism parameters (pipelines/PEs) live in
//! [`crate::sched::ParallelismPlan`], passed at execution — the paper's
//! `Set Pipeline = 8, PE = 1` line of Algorithm 1.

use super::apply::{ApplyExpr, BinOp};
use super::builder::GasProgramBuilder;
use super::params::{ParamSpec, Scalar};
use super::program::{
    Convergence, Direction, EdgeOpKind, FrontierPolicy, GasProgram, InitPolicy, ReduceOp,
    StateType, Writeback,
};

/// Breadth-first search: level = iter + 1, min-reduced, written to
/// unvisited vertices only; active frontier; stops when the frontier
/// empties. The paper's running example (Algorithm 1).
///
/// Declares `max_depth` (default unbounded): bind it to stop the
/// traversal after that many levels — same compiled design.
pub fn bfs() -> GasProgram {
    GasProgramBuilder::new("bfs")
        .state(StateType::I32)
        .init(InitPolicy::root_and_default(0.0, -1.0))
        .apply(ApplyExpr::iter().add(ApplyExpr::constant(1.0)))
        .reduce(ReduceOp::Min)
        .writeback(Writeback::IfUnvisited)
        .frontier(FrontierPolicy::Active)
        .direction(Direction::Push)
        .convergence(Convergence::EmptyFrontier)
        .param(
            ParamSpec::new("max_depth", f64::INFINITY)
                .with_min(1.0)
                .with_doc("stop after this many BFS levels (default: unbounded)"),
        )
        .depth_limit(Scalar::param("max_depth"))
        .kind(EdgeOpKind::Bfs)
        .build()
        .expect("bfs template must validate")
}

/// PageRank power iteration: message = src contribution (pre-divided by
/// out-degree on the vertex-loader module), sum-reduced, damped in the
/// writeback stage.
///
/// Declares `damping` (default 0.85, range [0, 1]) and `tolerance`
/// (default 1e-6) — both bound at query time through the argument
/// register file, so a damping sweep reuses one synthesized design.
pub fn pagerank() -> GasProgram {
    GasProgramBuilder::new("pagerank")
        .state(StateType::F32)
        .init(InitPolicy::UniformFraction)
        .apply(ApplyExpr::src()) // contribution gather; damping in writeback
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::DampedSum(Scalar::param("damping")))
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::DeltaBelow(Scalar::param("tolerance")))
        .param(
            ParamSpec::new("damping", 0.85)
                .with_range(0.0, 1.0)
                .with_doc("random-surfer damping factor"),
        )
        .param(ParamSpec::new("tolerance", 1e-6).with_doc("L1 convergence threshold"))
        .kind(EdgeOpKind::Pr)
        .build()
        .expect("pagerank template must validate")
}

/// Deprecated compile-time-parameter constructor: pre-binds `damping` and
/// `tolerance` as the signature's defaults. The program (and its emitted
/// design, kernel name, and AOT artifact key) is **identical** to
/// [`pagerank`]'s for every argument value — only the defaults differ.
#[deprecated(
    since = "0.3.0",
    note = "use pagerank() and bind damping/tolerance per query: \
            RunOptions::from_root(r).bind(\"damping\", d).bind(\"tolerance\", t)"
)]
pub fn pagerank_with(damping: f64, tolerance: f64) -> GasProgram {
    assert!((0.0..1.0).contains(&damping), "damping must be in (0,1)");
    let mut p = pagerank();
    p.params.set_default("damping", damping);
    p.params.set_default("tolerance", tolerance);
    p
}

/// Single-source shortest paths (Bellman-Ford): message = src + w,
/// min-reduced and min-combined; sweeps all vertices until no change.
///
/// Declares `max_depth` (default unbounded): bind it for bounded-horizon
/// distances (shortest paths using at most that many hops).
pub fn sssp() -> GasProgram {
    GasProgramBuilder::new("sssp")
        .state(StateType::F32)
        .init(InitPolicy::root_and_default(0.0, f64::INFINITY))
        .apply(ApplyExpr::src().add(ApplyExpr::weight()))
        .reduce(ReduceOp::Min)
        .writeback(Writeback::MinCombine)
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::NoChange)
        .param(
            ParamSpec::new("max_depth", f64::INFINITY)
                .with_min(1.0)
                .with_doc("bound the relaxation horizon in hops (default: unbounded)"),
        )
        .depth_limit(Scalar::param("max_depth"))
        .kind(EdgeOpKind::Sssp)
        .build()
        .expect("sssp template must validate")
}

/// Weakly-connected components by min-label propagation.
pub fn wcc() -> GasProgram {
    GasProgramBuilder::new("wcc")
        .state(StateType::I32)
        .init(InitPolicy::VertexId)
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Min)
        .writeback(Writeback::MinCombine)
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::NoChange)
        .kind(EdgeOpKind::Wcc)
        .build()
        .expect("wcc template must validate")
}

/// One sparse matrix-vector product: message = src * w, sum-reduced,
/// single iteration.
pub fn spmv() -> GasProgram {
    GasProgramBuilder::new("spmv")
        .state(StateType::F32)
        .init(InitPolicy::Constant(1.0.into()))
        .apply(ApplyExpr::src().mul(ApplyExpr::weight()))
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::Overwrite)
        .frontier(FrontierPolicy::All)
        .direction(Direction::Push)
        .convergence(Convergence::FixedIterations(1))
        .kind(EdgeOpKind::Spmv)
        .build()
        .expect("spmv template must validate")
}

/// In-degree count: message = 1, sum-reduced, one sweep. A "trivial but
/// custom" template showing extensibility beyond the canonical five; runs
/// on the software engine (no AOT kernel tag).
pub fn degree_count() -> GasProgram {
    GasProgramBuilder::new("degree-count")
        .state(StateType::F32)
        .init(InitPolicy::Constant(0.0.into()))
        .apply(ApplyExpr::constant(1.0))
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::Overwrite)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::FixedIterations(1))
        .build()
        .expect("degree-count template must validate")
}

/// Widest-path (maximum-bottleneck): message = min(src, w), max-reduced.
/// Another extensibility demo: a real algorithm the paper's comparators
/// cannot express without new RTL.
///
/// Declares `max_depth` (default unbounded) like the other traversals.
pub fn widest_path() -> GasProgram {
    GasProgramBuilder::new("widest-path")
        .state(StateType::F32)
        .init(InitPolicy::root_and_default(f64::MAX, 0.0))
        .apply(ApplyExpr::bin(BinOp::Min, ApplyExpr::src(), ApplyExpr::weight()))
        .reduce(ReduceOp::Max)
        .writeback(Writeback::MaxCombine)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::NoChange)
        .param(
            ParamSpec::new("max_depth", f64::INFINITY)
                .with_min(1.0)
                .with_doc("bound the bottleneck-path horizon in hops"),
        )
        .depth_limit(Scalar::param("max_depth"))
        .build()
        .expect("widest-path template must validate")
}

/// Reachability flag propagation: which vertices can the root reach?
/// Visited = 1 propagates along out-edges; active frontier like BFS but
/// without level arithmetic — the cheapest traversal template.
pub fn reachability() -> GasProgram {
    GasProgramBuilder::new("reachability")
        .state(StateType::I32)
        .init(InitPolicy::root_and_default(1.0, 0.0))
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Max)
        .writeback(Writeback::MaxCombine)
        .frontier(FrontierPolicy::Active)
        .convergence(Convergence::EmptyFrontier)
        .build()
        .expect("reachability template must validate")
}

/// Max-label propagation ("influence"): every vertex learns the largest
/// vertex id in its reachable-from set — the max-dual of WCC, another
/// template the paper's fixed-function comparators cannot express.
pub fn max_label() -> GasProgram {
    GasProgramBuilder::new("max-label")
        .state(StateType::I32)
        .init(InitPolicy::VertexId)
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Max)
        .writeback(Writeback::MaxCombine)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::NoChange)
        .build()
        .expect("max-label template must validate")
}

/// The canonical programs with AOT kernels (used by tests and reports).
pub fn all_canonical() -> Vec<GasProgram> {
    vec![bfs(), pagerank(), sssp(), wcc(), spmv()]
}

/// Every library algorithm, canonical + extension templates.
pub fn all() -> Vec<GasProgram> {
    let mut v = all_canonical();
    v.push(degree_count());
    v.push(widest_path());
    v.push(reachability());
    v.push(max_label());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_kinds_are_distinct_and_tagged() {
        let kinds: Vec<_> = all_canonical().iter().map(|p| p.kind.unwrap()).collect();
        let set: std::collections::HashSet<_> = kinds.iter().collect();
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn bfs_matches_paper_description() {
        let p = bfs();
        // "the Apply function is the current value plus one after traversal"
        assert_eq!(p.apply.render(), "(iter + 1)");
        assert_eq!(p.reduce, ReduceOp::Min);
        assert_eq!(p.frontier, FrontierPolicy::Active);
        assert!(!p.uses_weights);
    }

    #[test]
    fn sssp_uses_weights_bfs_does_not() {
        assert!(sssp().uses_weights);
        assert!(!bfs().uses_weights);
        assert!(spmv().uses_weights);
    }

    #[test]
    fn extension_templates_have_no_kernel() {
        assert!(!degree_count().has_aot_kernel());
        assert!(!widest_path().has_aot_kernel());
        assert!(!reachability().has_aot_kernel());
        assert!(!max_label().has_aot_kernel());
    }

    #[test]
    fn reachability_marks_reachable_set() {
        use crate::engine::gas;
        use crate::graph::{csr::Csr, edgelist::EdgeList};
        let mut el = EdgeList::from_pairs([(0, 1), (1, 2)]);
        el.num_vertices = 4; // vertex 3 unreachable
        let r = gas::run(&reachability(), &Csr::from_edgelist(&el), 0, |_| {}).unwrap();
        assert_eq!(r.values, vec![1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn max_label_is_dual_of_wcc() {
        use crate::engine::gas;
        use crate::graph::{csr::Csr, edgelist::EdgeList};
        let mut el = EdgeList::from_pairs([(0, 1), (1, 0), (2, 3), (3, 2)]);
        el.num_vertices = 4;
        let r = gas::run(&max_label(), &Csr::from_edgelist(&el), 0, |_| {}).unwrap();
        assert_eq!(r.values, vec![1.0, 1.0, 3.0, 3.0]);
    }

    #[test]
    fn pagerank_declares_its_parameters() {
        let p = pagerank();
        assert_eq!(p.name, "pagerank", "name must be parameter-independent");
        assert_eq!(p.params.names(), vec!["damping", "tolerance"]);
        let r = p.resolve_params(&crate::dsl::params::ParamSet::new()).unwrap();
        assert_eq!(r.get("damping"), Some(0.85));
        assert_eq!(r.get("tolerance"), Some(1e-6));
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shim_prebinds_defaults_but_keeps_the_design() {
        let new = pagerank();
        let old = pagerank_with(0.9, 1e-4);
        assert_eq!(old.name, new.name);
        assert_eq!(old.apply, new.apply);
        assert_eq!(old.writeback, new.writeback, "still a symbolic $damping reference");
        assert_eq!(old.convergence, new.convergence);
        let r = old.resolve_params(&crate::dsl::params::ParamSet::new()).unwrap();
        assert_eq!(r.get("damping"), Some(0.9));
        assert_eq!(r.get("tolerance"), Some(1e-4));
    }

    #[test]
    #[allow(deprecated)]
    #[should_panic(expected = "damping")]
    fn pagerank_shim_rejects_bad_damping() {
        pagerank_with(1.5, 1e-6);
    }

    #[test]
    fn out_of_range_damping_is_a_typed_error_at_binding_time() {
        use crate::dsl::params::{ParamError, ParamSet};
        let err = pagerank()
            .resolve_params(&ParamSet::new().bind("damping", 1.5))
            .unwrap_err();
        assert!(matches!(err, ParamError::OutOfRange { .. }), "{err:?}");
    }

    #[test]
    fn traversals_declare_max_depth() {
        for p in [bfs(), sssp(), widest_path()] {
            assert!(p.params.get("max_depth").is_some(), "{} lacks max_depth", p.name);
            assert_eq!(p.depth_limit, Some(Scalar::param("max_depth")), "{}", p.name);
        }
    }
}
