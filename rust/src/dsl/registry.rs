//! Interface registry — query API over the catalogue in [`super::ops`],
//! plus the comparator data for the paper's Table IV ("comparations of
//! graph atomic operators with accelerators and programming environment").


use super::ops::{Category, InterfaceSpec, Level, INTERFACES};

/// Count of all public DSL interfaces (the paper's "25+").
pub fn interface_count() -> usize {
    INTERFACES.len()
}

/// All interfaces of a level.
pub fn by_level(level: Level) -> Vec<&'static InterfaceSpec> {
    INTERFACES.iter().filter(|i| i.level == level).collect()
}

/// All interfaces of a family.
pub fn by_category(category: Category) -> Vec<&'static InterfaceSpec> {
    INTERFACES.iter().filter(|i| i.category == category).collect()
}

/// Find an interface by (case-insensitive) name.
pub fn find(name: &str) -> Option<&'static InterfaceSpec> {
    INTERFACES.iter().find(|i| i.name.eq_ignore_ascii_case(name))
}

/// One comparator row of Table IV.
#[derive(Debug, Clone)]
pub struct ComparatorRow {
    pub system: &'static str,
    pub year: u16,
    pub operator_count: usize,
    pub operators: &'static str,
}

/// The paper's Table IV comparators, verbatim.
pub fn table4_comparators() -> Vec<ComparatorRow> {
    vec![
        ComparatorRow {
            system: "GraFBoost",
            year: 2018,
            operator_count: 4,
            operators: "edge_program, vertex_update, finalize, is_active",
        },
        ComparatorRow {
            system: "Foregraph",
            year: 2017,
            operator_count: 5,
            operators: "interconnection controller, off-chip memory controller, \
                        data controller, dispatcher, processing elements",
        },
        ComparatorRow {
            system: "GraphOps",
            year: 2016,
            operator_count: 7,
            operators: "ForAllPropRdr, NbrPropRed, ElemUpdate, QRdrPktCntSM, \
                        UpdQueueSM, EndSignal, MemUnit",
        },
        ComparatorRow {
            system: "GraphSoc",
            year: 2015,
            operator_count: 17,
            operators: "SND, RCV, ACCU, UPD, SAR, DC, B, BNZ, NOP, HALT, LC, LS, \
                        LMSG, DC+SND, DC+LS+LMSG, ...",
        },
    ]
}

/// Full Table IV including our row (FAgraph = the paper's name for the
/// evaluated JGraph build).
pub fn table4_rows() -> Vec<ComparatorRow> {
    let mut rows = table4_comparators();
    rows.push(ComparatorRow {
        system: "FAgraph (this work)",
        year: 2022,
        operator_count: interface_count(),
        operators: "see Figure 3 / `jgraph report --interfaces`",
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn we_beat_every_comparator() {
        // Table IV's point: FAgraph exposes more programmable operators
        // than every prior interface set.
        let ours = interface_count();
        for c in table4_comparators() {
            assert!(
                ours > c.operator_count,
                "{} has {} >= our {}",
                c.system,
                c.operator_count,
                ours
            );
        }
    }

    #[test]
    fn comparator_counts_match_paper() {
        let rows = table4_comparators();
        let counts: Vec<_> = rows.iter().map(|r| (r.system, r.operator_count)).collect();
        assert_eq!(
            counts,
            vec![
                ("GraFBoost", 4),
                ("Foregraph", 5),
                ("GraphOps", 7),
                ("GraphSoc", 17),
            ]
        );
    }

    #[test]
    fn find_is_case_insensitive() {
        assert!(find("receive").is_some());
        assert!(find("RECEIVE").is_some());
        assert!(find("nonexistent_op").is_none());
    }

    #[test]
    fn level_partition_covers_catalogue() {
        let total = by_level(Level::Atomic).len()
            + by_level(Level::Function).len()
            + by_level(Level::Algorithm).len();
        assert_eq!(total, interface_count());
    }

    #[test]
    fn categories_nonempty() {
        for c in [
            Category::GraphData,
            Category::GraphOperation,
            Category::Preprocessing,
            Category::Control,
        ] {
            assert!(!by_category(c).is_empty(), "{c:?} empty");
        }
    }
}
