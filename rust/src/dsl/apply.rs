//! The **Apply** expression language (paper §IV-B): "The basic operators
//! are included such as +, -, *, /, %, sqrt, sqare, etc. Apply contains
//! these operators to be choosed... One can program almost all the graph
//! algorithms through changing the Apply interface."
//!
//! An [`ApplyExpr`] computes the per-edge *message* from the gathered
//! source-vertex value, the edge weight, and iteration context. The
//! software GAS engine interprets it directly; the translator lowers it to
//! a chain of ALU hardware modules; and for the five canonical algorithm
//! kinds it matches the AOT-compiled Pallas kernel (checked by tests).


/// Leaf terms available to an apply expression.
#[derive(Debug, Clone, PartialEq)]
pub enum Term {
    /// Gathered source-vertex state (the `Receive` result).
    SrcValue,
    /// Destination-vertex state before update (for read-modify patterns).
    DstValue,
    /// The edge's weight.
    EdgeWeight,
    /// Current iteration number (BFS level counter).
    IterCount,
    /// A literal constant.
    Const(f64),
    /// A declared runtime parameter, bound per query and substituted to a
    /// [`Term::Const`] by [`GasProgram::instantiate`] before evaluation.
    /// In hardware this is an operand wired from the argument register
    /// file instead of a synthesized literal.
    ///
    /// [`GasProgram::instantiate`]: super::program::GasProgram::instantiate
    Param(String),
}

/// Binary operators (the paper's `+ - * / %` plus min/max which the
/// Reduce accumulators need).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Min,
    Max,
}

/// Unary operators (the paper's `sqrt, sqare`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Sqrt,
    Square,
    Neg,
    Abs,
}

/// An apply expression tree.
#[derive(Debug, Clone, PartialEq)]
pub enum ApplyExpr {
    Term(Term),
    Unary(UnOp, Box<ApplyExpr>),
    Binary(BinOp, Box<ApplyExpr>, Box<ApplyExpr>),
}

impl ApplyExpr {
    pub fn term(t: Term) -> Self {
        ApplyExpr::Term(t)
    }

    pub fn constant(c: f64) -> Self {
        ApplyExpr::Term(Term::Const(c))
    }

    /// Reference a declared runtime parameter: a per-query constant fed
    /// from the argument register file rather than baked into the design.
    pub fn param(name: impl Into<String>) -> Self {
        ApplyExpr::Term(Term::Param(name.into()))
    }

    pub fn src() -> Self {
        ApplyExpr::Term(Term::SrcValue)
    }

    pub fn weight() -> Self {
        ApplyExpr::Term(Term::EdgeWeight)
    }

    pub fn iter() -> Self {
        ApplyExpr::Term(Term::IterCount)
    }

    pub fn bin(op: BinOp, a: ApplyExpr, b: ApplyExpr) -> Self {
        ApplyExpr::Binary(op, Box::new(a), Box::new(b))
    }

    pub fn un(op: UnOp, a: ApplyExpr) -> Self {
        ApplyExpr::Unary(op, Box::new(a))
    }

    pub fn add(self, rhs: ApplyExpr) -> Self {
        Self::bin(BinOp::Add, self, rhs)
    }

    pub fn mul(self, rhs: ApplyExpr) -> Self {
        Self::bin(BinOp::Mul, self, rhs)
    }

    /// Evaluate with the given environment — the software GAS engine's
    /// interpreter. All arithmetic in f64; integer state is converted by
    /// the caller.
    pub fn eval(&self, env: &ApplyEnv) -> f64 {
        match self {
            ApplyExpr::Term(t) => match t {
                Term::SrcValue => env.src_value,
                Term::DstValue => env.dst_value,
                Term::EdgeWeight => env.edge_weight,
                Term::IterCount => env.iter_count,
                Term::Const(c) => *c,
                Term::Param(name) => panic!(
                    "parameter {name:?} is unresolved — instantiate the \
                     program (bind its ParamSet) before evaluating Apply"
                ),
            },
            ApplyExpr::Unary(op, a) => {
                let x = a.eval(env);
                match op {
                    UnOp::Sqrt => x.sqrt(),
                    UnOp::Square => x * x,
                    UnOp::Neg => -x,
                    UnOp::Abs => x.abs(),
                }
            }
            ApplyExpr::Binary(op, a, b) => {
                let (x, y) = (a.eval(env), b.eval(env));
                match op {
                    BinOp::Add => x + y,
                    BinOp::Sub => x - y,
                    BinOp::Mul => x * y,
                    BinOp::Div => x / y,
                    BinOp::Mod => x % y,
                    BinOp::Min => x.min(y),
                    BinOp::Max => x.max(y),
                }
            }
        }
    }

    /// Count of arithmetic operations — the translator sizes the Apply
    /// hardware module's ALU chain from this (one ALU per op, pipelined).
    pub fn op_count(&self) -> usize {
        match self {
            ApplyExpr::Term(_) => 0,
            ApplyExpr::Unary(_, a) => 1 + a.op_count(),
            ApplyExpr::Binary(_, a, b) => 1 + a.op_count() + b.op_count(),
        }
    }

    /// Expression depth — the Apply module's pipeline latency in stages.
    pub fn depth(&self) -> usize {
        match self {
            ApplyExpr::Term(_) => 0,
            ApplyExpr::Unary(_, a) => 1 + a.depth(),
            ApplyExpr::Binary(_, a, b) => 1 + a.depth().max(b.depth()),
        }
    }

    /// Does the expression read the edge weight? (Validation: weighted
    /// expressions need a weighted graph / the weight-carrying datapath.)
    pub fn uses_weight(&self) -> bool {
        self.any_term(|t| matches!(t, Term::EdgeWeight))
    }

    /// Does the expression read the iteration counter?
    pub fn uses_iter(&self) -> bool {
        self.any_term(|t| matches!(t, Term::IterCount))
    }

    /// Does the expression read gathered source state?
    pub fn uses_src(&self) -> bool {
        self.any_term(|t| matches!(t, Term::SrcValue))
    }

    /// Does the expression reference any runtime parameter?
    pub fn uses_params(&self) -> bool {
        self.any_term(|t| matches!(t, Term::Param(_)))
    }

    /// Collect every referenced parameter name (with duplicates) into
    /// `out` — validation checks each against the declared signature.
    pub fn param_names<'a>(&'a self, out: &mut Vec<&'a str>) {
        match self {
            ApplyExpr::Term(Term::Param(name)) => out.push(name),
            ApplyExpr::Term(_) => {}
            ApplyExpr::Unary(_, a) => a.param_names(out),
            ApplyExpr::Binary(_, a, b) => {
                a.param_names(out);
                b.param_names(out);
            }
        }
    }

    /// Substitute every [`Term::Param`] with its bound value, yielding a
    /// closed expression the interpreter can evaluate.
    pub fn bind_params(
        &self,
        resolved: &crate::dsl::params::ResolvedParams,
    ) -> Result<ApplyExpr, crate::dsl::params::ParamError> {
        use crate::dsl::params::ParamError;
        Ok(match self {
            ApplyExpr::Term(Term::Param(name)) => {
                let value = resolved
                    .get(name)
                    .ok_or_else(|| ParamError::Unbound { name: name.clone() })?;
                ApplyExpr::Term(Term::Const(value))
            }
            ApplyExpr::Term(t) => ApplyExpr::Term(t.clone()),
            ApplyExpr::Unary(op, a) => {
                ApplyExpr::Unary(*op, Box::new(a.bind_params(resolved)?))
            }
            ApplyExpr::Binary(op, a, b) => ApplyExpr::Binary(
                *op,
                Box::new(a.bind_params(resolved)?),
                Box::new(b.bind_params(resolved)?),
            ),
        })
    }

    pub(crate) fn any_term(&self, f: impl Fn(&Term) -> bool + Copy) -> bool {
        match self {
            ApplyExpr::Term(t) => f(t),
            ApplyExpr::Unary(_, a) => a.any_term(f),
            ApplyExpr::Binary(_, a, b) => a.any_term(f) || b.any_term(f),
        }
    }

    /// Human-readable rendering (used by codegen comments and reports).
    pub fn render(&self) -> String {
        match self {
            ApplyExpr::Term(t) => match t {
                Term::SrcValue => "src".into(),
                Term::DstValue => "dst".into(),
                Term::EdgeWeight => "w".into(),
                Term::IterCount => "iter".into(),
                Term::Const(c) => format!("{c}"),
                Term::Param(name) => format!("${name}"),
            },
            ApplyExpr::Unary(op, a) => {
                let name = match op {
                    UnOp::Sqrt => "sqrt",
                    UnOp::Square => "sq",
                    UnOp::Neg => "neg",
                    UnOp::Abs => "abs",
                };
                format!("{name}({})", a.render())
            }
            ApplyExpr::Binary(op, a, b) => {
                let sym = match op {
                    BinOp::Add => "+",
                    BinOp::Sub => "-",
                    BinOp::Mul => "*",
                    BinOp::Div => "/",
                    BinOp::Mod => "%",
                    BinOp::Min => "min",
                    BinOp::Max => "max",
                };
                match op {
                    BinOp::Min | BinOp::Max => {
                        format!("{sym}({}, {})", a.render(), b.render())
                    }
                    _ => format!("({} {sym} {})", a.render(), b.render()),
                }
            }
        }
    }
}

/// Evaluation environment for one edge.
#[derive(Debug, Clone, Copy, Default)]
pub struct ApplyEnv {
    pub src_value: f64,
    pub dst_value: f64,
    pub edge_weight: f64,
    pub iter_count: f64,
}

/// Specialized forms of common apply expressions — the software engine's
/// analogue of the translator's fixed ALU chains. Detecting the shape
/// once per run removes the per-edge tree walk from the hot loop
/// (EXPERIMENTS.md §Perf, L3): the five canonical algorithms all compile
/// to one of the closed forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompiledApply {
    /// Reads neither src nor dst nor weight — constant within a superstep
    /// (BFS: `iter + 1`).
    ConstPerIter,
    /// `src` (WCC labels, PR contributions).
    Src,
    /// `src + w` (SSSP relaxation).
    SrcPlusWeight,
    /// `src * w` (SpMV products).
    SrcTimesWeight,
    /// Anything else: fall back to the tree interpreter.
    General,
}

impl CompiledApply {
    /// Classify an expression. Conservative: only exact shapes map to the
    /// closed forms; everything else keeps full generality.
    pub fn compile(e: &ApplyExpr) -> CompiledApply {
        use ApplyExpr as E;
        let uses_dst = e.any_term(|t| matches!(t, Term::DstValue));
        if !e.uses_src() && !e.uses_weight() && !uses_dst {
            return CompiledApply::ConstPerIter;
        }
        match e {
            E::Term(Term::SrcValue) => CompiledApply::Src,
            E::Binary(op, a, b) => match (op, a.as_ref(), b.as_ref()) {
                (BinOp::Add, E::Term(Term::SrcValue), E::Term(Term::EdgeWeight))
                | (BinOp::Add, E::Term(Term::EdgeWeight), E::Term(Term::SrcValue)) => {
                    CompiledApply::SrcPlusWeight
                }
                (BinOp::Mul, E::Term(Term::SrcValue), E::Term(Term::EdgeWeight))
                | (BinOp::Mul, E::Term(Term::EdgeWeight), E::Term(Term::SrcValue)) => {
                    CompiledApply::SrcTimesWeight
                }
                _ => CompiledApply::General,
            },
            _ => CompiledApply::General,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> ApplyEnv {
        ApplyEnv { src_value: 3.0, dst_value: 10.0, edge_weight: 2.0, iter_count: 5.0 }
    }

    #[test]
    fn eval_basic_ops() {
        let e = ApplyExpr::src().add(ApplyExpr::weight());
        assert_eq!(e.eval(&env()), 5.0);
        let e = ApplyExpr::bin(BinOp::Mul, ApplyExpr::src(), ApplyExpr::constant(4.0));
        assert_eq!(e.eval(&env()), 12.0);
        let e = ApplyExpr::un(UnOp::Square, ApplyExpr::weight());
        assert_eq!(e.eval(&env()), 4.0);
        let e = ApplyExpr::un(UnOp::Sqrt, ApplyExpr::constant(16.0));
        assert_eq!(e.eval(&env()), 4.0);
        let e = ApplyExpr::bin(BinOp::Mod, ApplyExpr::constant(7.0), ApplyExpr::constant(4.0));
        assert_eq!(e.eval(&env()), 3.0);
        let e = ApplyExpr::bin(BinOp::Min, ApplyExpr::src(), ApplyExpr::weight());
        assert_eq!(e.eval(&env()), 2.0);
    }

    #[test]
    fn bfs_expression_is_iter_plus_one() {
        // the paper: "the Apply function is the current value plus one"
        let e = ApplyExpr::iter().add(ApplyExpr::constant(1.0));
        assert_eq!(e.eval(&env()), 6.0);
        assert!(e.uses_iter() && !e.uses_weight() && !e.uses_src());
    }

    #[test]
    fn op_count_and_depth() {
        // (src + w) * (src + 1) -> 3 ops, depth 2
        let e = ApplyExpr::bin(
            BinOp::Mul,
            ApplyExpr::src().add(ApplyExpr::weight()),
            ApplyExpr::src().add(ApplyExpr::constant(1.0)),
        );
        assert_eq!(e.op_count(), 3);
        assert_eq!(e.depth(), 2);
    }

    #[test]
    fn param_terms_substitute_before_eval() {
        use crate::dsl::params::{ParamSet, ParamSignature, ParamSpec};
        let e = ApplyExpr::src().mul(ApplyExpr::param("beta"));
        assert!(e.uses_params());
        let mut names = Vec::new();
        e.param_names(&mut names);
        assert_eq!(names, vec!["beta"]);
        let mut sig = ParamSignature::default();
        sig.declare(ParamSpec::new("beta", 2.0));
        let resolved = sig.resolve(&ParamSet::new().bind("beta", 4.0)).unwrap();
        let closed = e.bind_params(&resolved).unwrap();
        assert!(!closed.uses_params());
        assert_eq!(closed.eval(&env()), 12.0);
        assert_eq!(e.render(), "(src * $beta)");
    }

    #[test]
    #[should_panic(expected = "unresolved")]
    fn eval_of_unbound_param_panics() {
        ApplyExpr::param("gamma").eval(&env());
    }

    #[test]
    fn render_is_readable() {
        let e = ApplyExpr::src().add(ApplyExpr::weight());
        assert_eq!(e.render(), "(src + w)");
        let e = ApplyExpr::bin(BinOp::Min, ApplyExpr::src(), ApplyExpr::constant(2.0));
        assert_eq!(e.render(), "min(src, 2)");
    }

}
