//! The JGraph **graph DSL** (paper §IV): atomic operators for graph
//! processing, organized in the paper's three abstraction levels
//! (§IV-D):
//!
//! 1. **algorithm level** — ready algorithms with parameters
//!    ([`algorithms`]: `bfs()`, `pagerank()`, …, each a [`GasProgram`]);
//! 2. **function level** — the GAS operations and graph-data functions
//!    ([`program`], [`apply`]: `Receive`/`Apply`/`Reduce`/`Send`, vertex
//!    and edge getters);
//! 3. **atomic-op level** — the instruction-like operators ([`ops`]:
//!    `load_Vertices`, `get_address`, …).
//!
//! A [`program::GasProgram`] is the translatable unit: it decouples graph
//! *scheduling* (frontier policy, direction, convergence) from the graph
//! *algorithm* (the [`apply::ApplyExpr`] and reduce operator), exactly the
//! decoupling the paper credits for translator optimization.
//!
//! [`registry`] enumerates every public interface — the Table IV count.

pub mod algorithms;
pub mod apply;
pub mod builder;
pub mod isa;
pub mod ops;
pub mod params;
pub mod program;
pub mod registry;
pub mod validate;

pub use apply::{ApplyExpr, BinOp, Term, UnOp};
pub use builder::GasProgramBuilder;
pub use params::{ParamError, ParamSet, ParamSignature, ParamSpec, ResolvedParams, Scalar};
pub use program::{
    Convergence, Direction, EdgeOpKind, FrontierPolicy, GasProgram, InitPolicy, ReduceOp,
    StateType,
};
