//! The atomic-instruction level made concrete: compile a [`GasProgram`]
//! into the instruction sequence a GraphSoc-style soft processor would
//! execute (paper §II-B3: "some works provide a few graph instructions
//! abstracted from graph atomic operations", and §IV-D level 3: "the
//! fine-grained encapsulation includes sets of exist graph instructions,
//! atimic operations and control commands, such as load_Vertices,
//! get_address, etc.").
//!
//! One superstep of any GAS program lowers to a fixed loop skeleton with
//! program-dependent Apply/Reduce bodies — which is exactly why the
//! translator can map programs onto fixed hardware: the instruction
//! stream's *shape* is algorithm-independent. `jgraph translate --emit
//! isa` prints it; the engine's instruction counter doubles as a cost
//! model cross-check (tests compare it against the simulator's issue
//! counts).

use crate::dsl::apply::ApplyExpr;
use crate::dsl::program::{FrontierPolicy, GasProgram, ReduceOp, Writeback};


/// The graph-ISA: close to GraphSoc's mnemonic set (SND/RCV/ACCU/UPD…)
/// extended with the memory ops of §IV-D's examples.
#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    /// Write the query's bound parameters into the argument register file
    /// (`set_argument`): the host's per-query setup, no recompile.
    LoadArgs { count: usize },
    /// Burst-load vertex values into BRAM (`load_Vertices`).
    LoadVertices { base: &'static str, len: &'static str },
    /// Compute a DRAM address (`get_address`).
    GetAddress { array: &'static str, index: &'static str },
    /// Issue a DDR burst read.
    BurstRead { addr: &'static str, beats: u32 },
    /// Pop the next active vertex (frontier loop head).
    QueuePop,
    /// Receive a gathered source value (RCV).
    Rcv { reg: &'static str },
    /// One ALU op of the Apply chain.
    Alu { op: String, dst: &'static str },
    /// Accumulate into the reduce bank (ACCU).
    Accu { op: &'static str },
    /// Conditional vertex update (UPD).
    Upd { rule: &'static str },
    /// Push a newly-activated vertex (SND to the frontier).
    QueuePush,
    /// Branch if the frontier/edge loop continues (BNZ).
    Bnz { target: &'static str },
    /// Superstep barrier / host doorbell.
    Halt,
}

impl Instr {
    pub fn mnemonic(&self) -> String {
        match self {
            Instr::LoadArgs { count } => format!("LARG  x{count}"),
            Instr::LoadVertices { base, len } => format!("LDV   {base}, {len}"),
            Instr::GetAddress { array, index } => format!("ADDR  {array}[{index}]"),
            Instr::BurstRead { addr, beats } => format!("BRD   {addr}, x{beats}"),
            Instr::QueuePop => "QPOP  v".into(),
            Instr::Rcv { reg } => format!("RCV   {reg}"),
            Instr::Alu { op, dst } => format!("ALU.{op} {dst}"),
            Instr::Accu { op } => format!("ACCU.{op} bank[dst]"),
            Instr::Upd { rule } => format!("UPD.{rule} V[dst]"),
            Instr::QueuePush => "QPUSH dst".into(),
            Instr::Bnz { target } => format!("BNZ   {target}"),
            Instr::Halt => "HALT".into(),
        }
    }
}

/// The compiled superstep: a labelled instruction listing plus the
/// per-edge / per-vertex instruction counts the cost model uses.
#[derive(Debug, Clone)]
pub struct IsaProgram {
    pub instrs: Vec<(Option<&'static str>, Instr)>,
    /// Instructions executed once per superstep.
    pub per_superstep: usize,
    /// Instructions executed once per active vertex.
    pub per_vertex: usize,
    /// Instructions executed once per edge.
    pub per_edge: usize,
}

impl IsaProgram {
    /// Render the assembly-style listing.
    pub fn listing(&self) -> String {
        let mut out = String::new();
        for (label, i) in &self.instrs {
            match label {
                Some(l) => out += &format!("{l}:\n    {}\n", i.mnemonic()),
                None => out += &format!("    {}\n", i.mnemonic()),
            }
        }
        out
    }

    /// Total instructions for a superstep touching `vertices` rows and
    /// `edges` edges — the soft-processor cost model.
    pub fn dynamic_count(&self, vertices: u64, edges: u64) -> u64 {
        self.per_superstep as u64 + self.per_vertex as u64 * vertices + self.per_edge as u64 * edges
    }
}

/// Compile one superstep of `program` to the graph ISA.
pub fn compile(program: &GasProgram) -> IsaProgram {
    let mut instrs: Vec<(Option<&'static str>, Instr)> = Vec::new();
    let mut per_superstep = 0;
    let mut per_vertex = 0;
    let mut per_edge = 0;

    // prologue: bound parameters into the argument registers (once per
    // query, written by the host), then vertex state into BRAM
    if program.has_runtime_params() {
        instrs.push((None, Instr::LoadArgs { count: program.params.len() }));
        per_superstep += 1;
    }
    instrs.push((None, Instr::LoadVertices { base: "V", len: "N" }));
    per_superstep += 1;

    // vertex loop head
    let vertex_label = match program.frontier {
        FrontierPolicy::Active => "next_active",
        FrontierPolicy::All => "next_vertex",
    };
    instrs.push((Some(vertex_label), Instr::QueuePop));
    instrs.push((None, Instr::GetAddress { array: "Edge_offset", index: "v" }));
    instrs.push((None, Instr::BurstRead { addr: "off", beats: 1 }));
    per_vertex += 3;

    // edge loop body
    instrs.push((Some("next_edge"), Instr::GetAddress { array: "Edges", index: "e" }));
    instrs.push((None, Instr::BurstRead { addr: "edge", beats: 1 }));
    instrs.push((None, Instr::Rcv { reg: "r_src" }));
    per_edge += 3;
    for op in alu_ops(&program.apply) {
        instrs.push((None, Instr::Alu { op, dst: "r_msg" }));
        per_edge += 1;
    }
    let acc = match program.reduce {
        ReduceOp::Min => "MIN",
        ReduceOp::Max => "MAX",
        ReduceOp::Sum => "SUM",
    };
    instrs.push((None, Instr::Accu { op: acc }));
    instrs.push((None, Instr::Bnz { target: "next_edge" }));
    per_edge += 2;

    // writeback + frontier maintenance per touched vertex
    let rule = match program.writeback {
        Writeback::MinCombine => "MIN",
        Writeback::MaxCombine => "MAX",
        Writeback::IfUnvisited => "UNV",
        Writeback::Overwrite => "OVR",
        Writeback::DampedSum(_) => "DMP",
    };
    instrs.push((None, Instr::Upd { rule }));
    per_vertex += 1;
    if program.frontier == FrontierPolicy::Active {
        instrs.push((None, Instr::QueuePush));
        per_vertex += 1;
    }
    instrs.push((None, Instr::Bnz { target: vertex_label }));
    per_vertex += 1;

    instrs.push((None, Instr::Halt));
    per_superstep += 1;

    IsaProgram { instrs, per_superstep, per_vertex, per_edge }
}

fn alu_ops(expr: &ApplyExpr) -> Vec<String> {
    // the translator's ALU-chain flattening is the same post-order walk
    crate::translator::lower::alu_chain(expr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;

    #[test]
    fn bfs_listing_shape() {
        let isa = compile(&algorithms::bfs());
        let text = isa.listing();
        assert!(text.contains("LDV"));
        assert!(text.contains("next_active:"));
        assert!(text.contains("ACCU.MIN"));
        assert!(text.contains("UPD.UNV"));
        assert!(text.contains("QPUSH"), "active frontier pushes");
        assert!(text.contains("HALT"));
    }

    #[test]
    fn all_active_programs_have_no_queue_push() {
        let isa = compile(&algorithms::pagerank());
        assert!(!isa.listing().contains("QPUSH"));
        assert!(isa.listing().contains("next_vertex:"));
        // parameterized programs load their argument registers up front
        assert!(isa.listing().contains("LARG  x2"));
        assert!(isa.listing().contains("UPD.DMP"));
    }

    #[test]
    fn per_edge_count_tracks_apply_complexity() {
        let bfs = compile(&algorithms::bfs()); // iter+1: 1 ALU op
        let sssp = compile(&algorithms::sssp()); // src+w: 1 ALU op
        assert_eq!(bfs.per_edge, sssp.per_edge);
        let custom = crate::dsl::builder::GasProgramBuilder::new("deep")
            .apply(
                crate::dsl::apply::ApplyExpr::src()
                    .add(crate::dsl::apply::ApplyExpr::weight())
                    .mul(crate::dsl::apply::ApplyExpr::constant(2.0)),
            )
            .build()
            .unwrap();
        assert!(compile(&custom).per_edge > bfs.per_edge);
    }

    #[test]
    fn dynamic_count_is_affine() {
        let isa = compile(&algorithms::wcc());
        let base = isa.dynamic_count(0, 0);
        assert_eq!(base, isa.per_superstep as u64);
        assert_eq!(
            isa.dynamic_count(10, 100) - base,
            10 * isa.per_vertex as u64 + 100 * isa.per_edge as u64
        );
    }

    #[test]
    fn instruction_count_matches_graphsoc_scale() {
        // GraphSoc exposes 17 instructions; our ISA skeleton per program
        // stays in the same order of magnitude (it is an abstraction
        // level, not a bloated VM)
        for p in algorithms::all() {
            let isa = compile(&p);
            assert!(
                (8..=24).contains(&isa.instrs.len()),
                "{}: {} instrs",
                p.name,
                isa.instrs.len()
            );
        }
    }
}
