//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.tsv` + `*.hlo.txt` at build time) and the
//! rust runtime (which compiles and executes them at startup). Python is
//! never on the request path — this module only reads files.
//!
//! The manifest is tab-separated (one artifact per line) because the
//! offline build has no JSON dependency; aot.py also writes a
//! `manifest.json` twin for humans/tools.
//!
//! Line format (tab-separated):
//! `algo bucket n m block use_pallas file sha256 inputs outputs`
//! where inputs/outputs are `name:dtype:elements` triples joined by `;`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Element type of a tensor in the artifact ABI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I32,
    F32,
}

impl std::str::FromStr for DType {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "i32" => DType::I32,
            "f32" => DType::F32,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// One input/output tensor spec (all artifact tensors are rank-1 or
/// scalar; only the element count matters for literal transport).
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub elements: usize,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.elements.max(1)
    }

    fn parse(field: &str) -> Result<Self> {
        let parts: Vec<&str> = field.split(':').collect();
        if parts.len() != 3 {
            bail!("bad tensor spec {field:?} (want name:dtype:elems)");
        }
        Ok(TensorSpec {
            name: parts[0].to_string(),
            dtype: parts[1].parse()?,
            elements: parts[2].parse().with_context(|| format!("bad elems in {field:?}"))?,
        })
    }
}

/// One AOT-compiled superstep artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub algo: String,
    pub bucket: String,
    /// Padded vertex count.
    pub n: usize,
    /// Padded edge count.
    pub m: usize,
    pub block: usize,
    pub use_pallas: bool,
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `manifest.tsv` from the artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    /// Parse manifest text (unit-testable without disk).
    pub fn parse(text: &str) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            // NB: do not trim the line itself — trailing empty fields
            // (no inputs/outputs) are legitimate and tab-separated.
            if line.trim().is_empty() || line.trim_start().starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.trim_end_matches('\r').split('\t').collect();
            if f.len() != 10 {
                bail!("manifest line {}: want 10 tab-separated fields, got {}", lineno + 1, f.len());
            }
            let parse_specs = |s: &str| -> Result<Vec<TensorSpec>> {
                if s.is_empty() {
                    return Ok(Vec::new());
                }
                s.split(';').map(TensorSpec::parse).collect()
            };
            artifacts.push(ArtifactMeta {
                algo: f[0].to_string(),
                bucket: f[1].to_string(),
                n: f[2].parse().context("n")?,
                m: f[3].parse().context("m")?,
                block: f[4].parse().context("block")?,
                use_pallas: f[5] == "1" || f[5] == "true",
                file: f[6].to_string(),
                sha256: f[7].to_string(),
                inputs: parse_specs(f[8])?,
                outputs: parse_specs(f[9])?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { artifacts })
    }

    /// The smallest bucket of `algo` fitting a graph with `n` vertices and
    /// `m` edges.
    pub fn select(&self, algo: &str, n: usize, m: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.algo == algo && a.n >= n && a.m >= m)
            .min_by_key(|a| (a.m, a.n))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact bucket for algo {algo:?} with n={n}, m={m}; \
                     available: {:?}",
                    self.artifacts
                        .iter()
                        .filter(|a| a.algo == algo)
                        .map(|a| (a.bucket.as_str(), a.n, a.m))
                        .collect::<Vec<_>>()
                )
            })
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, dir: impl AsRef<Path>, meta: &ArtifactMeta) -> PathBuf {
        dir.as_ref().join(&meta.file)
    }
}

/// Locate the artifact directory: `$JGRAPH_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walking up from cwd until found).
pub fn default_artifact_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("JGRAPH_ARTIFACTS") {
        return Ok(PathBuf::from(dir));
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.tsv").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            bail!(
                "artifacts/manifest.tsv not found in any parent directory; \
                 run `make artifacts` or set JGRAPH_ARTIFACTS"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> Manifest {
        let tsv = "\
# comment line\n\
bfs\ttiny\t256\t4096\t4096\t1\tbfs_tiny.hlo.txt\txx\tlevels:i32:256;num_edges:i32:1\tnew_levels:i32:256;frontier_size:i32:0\n\
bfs\tsmall\t1024\t32768\t4096\t1\tbfs_small.hlo.txt\tyy\t\t\n";
        Manifest::parse(tsv).unwrap()
    }

    #[test]
    fn parse_fields() {
        let m = fake_manifest();
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts[0];
        assert_eq!(a.algo, "bfs");
        assert_eq!(a.n, 256);
        assert!(a.use_pallas);
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].name, "levels");
        assert_eq!(a.inputs[0].dtype, DType::I32);
        assert_eq!(a.outputs[1].elements(), 1, "scalar reads back 1 element");
    }

    #[test]
    fn select_smallest_fitting_bucket() {
        let m = fake_manifest();
        assert_eq!(m.select("bfs", 100, 1000).unwrap().bucket, "tiny");
        assert_eq!(m.select("bfs", 256, 4096).unwrap().bucket, "tiny");
        assert_eq!(m.select("bfs", 300, 1000).unwrap().bucket, "small");
        assert_eq!(m.select("bfs", 100, 10_000).unwrap().bucket, "small");
    }

    #[test]
    fn select_fails_when_too_big_or_unknown() {
        let m = fake_manifest();
        assert!(m.select("bfs", 10_000, 10).is_err());
        assert!(m.select("dfs", 10, 10).is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("too\tfew\tfields\n").is_err());
        assert!(Manifest::parse("").is_err());
        let bad_dtype = "bfs\ttiny\t1\t1\t1\t1\tf\tx\tv:i64:4\t\n";
        assert!(Manifest::parse(bad_dtype).is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // soft test: if the workspace artifacts exist, parse them
        if let Ok(dir) = default_artifact_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 20);
            assert!(m.select("bfs", 1005, 25571).is_ok(), "email-Eu-core bucket");
            assert!(m.select("bfs", 82168, 948464).is_ok(), "slashdot bucket");
        }
    }
}
