//! PJRT client wrapper: load HLO text → compile once → execute many.
//! Pattern follows /opt/xla-example/load_hlo (text interchange because
//! xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactMeta, DType};

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature expects the real `xla` (xla_extension) bindings crate; \
     vendor it, add it as a dependency, and delete the stub `mod xla` in \
     runtime/client.rs"
);

/// Inert stand-in for the `xla` PJRT bindings so the crate builds without
/// the XLA C++ toolchain. The client opens fine (registries can parse
/// manifests and report a platform), but `HloModuleProto::from_text_file`
/// always fails — no [`Executable`] can ever be constructed, so the engine
/// falls back to the software GAS oracle. Build with `--features pjrt`
/// (after vendoring the bindings) for real AOT execution.
#[cfg(not(feature = "pjrt"))]
pub mod xla {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Placeholder for a PJRT host literal.
    #[derive(Debug)]
    pub struct Literal;

    impl Literal {
        pub fn vec1<T>(_v: &[T]) -> Literal {
            Literal
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            bail!("PJRT backend not compiled in (build with --features pjrt)")
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>> {
            bail!("PJRT backend not compiled in (build with --features pjrt)")
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<Self> {
            Ok(PjRtClient)
        }

        pub fn platform_name(&self) -> String {
            "stub (pjrt feature disabled)".into()
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            bail!("PJRT backend not compiled in (build with --features pjrt)")
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &Path) -> Result<Self> {
            bail!("PJRT backend not compiled in (build with --features pjrt)")
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    /// Placeholder for a device-side output buffer.
    pub struct ExecOut;

    impl ExecOut {
        pub fn to_literal_sync(&self) -> Result<Literal> {
            bail!("PJRT backend not compiled in (build with --features pjrt)")
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<ExecOut>>> {
            bail!("PJRT backend not compiled in (build with --features pjrt)")
        }
    }
}

/// A host-side typed buffer crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Buffer {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl Buffer {
    pub fn len(&self) -> usize {
        match self {
            Buffer::I32(v) => v.len(),
            Buffer::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Buffer::I32(_) => DType::I32,
            Buffer::F32(_) => DType::F32,
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Buffer::I32(v) => Ok(v),
            Buffer::F32(_) => bail!("expected i32 buffer, got f32"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Buffer::F32(v) => Ok(v),
            Buffer::I32(_) => bail!("expected f32 buffer, got i32"),
        }
    }

    /// First element as i64 (scalar readback convenience).
    pub fn scalar_i64(&self) -> Result<i64> {
        match self {
            Buffer::I32(v) => Ok(*v.first().ok_or_else(|| anyhow::anyhow!("empty buffer"))? as i64),
            Buffer::F32(v) => Ok(*v.first().ok_or_else(|| anyhow::anyhow!("empty buffer"))? as i64),
        }
    }

    pub fn scalar_f64(&self) -> Result<f64> {
        match self {
            Buffer::F32(v) => Ok(*v.first().ok_or_else(|| anyhow::anyhow!("empty buffer"))? as f64),
            Buffer::I32(v) => Ok(*v.first().ok_or_else(|| anyhow::anyhow!("empty buffer"))? as f64),
        }
    }

    fn to_literal(&self) -> xla::Literal {
        match self {
            Buffer::I32(v) => xla::Literal::vec1(v),
            Buffer::F32(v) => xla::Literal::vec1(v),
        }
    }

    fn from_literal(lit: &xla::Literal, dtype: DType) -> Result<Buffer> {
        Ok(match dtype {
            DType::I32 => Buffer::I32(lit.to_vec::<i32>()?),
            DType::F32 => Buffer::F32(lit.to_vec::<f32>()?),
        })
    }
}

/// The PJRT CPU client (one per process).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact. Compilation happens once at load time;
    /// `Executable::run` is the request path.
    pub fn load(&self, path: impl AsRef<Path>, meta: &ArtifactMeta) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.as_ref())
            .with_context(|| format!("parsing HLO text {:?}", path.as_ref()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        Ok(Executable { exe, meta: meta.clone(), compile_seconds: t0.elapsed().as_secs_f64() })
    }
}

/// One compiled superstep, executable from the hot loop.
// Manual Debug below: the wrapped PJRT handle is opaque.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    pub compile_seconds: f64,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable")
            .field("file", &self.meta.file)
            .field("compile_seconds", &self.compile_seconds)
            .finish_non_exhaustive()
    }
}

/// Argument to [`Executable::run_args`]: either a host buffer (converted
/// to a literal on the spot) or a pre-converted literal (static operands —
/// edge arrays — prepared once per run; §Perf: skips re-copying the COO
/// arrays every superstep).
pub enum ArgRef<'a> {
    Buf(&'a Buffer),
    Lit(&'a xla::Literal),
}

impl Executable {
    /// Execute one superstep. `args` must match the artifact ABI (count,
    /// length, dtype) — validated here so engine bugs fail loudly instead
    /// of segfaulting inside PJRT.
    pub fn run(&self, args: &[Buffer]) -> Result<Vec<Buffer>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                self.meta.file,
                self.meta.inputs.len(),
                args.len()
            );
        }
        for (a, spec) in args.iter().zip(&self.meta.inputs) {
            if a.len() != spec.elements() {
                bail!(
                    "input {:?}: expected {} elements, got {}",
                    spec.name,
                    spec.elements(),
                    a.len()
                );
            }
            if a.dtype() != spec.dtype {
                bail!("input {:?}: dtype mismatch", spec.name);
            }
        }
        let literals: Vec<xla::Literal> = args.iter().map(Buffer::to_literal).collect();
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.execute_refs(&refs)
    }

    /// Validate and pre-convert one input to a literal for reuse across
    /// supersteps (pair with [`Self::run_args`]).
    pub fn prepare(&self, index: usize, buf: &Buffer) -> Result<xla::Literal> {
        let spec = self
            .meta
            .inputs
            .get(index)
            .ok_or_else(|| anyhow::anyhow!("input index {index} out of range"))?;
        if buf.len() != spec.elements() || buf.dtype() != spec.dtype {
            bail!("prepare({index}): buffer does not match input {:?}", spec.name);
        }
        Ok(buf.to_literal())
    }

    /// Execute with a mix of cached literals and fresh buffers. Cached
    /// entries must have been produced by [`Self::prepare`] for the same
    /// position.
    pub fn run_args(&self, args: &[ArgRef<'_>]) -> Result<Vec<Buffer>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                self.meta.file,
                self.meta.inputs.len(),
                args.len()
            );
        }
        // fresh buffers are validated + converted; cached literals pass
        // through (validated at prepare() time)
        let mut owned: Vec<Option<xla::Literal>> = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            match a {
                ArgRef::Buf(b) => {
                    let spec = &self.meta.inputs[i];
                    if b.len() != spec.elements() || b.dtype() != spec.dtype {
                        bail!("input {:?}: shape/dtype mismatch", spec.name);
                    }
                    owned.push(Some(b.to_literal()));
                }
                ArgRef::Lit(_) => owned.push(None),
            }
        }
        let refs: Vec<&xla::Literal> = args
            .iter()
            .zip(&owned)
            .map(|(a, o)| match a {
                ArgRef::Lit(l) => *l,
                ArgRef::Buf(_) => o.as_ref().unwrap(),
            })
            .collect();
        self.execute_refs(&refs)
    }

    fn execute_refs(&self, refs: &[&xla::Literal]) -> Result<Vec<Buffer>> {
        let result = self.exe.execute::<&xla::Literal>(refs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                self.meta.file,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.meta.outputs)
            .map(|(lit, spec)| Buffer::from_literal(lit, spec.dtype))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_typing() {
        let b = Buffer::I32(vec![1, 2, 3]);
        assert_eq!(b.len(), 3);
        assert!(b.as_i32().is_ok());
        assert!(b.as_f32().is_err());
        assert_eq!(b.scalar_i64().unwrap(), 1);
        let f = Buffer::F32(vec![2.5]);
        assert_eq!(f.scalar_f64().unwrap(), 2.5);
        assert_eq!(f.dtype(), DType::F32);
    }

    #[test]
    fn empty_scalar_errors() {
        assert!(Buffer::I32(vec![]).scalar_i64().is_err());
    }
}
