//! Executable registry: (algorithm, bucket) → compiled [`Executable`],
//! compiled lazily on first use and cached for the rest of the process.
//! The paper's per-model-variant "one compiled executable" rule.
//!
//! One level up the stack, the serve daemon applies the same
//! compile-on-first-use discipline to whole pipelines and prepared
//! graphs: see [`crate::serve::registry::ServeRegistry`], which adds
//! LRU residency bounds (graphs are the memory that matters) on top of
//! this registry's cache-forever policy.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::artifact::{default_artifact_dir, ArtifactMeta, Manifest};
use super::client::{Executable, PjrtRuntime};

/// Thread-safe registry over one PJRT client.
pub struct KernelRegistry {
    runtime: PjrtRuntime,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<(String, String), Arc<Executable>>>,
}

impl KernelRegistry {
    /// Open the default artifact directory (see
    /// [`default_artifact_dir`]) on the CPU PJRT client.
    pub fn open_default() -> Result<Self> {
        let dir = default_artifact_dir()?;
        Self::open(dir)
    }

    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        Ok(Self { runtime: PjrtRuntime::cpu()?, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Get (compile-on-first-use) the smallest executable of `algo`
    /// fitting a graph with `n` vertices / `m` edges.
    pub fn for_graph(&self, algo: &str, n: usize, m: usize) -> Result<Arc<Executable>> {
        let meta = self.manifest.select(algo, n, m)?.clone();
        self.load_cached(&meta)
    }

    /// Get a specific bucket (used by benches to pin sizes).
    pub fn for_bucket(&self, algo: &str, bucket: &str) -> Result<Arc<Executable>> {
        let meta = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.algo == algo && a.bucket == bucket)
            .ok_or_else(|| anyhow::anyhow!("no artifact {algo}/{bucket}"))?
            .clone();
        self.load_cached(&meta)
    }

    fn load_cached(&self, meta: &ArtifactMeta) -> Result<Arc<Executable>> {
        let key = (meta.algo.clone(), meta.bucket.clone());
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let path = self.manifest.path_of(&self.dir, meta);
        let exe = Arc::new(self.runtime.load(&path, meta)?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }
}
