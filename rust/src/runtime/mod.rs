//! The PJRT runtime: loads the HLO-text artifacts that `make artifacts`
//! produced from the JAX/Pallas layers and executes them from rust. This
//! is the only place the three layers meet at run time; Python is never
//! on the request path.
//!
//! Flow (per /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` (once, at load)
//! → `execute` (the hot path).

pub mod artifact;
pub mod client;
pub mod registry;

pub use artifact::{default_artifact_dir, ArtifactMeta, DType, Manifest, TensorSpec};
pub use client::{Buffer, Executable, PjrtRuntime};
pub use registry::KernelRegistry;
