//! `jgraph` — CLI for the JGraph framework: translate DSL programs, run
//! them on the simulated U200 through the AOT/XLA functional path, and
//! regenerate the paper's tables and figures.
//!
//! Argument parsing is hand-rolled (the offline build has no clap):
//!
//! ```text
//! jgraph run --algo bfs --graph email --translator jgraph [--pipelines 8]
//!            [--pes 1] [--root 0] [--reorder degree] [--no-xla] [--verbose]
//! jgraph serve [--addr 127.0.0.1:7411] [--batch-window-us 2000]
//!              [--register name=spec] [--tenant-cap tenant=N]
//! jgraph translate --algo sssp [--translator vivado] [--emit hdl|chisel|host|library|isa|both|stats]
//! jgraph report --table 5 | --fig 5 | --interfaces [--full]
//! jgraph gen --preset slashdot --out /tmp/slashdot.bin [--seed 7]
//! jgraph info
//! ```

use anyhow::{bail, Context, Result};

use jgraph::dsl::algorithms;
use jgraph::dsl::program::GasProgram;
use jgraph::engine::{CompileError, RunOptions, Session, SessionConfig};
use jgraph::graph::{edgelist::EdgeList, io};
use jgraph::prep::prepared::PrepOptions;
use jgraph::prep::reorder::ReorderStrategy;
use jgraph::sched::ParallelismPlan;
use jgraph::translator::{Translator, TranslatorKind};

/// Minimal flag parser: `--key value` pairs + boolean `--flag`s.
/// Keys listed in `REPEATABLE` (e.g. `--param`) may appear many times and
/// accumulate in order.
struct Args {
    values: std::collections::HashMap<String, String>,
    repeated: Vec<(String, String)>,
    flags: std::collections::HashSet<String>,
}

/// Flags that may be passed more than once.
const REPEATABLE: &[&str] = &["param", "register", "tenant-cap"];

impl Args {
    fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Self> {
        let mut values = std::collections::HashMap::new();
        let mut repeated = Vec::new();
        let mut flags = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            if bool_flags.contains(&key) {
                flags.insert(key.to_string());
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .with_context(|| format!("--{key} needs a value"))?;
                if REPEATABLE.contains(&key) {
                    repeated.push((key.to_string(), v.clone()));
                } else {
                    values.insert(key.to_string(), v.clone());
                }
                i += 2;
            }
        }
        Ok(Self { values, repeated, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// All occurrences of a repeatable key, in command-line order.
    fn get_all(&self, key: &str) -> Vec<&str> {
        self.repeated
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.contains(key)
    }
}

/// Parse one `--param name=value` occurrence.
fn parse_param(spec: &str) -> Result<(String, f64)> {
    let (name, value) = spec
        .split_once('=')
        .with_context(|| format!("--param {spec:?}: expected name=value"))?;
    let value: f64 = value
        .parse()
        .map_err(|e| anyhow::anyhow!("--param {spec:?}: {e}"))?;
    Ok((name.to_string(), value))
}

/// Collect every `--param` flag into a `ParamSet` and pre-flight it
/// against the program's declared signature, so a typo'd name fails here
/// with the declared parameter list instead of mid-run.
fn param_set_for(args: &Args, program: &GasProgram) -> Result<jgraph::dsl::ParamSet> {
    let mut set = jgraph::dsl::ParamSet::new();
    for spec in args.get_all("param") {
        let (name, value) = parse_param(spec)?;
        set.set(name, value);
    }
    program
        .resolve_params(&set)
        .map_err(|e| anyhow::anyhow!("program {:?}: {e}", program.name))?;
    Ok(set)
}

fn program_of(name: &str) -> Result<GasProgram> {
    Ok(match name {
        "bfs" => algorithms::bfs(),
        "pagerank" | "pr" => algorithms::pagerank(),
        "sssp" => algorithms::sssp(),
        "wcc" => algorithms::wcc(),
        "spmv" => algorithms::spmv(),
        "degree-count" => algorithms::degree_count(),
        "widest-path" => algorithms::widest_path(),
        "reachability" => algorithms::reachability(),
        "max-label" => algorithms::max_label(),
        other => bail!(
            "unknown algorithm {other:?} (bfs|pagerank|sssp|wcc|spmv|\
             degree-count|widest-path|reachability|max-label)"
        ),
    })
}

fn translator_of(name: &str) -> Result<TranslatorKind> {
    Ok(match name {
        "jgraph" | "fagraph" => TranslatorKind::JGraph,
        "vivado" | "vivado-hls" => TranslatorKind::VivadoHls,
        "spatial" => TranslatorKind::Spatial,
        other => bail!("unknown translator {other:?} (jgraph|vivado|spatial)"),
    })
}

fn load_graph(spec: &str, seed: u64) -> Result<(String, EdgeList)> {
    // one resolver for the CLI and the serve registry: a graph name
    // means the same dataset in `jgraph run` and in a daemon query
    jgraph::graph::catalog::load_spec(spec, seed)
}

const USAGE: &str =
    "usage: jgraph <run|serve|translate|lint|partition|calibrate|report|gen|sweep|info> [--help]
  run       --algo A [--graph G] [--translator T] [--pipelines N] [--pes N]
            [--root V] [--param name=value]... [--reorder S] [--trace out.csv]
            [--no-xla] [--verbose]
  serve     [--addr HOST:PORT] [--batch-window-us N] [--max-resident N]
            [--tenant-cap-default N] [--tenant-cap tenant=N]...
            [--register name=spec]... [--sweep-workers N] [--seed S] [--no-xla]
            [--retry-limit N] [--retry-budget N] [--read-timeout-ms N]
            [--idle-timeout-s N] [--fault-plan PLAN]
            (always-on query daemon, line-delimited JSON; see docs/serving.md.
            PLAN is a deterministic fault-injection plan, e.g.
            \"seed=7;panic@exec%101;transfer_error@commit#9\"; the
            JGRAPH_FAULT_PLAN env var is the fallback when the flag is absent)
  translate --algo A [--translator T] [--pipelines N] [--pes N] [--emit M]
  lint      [--algo A] [--emit text|json]   (all library algorithms by default;
            exits nonzero on any deny-level JG*** diagnostic)
  partition [--graph G] [--parts K] [--seed S] [--emit text|json]
            (per-strategy split quality: edge imbalance, cut fraction, sizes)
  calibrate [--graph G] [--seed S] [--iters N] [--tolerance T] [--root V]
            [--emit text|json]  (sweep the push/pull crossover alphas and the
            auto-shard count on the actual graph; prints fitted constants)
  report    [--table N] [--fig N] [--interfaces] [--full]
  gen       --out PATH [--preset P] [--seed S]
  sweep     --algo A [--graph G] [--reorders]
  info";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    if rest.iter().any(|a| a == "--help") || cmd == "--help" || cmd == "help" {
        println!("{USAGE}");
        return Ok(());
    }
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        "translate" => cmd_translate(rest),
        "lint" => cmd_lint(rest),
        "partition" => cmd_partition(rest),
        "calibrate" => cmd_calibrate(rest),
        "report" => cmd_report(rest),
        "gen" => cmd_gen(rest),
        "sweep" => cmd_sweep(rest),
        "info" => cmd_info(),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Design-space what-if: sweep pipelines x PEs (and optionally reorder
/// strategies) for one algorithm/graph, printing simulated MTEPS,
/// resources, and fit — the interactive exploration the light-weight
/// translator makes possible (seconds, not synthesis runs).
fn cmd_sweep(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["reorders"])?;
    let program = program_of(&args.get_or("algo", "bfs"))?;
    let (name, el) = load_graph(&args.get_or("graph", "email"), args.get_num("seed", 42u64)?)?;
    let device = jgraph::accel::device::DeviceModel::u200();
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    println!(
        "design-space sweep: {} on {name} ({}v/{}e)",
        program.name,
        el.num_vertices,
        el.num_edges()
    );
    println!(
        "{:>9} {:>4} | {:>10} | {:>9} | {:>6} | {:>5}",
        "pipelines", "pes", "MTEPS", "kLUT", "LUT%", "fits"
    );
    for pipes in [1u32, 2, 4, 8, 16, 32] {
        for pes in [1u32, 2, 4] {
            let translator = Translator::jgraph().with_plan(ParallelismPlan::new(pipes, pes));
            // compile-once per design point; the graph loads once per point
            // (one sweep = many compiles, one graph)
            let (design, mteps, fits) = match session.compile_with(translator, &program) {
                Ok(compiled) => {
                    let mut bound = compiled.load(&el, PrepOptions::named(name.clone()))?;
                    let r = bound.run(&RunOptions::default())?;
                    (compiled.design().clone(), r.simulated_mteps, true)
                }
                Err(CompileError::DoesNotFit { .. }) => {
                    (translator.translate(&program)?, 0.0, false)
                }
                Err(e) => return Err(e.into()),
            };
            println!(
                "{:>9} {:>4} | {:>10.1} | {:>9} | {:>5.1}% | {:>5}",
                pipes,
                pes,
                mteps,
                design.resources.lut / 1000,
                100.0 * design.resources.utilization(&device)[0],
                fits
            );
        }
    }
    if args.flag("reorders") {
        println!("\nreorder sweep (8x1):");
        let compiled = session.compile(&program)?;
        for &s in jgraph::prep::reorder::all_strategies() {
            let mut bound =
                compiled.load(&el, PrepOptions::named(name.clone()).with_reorder(s))?;
            let r = bound.run(&RunOptions::default())?;
            println!("  {:>14?} | {:>10.1} MTEPS", s, r.simulated_mteps);
        }
    }
    Ok(())
}

/// `jgraph serve`: the always-on query daemon. Every catalog preset is
/// registered up front (deterministic under `--seed`), plus any
/// `--register name=spec` pairs; queries arrive as line-delimited JSON
/// (see `docs/serving.md`) and coalesce into parallel sweeps. Drains
/// gracefully on SIGTERM/SIGINT or the wire `shutdown` op.
///
/// Fault tolerance (ISSUE 10): `--retry-limit` / `--retry-budget` bound
/// the transient-failure retry machinery, `--read-timeout-ms` /
/// `--idle-timeout-s` reap dead client connections, and `--fault-plan`
/// (falling back to the `JGRAPH_FAULT_PLAN` env var) arms the
/// deterministic fault-injection harness for chaos drills.
fn cmd_serve(argv: &[String]) -> Result<()> {
    use jgraph::sched::FaultPlan;
    use jgraph::serve::{self, ServeConfig, ServeRegistry, Server};
    let args = Args::parse(argv, &["no-xla"])?;
    let seed = args.get_num("seed", 42u64)?;
    let session = Session::new(SessionConfig {
        use_xla: !args.flag("no-xla"),
        ..Default::default()
    });
    let registry =
        std::sync::Arc::new(ServeRegistry::new(session, args.get_num("max-resident", 8usize)?));
    for preset in jgraph::graph::catalog::PRESETS {
        registry.register_spec(*preset, *preset, seed);
    }
    for spec in args.get_all("register") {
        let (name, graph) = spec
            .split_once('=')
            .with_context(|| format!("--register {spec:?}: expected name=spec"))?;
        registry.register_spec(name, graph, seed);
    }
    let mut tenant_caps = Vec::new();
    for spec in args.get_all("tenant-cap") {
        let (tenant, cap) = spec
            .split_once('=')
            .with_context(|| format!("--tenant-cap {spec:?}: expected tenant=cap"))?;
        let cap: usize =
            cap.parse().map_err(|e| anyhow::anyhow!("--tenant-cap {spec:?}: {e}"))?;
        tenant_caps.push((tenant.to_string(), cap));
    }
    // --fault-plan wins; otherwise JGRAPH_FAULT_PLAN arms the harness.
    let fault_plan = match args.get("fault-plan") {
        Some(spec) => Some(std::sync::Arc::new(
            FaultPlan::parse(spec).with_context(|| format!("--fault-plan {spec:?}"))?,
        )),
        None => FaultPlan::from_env()?,
    };
    let config = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7411"),
        batch_window: std::time::Duration::from_micros(args.get_num("batch-window-us", 2_000u64)?),
        default_tenant_cap: args.get_num("tenant-cap-default", 64usize)?,
        tenant_caps,
        sweep_workers: args.get_num("sweep-workers", jgraph::sched::available_workers())?,
        read_timeout: std::time::Duration::from_millis(args.get_num("read-timeout-ms", 250u64)?),
        idle_timeout: std::time::Duration::from_secs(args.get_num("idle-timeout-s", 300u64)?),
        retry_limit: args.get_num("retry-limit", 2u32)?,
        retry_budget_per_tenant: args.get_num("retry-budget", 256u64)?,
        fault_plan: fault_plan.clone(),
    };
    let server = Server::start(config, registry.clone())?;
    println!(
        "jgraph serve: listening on {} ({} graphs registered, {} resident max)",
        server.local_addr(),
        registry.graph_names().len(),
        registry.max_resident(),
    );
    if let Some(plan) = &fault_plan {
        println!(
            "jgraph serve: fault-injection plan armed: {} (seed {})",
            plan.source(),
            plan.seed()
        );
    }
    serve::install_termination_handler();
    while !server.is_shutting_down() && !serve::termination_requested() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("jgraph serve: draining");
    server.join()?;
    println!("jgraph serve: drained, exiting");
    Ok(())
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["no-xla", "verbose"])?;
    let program = program_of(&args.get_or("algo", "bfs"))?;
    let (name, el) = load_graph(&args.get_or("graph", "email"), args.get_num("seed", 42u64)?)?;
    let plan = ParallelismPlan::new(args.get_num("pipelines", 8)?, args.get_num("pes", 1)?);
    let translator = Translator::of_kind(translator_of(&args.get_or("translator", "jgraph"))?)
        .with_plan(plan);
    let reorder = match args.get("reorder") {
        None => None,
        Some(s) => Some(s.parse::<ReorderStrategy>()?),
    };
    let session = Session::new(SessionConfig {
        translator,
        use_xla: !args.flag("no-xla"),
        ..Default::default()
    });
    let params = param_set_for(&args, &program)?;
    let compiled = session.compile(&program)?;
    let mut prep = PrepOptions::named(name);
    prep.reorder = reorder;
    let mut bound = compiled.load(&el, prep)?;
    let report = bound.run(&RunOptions {
        root: args.get_num("root", 0)?,
        params,
        trace_path: args.get("trace").map(std::path::PathBuf::from),
        ..Default::default()
    })?;
    println!("{}", report.summary());
    if !report.bound_params.is_empty() {
        let rendered: Vec<String> =
            report.bound_params.iter().map(|(n, v)| format!("{n}={v}")).collect();
        println!("params: {}", rendered.join(", "));
    }
    if args.flag("verbose") {
        println!(
            "cycles: compute={} conflict={} row_start={} vertex_random={} \
             stream={} fill_drain={} | launches {:.1}us | path {:?}",
            report.sim.cycles.compute,
            report.sim.cycles.conflict,
            report.sim.cycles.row_start,
            report.sim.cycles.vertex_random,
            report.sim.cycles.stream,
            report.sim.cycles.fill_drain,
            report.sim.launch_seconds * 1e6,
            report.functional_path,
        );
    }
    Ok(())
}

/// `jgraph lint`: run the static analyzer's lint pass over one algorithm
/// (`--algo`) or the whole library, print diagnostics as text or JSON
/// (`--emit json`), and exit nonzero if any deny-level diagnostic fired —
/// the CI gate shape (see `.github/workflows/ci.yml`).
fn cmd_lint(argv: &[String]) -> Result<()> {
    use jgraph::analysis::lint::{diagnostics_json, lint};
    use jgraph::analysis::LintLevel;
    let args = Args::parse(argv, &[])?;
    let programs: Vec<GasProgram> = match args.get("algo") {
        Some(name) => vec![program_of(name)?],
        None => algorithms::all(),
    };
    let emit = args.get_or("emit", "text");
    let mut denies = 0usize;
    let mut warns = 0usize;
    let mut json_blocks = Vec::new();
    for p in &programs {
        let diags = lint(p);
        denies += diags.iter().filter(|d| d.level == LintLevel::Deny).count();
        warns += diags.iter().filter(|d| d.level == LintLevel::Warn).count();
        match emit.as_str() {
            "json" => json_blocks.push(diagnostics_json(&p.name, &diags)),
            "text" => {
                if diags.is_empty() {
                    println!("{}: clean", p.name);
                } else {
                    println!("{}:", p.name);
                    for d in &diags {
                        let level = match d.level {
                            LintLevel::Deny => "deny",
                            LintLevel::Warn => "warn",
                        };
                        println!("  {level} {}: {} ({})", d.code.code(), d.message, d.interface);
                    }
                }
            }
            other => bail!("unknown emit mode {other:?} (text|json)"),
        }
    }
    if emit == "json" {
        println!("[{}]", json_blocks.join(","));
    } else {
        println!(
            "{} program(s): {denies} deny, {warns} warn (warns suppressible via \
             GasProgramBuilder::allow; see the lint catalog in the crate docs)",
            programs.len()
        );
    }
    if denies > 0 {
        bail!("lint: {denies} deny-level diagnostic(s)");
    }
    Ok(())
}

/// `jgraph partition`: split one graph with every strategy and print the
/// quality statistics sharded execution cares about — edge imbalance
/// (max/mean part edges: the slowest shard bounds every superstep), cut
/// fraction (boundary-exchange volume), and part sizes. Text or JSON.
fn cmd_partition(argv: &[String]) -> Result<()> {
    use jgraph::prep::partition::{partition, PartitionStrategy};
    let args = Args::parse(argv, &[])?;
    let (name, el) = load_graph(&args.get_or("graph", "email"), args.get_num("seed", 42u64)?)?;
    let parts: usize = args.get_num("parts", 4)?;
    let emit = args.get_or("emit", "text");
    let strategies = [
        PartitionStrategy::Range,
        PartitionStrategy::Hash,
        PartitionStrategy::DegreeBalanced,
        PartitionStrategy::BfsGrow,
    ];
    let total_edges = el.num_edges();
    if emit == "text" {
        println!(
            "partition quality: {name} ({}v/{}e) into {parts} parts",
            el.num_vertices, total_edges
        );
        println!(
            "{:>15} | {:>13} | {:>12} | part sizes (vertices)",
            "strategy", "edge imbal.", "cut fraction"
        );
    }
    let mut json_blocks = Vec::new();
    for strategy in strategies {
        let p = partition(&el, parts, strategy)?;
        match emit.as_str() {
            "text" => {
                let sizes: Vec<String> =
                    p.part_sizes.iter().map(|s| s.to_string()).collect();
                println!(
                    "{:>15} | {:>13.3} | {:>12.4} | [{}]",
                    format!("{strategy:?}"),
                    p.edge_imbalance(),
                    p.cut_fraction(total_edges),
                    sizes.join(", ")
                );
            }
            "json" => {
                let sizes: Vec<String> =
                    p.part_sizes.iter().map(|s| s.to_string()).collect();
                let edges: Vec<String> =
                    p.part_edges.iter().map(|e| e.to_string()).collect();
                json_blocks.push(format!(
                    "{{\"strategy\":\"{strategy:?}\",\"parts\":{parts},\
                     \"edge_imbalance\":{},\"cut_fraction\":{},\"cut_edges\":{},\
                     \"part_sizes\":[{}],\"part_edges\":[{}]}}",
                    p.edge_imbalance(),
                    p.cut_fraction(total_edges),
                    p.cut_edges,
                    sizes.join(","),
                    edges.join(",")
                ));
            }
            other => bail!("unknown emit mode {other:?} (text|json)"),
        }
    }
    if emit == "json" {
        println!(
            "{{\"graph\":\"{name}\",\"num_vertices\":{},\"num_edges\":{total_edges},\
             \"strategies\":[{}]}}",
            el.num_vertices,
            json_blocks.join(",")
        );
    }
    Ok(())
}

/// `jgraph calibrate`: measure the push↔pull crossover alphas and the
/// auto-shard count on one graph and print every candidate's timing plus
/// the fitted argmin — the constants
/// [`jgraph::prep::PreparedGraph::set_calibration`] applies so queries
/// run measured numbers instead of hand-set defaults.
fn cmd_calibrate(argv: &[String]) -> Result<()> {
    use jgraph::prep::prepared::PreparedGraph;
    use jgraph::prep::{calibrate, CalibrateOptions};
    let args = Args::parse(argv, &[])?;
    let (name, el) = load_graph(&args.get_or("graph", "email"), args.get_num("seed", 42u64)?)?;
    let prepared = PreparedGraph::prepare(&el, &PrepOptions::named(name))?;
    let root = match args.get("root") {
        None => None,
        Some(s) => Some(
            s.parse::<jgraph::graph::VertexId>()
                .map_err(|e| anyhow::anyhow!("--root: {e}"))?,
        ),
    };
    let opts = CalibrateOptions {
        iters: args.get_num("iters", 3usize)?.max(1),
        root,
        tolerance: args.get_num("tolerance", 1e-3f64)?,
    };
    let report = calibrate(&prepared, &opts)?;
    match args.get_or("emit", "text").as_str() {
        "json" => print!("{}", report.to_json()),
        "text" => {
            println!(
                "calibration: {} ({}v/{}e), best of {} run(s) per candidate",
                report.graph, report.vertices, report.edges, opts.iters
            );
            println!("  alpha_early_exit sweep (adaptive BFS):");
            for (a, t) in &report.early_exit_sweep {
                let mark =
                    if *a == report.fitted.pull_alpha_early_exit { "  <- fitted" } else { "" };
                println!("    {a:>5} | {t:>9.6}s{mark}");
            }
            println!("  alpha_full_scan sweep (adaptive WCC):");
            for (a, t) in &report.full_scan_sweep {
                let mark =
                    if *a == report.fitted.pull_alpha_full_scan { "  <- fitted" } else { "" };
                println!("    {a:>5} | {t:>9.6}s{mark}");
            }
            println!("  auto-shard sweep (PageRank to fixpoint):");
            for (k, t) in &report.shard_sweep {
                let mark = if Some(*k) == report.fitted.auto_shards { "  <- fitted" } else { "" };
                println!("    {k:>5} | {t:>9.6}s{mark}");
            }
            println!(
                "fitted: pull_alpha_early_exit={} pull_alpha_full_scan={} auto_shards={}",
                report.fitted.pull_alpha_early_exit,
                report.fitted.pull_alpha_full_scan,
                match report.fitted.auto_shards {
                    Some(k) => k.to_string(),
                    None => "auto".into(),
                },
            );
        }
        other => bail!("unknown emit mode {other:?} (text|json)"),
    }
    Ok(())
}

fn cmd_translate(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let program = program_of(&args.get_or("algo", "bfs"))?;
    let plan = ParallelismPlan::new(args.get_num("pipelines", 8)?, args.get_num("pes", 1)?);
    let design = Translator::of_kind(translator_of(&args.get_or("translator", "jgraph"))?)
        .with_plan(plan)
        .translate(&program)?;
    match args.get_or("emit", "both").as_str() {
        "hdl" => print!("{}", design.hdl),
        "chisel" => match &design.chisel {
            Some(c) => print!("{c}"),
            None => bail!("only the jgraph flow has a Chisel intermediate"),
        },
        "host" => print!("{}", design.host_c),
        "isa" => print!("{}", jgraph::dsl::isa::compile(&program).listing()),
        "library" => print!(
            "{}",
            jgraph::translator::modlib::emit_library(&design.module_graph)
        ),
        "both" => print!("{}\n{}", design.hdl, design.host_c),
        "stats" => println!(
            "{} via {:?}: {} HDL lines, {} host lines, {} modules, \
             LUT {} FF {} BRAM {}kb URAM {} DSP {}, translate {:.3}ms, \
             modeled synthesis {:.1}s",
            design.program_name,
            design.kind,
            design.hdl_lines,
            design.host_lines,
            design.module_graph.instances.len(),
            design.resources.lut,
            design.resources.ff,
            design.resources.bram_kb,
            design.resources.uram,
            design.resources.dsp,
            design.translate_seconds * 1e3,
            design.synthesis_seconds,
        ),
        other => bail!("unknown emit mode {other:?}"),
    }
    if args.get_or("emit", "both") == "stats" {
        // what the analyzer proved, and what hardware that saved
        let facts = jgraph::analysis::analyze(&program);
        println!("  reduce algebra : {}", facts.reduce.describe());
        println!("  convergence    : {}", facts.convergence.describe());
        println!("  parallel safety: {} certificate", facts.parallel_safety.describe());
        println!("  pull early-exit: {}", facts.pull_early_exit);
        println!(
            "  conflict unit  : {}",
            if facts.needs_conflict_unit() {
                "kept (non-idempotent reduce)".to_string()
            } else {
                let c = jgraph::translator::modules::cost(jgraph::dsl::ops::HwModule::ConflictUnit);
                format!(
                    "elided — reduce proven idempotent (saves {} LUT / {} FF per lane)",
                    c.lut, c.ff
                )
            }
        );
        println!(
            "  arg registers  : {} datapath-live of {} declared (host-loop: {})",
            facts.datapath_params.len(),
            program.params.len(),
            if facts.host_params.is_empty() { "none".into() } else { facts.host_params.join(", ") },
        );
        for spec in program.params.iter() {
            println!(
                "  param {:<12} default {:?} range [{}, {}] {}",
                spec.name,
                spec.default,
                spec.min.unwrap_or(f64::NEG_INFINITY),
                spec.max.unwrap_or(f64::INFINITY),
                spec.doc
            );
        }
    }
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["interfaces", "full"])?;
    let mut did_something = false;
    if args.flag("interfaces") {
        did_something = true;
        println!("JGraph DSL interfaces (Figure 3):");
        for i in jgraph::dsl::ops::INTERFACES {
            println!(
                "  [{:?}/{:?}] {}{} -> {:?}: {}",
                i.level, i.category, i.name, i.params, i.module, i.doc
            );
        }
        println!("total: {}", jgraph::dsl::registry::interface_count());
    }
    if let Some(t) = args.get("table") {
        did_something = true;
        match t {
            "1" => println!("{}", jgraph::report::table1()),
            "2" => println!("{}", jgraph::report::table2()),
            "3" => println!("{}", jgraph::report::table3()),
            "4" => println!("{}", jgraph::report::table4()),
            "5" => {
                let (t, _) = jgraph::report::table5(false, !args.flag("full"))?;
                println!("{t}");
            }
            n => bail!("no table {n}"),
        }
    }
    if let Some(f) = args.get("fig") {
        did_something = true;
        match f {
            "1" => println!("{}", jgraph::report::fig1_environments()),
            "5" => {
                let (f, _) = jgraph::report::fig5_devcost()?;
                println!("{f}");
            }
            n => bail!("no figure {n}"),
        }
    }
    if !did_something {
        bail!("pass --table N, --fig N, or --interfaces");
    }
    Ok(())
}

fn cmd_gen(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let out = args.get("out").context("--out is required")?.to_string();
    let (name, el) = load_graph(&args.get_or("preset", "email"), args.get_num("seed", 42u64)?)?;
    if out.ends_with(".bin") {
        io::write_binary(&el, &out)?;
    } else if out.ends_with(".db") {
        jgraph::graph::store::GraphStore::from_edgelist(&el, "Vertex", "EDGE").save(&out)?;
    } else {
        io::write_snap_text(&el, &out)?;
    }
    println!("wrote {name}: {} vertices, {} edges -> {out}", el.num_vertices, el.num_edges());
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dev = jgraph::accel::device::DeviceModel::u200();
    println!(
        "device model: {} ({}k LUT, {}k FF, {} DSP, {} URAM, {} GB DDR4, {:.0} MHz)",
        dev.name,
        dev.luts / 1000,
        dev.registers / 1000,
        dev.dsps,
        dev.urams,
        dev.dram_bytes >> 30,
        dev.clock_hz / 1e6
    );
    match jgraph::runtime::KernelRegistry::open_default() {
        Ok(reg) => {
            println!("PJRT platform: {}", reg.platform());
            println!("artifacts ({}):", reg.manifest.artifacts.len());
            for a in &reg.manifest.artifacts {
                println!(
                    "  {:5} {:7} N={:>7} M={:>9} pallas={} {}",
                    a.algo, a.bucket, a.n, a.m, a.use_pallas, a.file
                );
            }
        }
        Err(e) => println!("artifact registry unavailable: {e:#}"),
    }
    Ok(())
}
