//! Synthetic graph generators — the substitute for the paper's SNAP
//! datasets (DESIGN.md §2): R-MAT reproduces the power-law degree skew the
//! paper's locality discussion relies on; presets match the vertex/edge
//! counts of the two evaluation graphs.

use super::edgelist::EdgeList;
use super::{SplitMix64, VertexId};

/// R-MAT (recursive matrix) generator, the Graph500 standard power-law
/// model. `scale` fixes `n = 2^scale` vertices; `num_edges` directed edges
/// are drawn with quadrant probabilities `(a, b, c, d)`, `a+b+c+d = 1`.
pub fn rmat(scale: u32, num_edges: usize, a: f64, b: f64, c: f64, seed: u64) -> EdgeList {
    assert!(scale <= 31, "scale too large for u32 vertex ids");
    let d = 1.0 - a - b - c;
    assert!(d >= 0.0, "quadrant probabilities must sum to <= 1");
    let n = 1usize << scale;
    let mut rng = SplitMix64::new(seed);
    let mut el = EdgeList::with_vertices(n);
    el.edges.reserve(num_edges);
    for _ in 0..num_edges {
        let (mut lo_s, mut hi_s) = (0usize, n);
        let (mut lo_d, mut hi_d) = (0usize, n);
        while hi_s - lo_s > 1 {
            let r = rng.next_f64();
            let mid_s = (lo_s + hi_s) / 2;
            let mid_d = (lo_d + hi_d) / 2;
            if r < a {
                hi_s = mid_s;
                hi_d = mid_d;
            } else if r < a + b {
                hi_s = mid_s;
                lo_d = mid_d;
            } else if r < a + b + c {
                lo_s = mid_s;
                hi_d = mid_d;
            } else {
                lo_s = mid_s;
                lo_d = mid_d;
            }
        }
        let w = rng.next_f32_range(0.5, 10.0);
        el.push(lo_s as VertexId, lo_d as VertexId, w);
    }
    el.num_vertices = n;
    el
}

/// Erdős–Rényi G(n, m): `m` uniformly random directed edges.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> EdgeList {
    assert!(n > 0);
    let mut rng = SplitMix64::new(seed);
    let mut el = EdgeList::with_vertices(n);
    el.edges.reserve(m);
    for _ in 0..m {
        let s = rng.next_below(n as u64) as VertexId;
        let d = rng.next_below(n as u64) as VertexId;
        let w = rng.next_f32_range(0.5, 10.0);
        el.push(s, d, w);
    }
    el.num_vertices = n;
    el
}

/// 2-D grid (road-network-like): vertex `(x, y)` connects right and down,
/// symmetrized — low degree, high diameter, the opposite locality regime
/// from R-MAT. Good for SSSP examples.
pub fn grid2d(width: usize, height: usize, seed: u64) -> EdgeList {
    let mut rng = SplitMix64::new(seed);
    let mut el = EdgeList::with_vertices(width * height);
    let id = |x: usize, y: usize| (y * width + x) as VertexId;
    for y in 0..height {
        for x in 0..width {
            if x + 1 < width {
                let w = rng.next_f32_range(1.0, 5.0);
                el.push(id(x, y), id(x + 1, y), w);
                el.push(id(x + 1, y), id(x, y), w);
            }
            if y + 1 < height {
                let w = rng.next_f32_range(1.0, 5.0);
                el.push(id(x, y), id(x, y + 1), w);
                el.push(id(x, y + 1), id(x, y), w);
            }
        }
    }
    el
}

/// Star: hub 0 connected to all others (both directions). Degenerate
/// skew case for scheduler/simulator tests.
pub fn star(n: usize) -> EdgeList {
    let mut el = EdgeList::with_vertices(n);
    for v in 1..n as VertexId {
        el.push(0, v, 1.0);
        el.push(v, 0, 1.0);
    }
    el
}

/// Directed chain 0→1→…→n-1. Maximum-diameter case: BFS needs n-1
/// supersteps; exercises iteration-bound paths.
pub fn chain(n: usize) -> EdgeList {
    let mut el = EdgeList::with_vertices(n);
    for v in 0..(n as VertexId).saturating_sub(1) {
        el.push(v, v + 1, 1.0);
    }
    el
}

/// Preset matching **email-Eu-core** (SNAP): 1,005 vertices / 25,571
/// directed edges, dense power-law core. Used by Table V "small".
pub fn email_eu_core_like(seed: u64) -> EdgeList {
    // scale 10 = 1,024 >= 1,005; R-MAT with Graph500 skew, then clamp the
    // vertex universe to exactly 1,005 ids by folding overflowing ids.
    let mut el = rmat(10, 25_571, 0.57, 0.19, 0.19, seed);
    clamp_vertices(&mut el, 1_005);
    el
}

/// Preset matching **soc-Slashdot0922** (SNAP): 82,168 vertices / 948,464
/// directed edges. Used by Table V "large".
pub fn soc_slashdot_like(seed: u64) -> EdgeList {
    let mut el = rmat(17, 948_464, 0.57, 0.19, 0.19, seed);
    clamp_vertices(&mut el, 82_168);
    el
}

/// Fold vertex ids into `[0, n)` and fix up the vertex count. Preserves the
/// degree skew while matching the target universe exactly.
fn clamp_vertices(el: &mut EdgeList, n: usize) {
    for e in &mut el.edges {
        e.src %= n as VertexId;
        e.dst %= n as VertexId;
    }
    el.num_vertices = n;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::properties;

    #[test]
    fn rmat_shape_and_determinism() {
        let a = rmat(8, 1000, 0.57, 0.19, 0.19, 3);
        let b = rmat(8, 1000, 0.57, 0.19, 0.19, 3);
        assert_eq!(a.num_vertices, 256);
        assert_eq!(a.num_edges(), 1000);
        assert!(a.is_valid());
        assert_eq!(a.sorted().edges.len(), b.sorted().edges.len());
        assert_eq!(
            a.edges.iter().map(|e| (e.src, e.dst)).collect::<Vec<_>>(),
            b.edges.iter().map(|e| (e.src, e.dst)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rmat_is_skewed_er_is_not() {
        let rm = rmat(10, 20_000, 0.57, 0.19, 0.19, 1);
        let er = erdos_renyi(1024, 20_000, 1);
        let max_rm = *rm.out_degrees().iter().max().unwrap();
        let max_er = *er.out_degrees().iter().max().unwrap();
        // power-law hub should dominate the ER max degree comfortably
        assert!(
            max_rm > 2 * max_er,
            "expected R-MAT hubs ({max_rm}) >> ER max degree ({max_er})"
        );
    }

    #[test]
    fn grid_degrees_bounded() {
        let g = grid2d(10, 7, 0);
        assert_eq!(g.num_vertices, 70);
        assert!(g.out_degrees().iter().all(|&d| d <= 4));
        assert!(g.is_valid());
    }

    #[test]
    fn star_and_chain_shapes() {
        let s = star(5);
        assert_eq!(s.num_edges(), 8);
        assert_eq!(s.out_degrees()[0], 4);
        let c = chain(5);
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.out_degrees(), vec![1, 1, 1, 1, 0]);
    }

    #[test]
    fn presets_match_paper_sizes() {
        let e = email_eu_core_like(1);
        assert_eq!(e.num_vertices, 1_005);
        assert_eq!(e.num_edges(), 25_571);
        assert!(e.is_valid());
        // slashdot preset is big; just validate the arithmetic on a sample
        let s = soc_slashdot_like(1);
        assert_eq!(s.num_vertices, 82_168);
        assert_eq!(s.num_edges(), 948_464);
    }

    #[test]
    fn presets_are_power_law() {
        let e = email_eu_core_like(1);
        let stats = properties::GraphStats::compute(&e);
        assert!(stats.max_out_degree as f64 > 10.0 * stats.avg_degree);
    }
}
