//! Graph data substrate: the arrays the paper's DSL exposes.
//!
//! The paper (§IV-A) represents a graph with three arrays — `Vertices`,
//! `Edge_offset`, `Edges` — i.e. CSR. This module provides that
//! representation ([`csr::Csr`]), the raw edge-list form it is built from
//! ([`edgelist::EdgeList`]), synthetic generators standing in for the SNAP
//! datasets ([`generate`]), file I/O (the DSL's *FIFO* preprocessing stage,
//! [`io`]), and structural statistics ([`properties`]).

pub mod catalog;
pub mod csr;
pub mod edgelist;
pub mod generate;
pub mod io;
pub mod properties;
pub mod store;

/// Vertex identifier. u32 everywhere: the paper's graphs are well under
/// 2^32 vertices and the FPGA datapath is 32-bit.
pub type VertexId = u32;

/// Edge identifier (index into the CSR `Edges` array).
pub type EdgeId = u32;

/// Default edge weight for unweighted inputs (BFS treats weights as 1).
pub const DEFAULT_WEIGHT: f32 = 1.0;

/// Deterministic 64-bit PRNG (splitmix64). Used by generators, partitioners
/// and tests; no external crate so results are reproducible byte-for-byte
/// across platforms.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // bias is < 2^-32 for our bounds.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn next_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_f64() as f32) * (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_bounds_respected() {
        let mut r = SplitMix64::new(1);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            let x = r.next_f32_range(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn splitmix_different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
