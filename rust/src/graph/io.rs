//! File I/O — the DSL's **FIFO** preprocessing stage (paper §IV-C1):
//! "reading input files, writing data to output files". Supports the SNAP
//! text format the paper's datasets ship in, plus a compact binary format
//! for repeated runs.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::edgelist::EdgeList;
use super::DEFAULT_WEIGHT;

/// Read a SNAP-style edge-list text file: `#`-comment lines, then
/// whitespace-separated `src dst [weight]` per line. Vertex ids may be
/// sparse; they are kept as-is (the universe is `max_id + 1`).
pub fn read_snap_text(path: impl AsRef<Path>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening graph file {:?}", path.as_ref()))?;
    parse_snap_text(BufReader::new(f))
}

/// Parse SNAP text from any reader (unit-testable without touching disk).
pub fn parse_snap_text(r: impl BufRead) -> Result<EdgeList> {
    let mut el = EdgeList::default();
    for (lineno, line) in r.lines().enumerate() {
        let line = line.context("reading graph line")?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let src: u32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing src", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad src", lineno + 1))?;
        let dst: u32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing dst", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let w: f32 = match it.next() {
            Some(s) => s.parse().with_context(|| format!("line {}: bad weight", lineno + 1))?,
            None => DEFAULT_WEIGHT,
        };
        el.push(src, dst, w);
    }
    Ok(el)
}

/// Write SNAP-style text (with weights).
pub fn write_snap_text(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# jgraph edge list: {} vertices, {} edges", el.num_vertices, el.num_edges())?;
    for e in &el.edges {
        writeln!(w, "{}\t{}\t{}", e.src, e.dst, e.weight)?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"JGRAPH01";

/// Write the compact binary format: magic, counts, then (src, dst, weight)
/// triples little-endian.
pub fn write_binary(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(el.num_vertices as u64).to_le_bytes())?;
    w.write_all(&(el.num_edges() as u64).to_le_bytes())?;
    for e in &el.edges {
        w.write_all(&e.src.to_le_bytes())?;
        w.write_all(&e.dst.to_le_bytes())?;
        w.write_all(&e.weight.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary format written by [`write_binary`]. Validates magic,
/// counts, and endpoint bounds (corrupt files fail loudly — exercised by
/// the failure-injection tests).
pub fn read_binary(path: impl AsRef<Path>) -> Result<EdgeList> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("truncated header")?;
    if &magic != BIN_MAGIC {
        bail!("bad magic: not a jgraph binary graph file");
    }
    let mut buf8 = [0u8; 8];
    f.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    f.read_exact(&mut buf8)?;
    let m = u64::from_le_bytes(buf8) as usize;
    let mut el = EdgeList::with_vertices(n);
    let mut rec = [0u8; 12];
    for i in 0..m {
        f.read_exact(&mut rec).with_context(|| format!("truncated at edge {i}"))?;
        let src = u32::from_le_bytes(rec[0..4].try_into().unwrap());
        let dst = u32::from_le_bytes(rec[4..8].try_into().unwrap());
        let w = f32::from_le_bytes(rec[8..12].try_into().unwrap());
        if src as usize >= n || dst as usize >= n {
            bail!("edge {i} endpoint out of range ({src}, {dst}) for n={n}");
        }
        el.edges.push(super::edgelist::Edge { src, dst, weight: w });
    }
    Ok(el)
}

/// Load a graph by extension: `.txt`/`.el` → SNAP text, `.bin` → binary.
pub fn load(path: impl AsRef<Path>) -> Result<EdgeList> {
    let p = path.as_ref();
    match p.extension().and_then(|e| e.to_str()) {
        Some("bin") => read_binary(p),
        _ => read_snap_text(p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn parse_snap_with_comments_and_weights() {
        let text = "# comment\n% other comment\n0 1\n1 2 3.5\n\n2 0 1.0\n";
        let el = parse_snap_text(std::io::Cursor::new(text)).unwrap();
        assert_eq!(el.num_vertices, 3);
        assert_eq!(el.num_edges(), 3);
        assert_eq!(el.edges[0].weight, DEFAULT_WEIGHT);
        assert_eq!(el.edges[1].weight, 3.5);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_snap_text(std::io::Cursor::new("0 x\n")).is_err());
        assert!(parse_snap_text(std::io::Cursor::new("7\n")).is_err());
    }

    #[test]
    fn text_roundtrip() {
        let g = generate::erdos_renyi(50, 200, 9);
        let dir = std::env::temp_dir().join("jgraph_io_text");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        write_snap_text(&g, &p).unwrap();
        let rt = read_snap_text(&p).unwrap();
        assert_eq!(rt.num_edges(), g.num_edges());
        assert_eq!(rt.sorted().edges[0].src, g.sorted().edges[0].src);
    }

    #[test]
    fn binary_roundtrip_exact() {
        let g = generate::rmat(7, 500, 0.57, 0.19, 0.19, 2);
        let dir = std::env::temp_dir().join("jgraph_io_bin");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        write_binary(&g, &p).unwrap();
        let rt = read_binary(&p).unwrap();
        assert_eq!(rt.num_vertices, g.num_vertices);
        assert_eq!(rt.num_edges(), g.num_edges());
        for (a, b) in rt.edges.iter().zip(&g.edges) {
            assert_eq!((a.src, a.dst), (b.src, b.dst));
            assert_eq!(a.weight, b.weight);
        }
    }

    #[test]
    fn binary_rejects_bad_magic_and_truncation() {
        let dir = std::env::temp_dir().join("jgraph_io_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(read_binary(&p).is_err());

        // valid header claiming 10 edges but providing none
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BIN_MAGIC);
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.extend_from_slice(&10u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_binary(&p).unwrap_err().to_string().contains("truncated"));
    }

    #[test]
    fn load_dispatches_on_extension() {
        let g = generate::chain(4);
        let dir = std::env::temp_dir().join("jgraph_io_disp");
        std::fs::create_dir_all(&dir).unwrap();
        let pt = dir.join("g.txt");
        let pb = dir.join("g.bin");
        write_snap_text(&g, &pt).unwrap();
        write_binary(&g, &pb).unwrap();
        assert_eq!(load(&pt).unwrap().num_edges(), 3);
        assert_eq!(load(&pb).unwrap().num_edges(), 3);
    }
}
