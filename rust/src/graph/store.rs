//! Embedded property-graph store — the Neo4j stand-in for the DSL's FIFO
//! stage (paper §IV-C1: "For graph data in graph database management
//! system such as Neo4j, we can read data from database directly").
//!
//! A deliberately small but real store: fixed-size node and relationship
//! records in the Neo4j style (each node heads linked lists of its out/in
//! relationships), string labels and relationship types interned in a
//! dictionary, numeric properties, binary persistence, and the two query
//! shapes graph preprocessing needs — label scans and neighborhood
//! expansion. `to_edgelist` is the FIFO bridge into the JGraph pipeline.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::edgelist::EdgeList;
use super::VertexId;

/// Sentinel "nil" pointer in record linked lists.
const NIL: u32 = u32::MAX;

/// A node record: label + head of its relationship chains (Neo4j's
/// `firstRel` pointers) + optional numeric property.
#[derive(Debug, Clone, PartialEq)]
struct NodeRecord {
    label: u32,
    first_out: u32,
    first_in: u32,
    prop: f32,
}

/// A relationship record: endpoints, type, weight property, and the
/// next-pointers of both endpoints' chains.
#[derive(Debug, Clone, PartialEq)]
struct RelRecord {
    src: u32,
    dst: u32,
    rel_type: u32,
    weight: f32,
    next_out: u32,
    next_in: u32,
}

/// The store.
#[derive(Debug, Default)]
pub struct GraphStore {
    nodes: Vec<NodeRecord>,
    rels: Vec<RelRecord>,
    /// Interned strings (labels and relationship types share the pool).
    dict: Vec<String>,
    dict_index: HashMap<String, u32>,
}

impl GraphStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn intern(&mut self, s: &str) -> u32 {
        if let Some(&id) = self.dict_index.get(s) {
            return id;
        }
        let id = self.dict.len() as u32;
        self.dict.push(s.to_string());
        self.dict_index.insert(s.to_string(), id);
        id
    }

    /// Create a node with a label and a numeric property; returns its id.
    pub fn create_node(&mut self, label: &str, prop: f32) -> VertexId {
        let label = self.intern(label);
        let id = self.nodes.len() as u32;
        self.nodes.push(NodeRecord { label, first_out: NIL, first_in: NIL, prop });
        id
    }

    /// Create a relationship `src -[rel_type {weight}]-> dst`.
    pub fn create_rel(
        &mut self,
        src: VertexId,
        dst: VertexId,
        rel_type: &str,
        weight: f32,
    ) -> Result<u32> {
        let n = self.nodes.len() as u32;
        if src >= n || dst >= n {
            bail!("relationship endpoint out of range ({src}, {dst}) for {n} nodes");
        }
        let rel_type = self.intern(rel_type);
        let id = self.rels.len() as u32;
        // push-front into both endpoint chains (Neo4j-style)
        let rec = RelRecord {
            src,
            dst,
            rel_type,
            weight,
            next_out: self.nodes[src as usize].first_out,
            next_in: self.nodes[dst as usize].first_in,
        };
        self.nodes[src as usize].first_out = id;
        self.nodes[dst as usize].first_in = id;
        self.rels.push(rec);
        Ok(id)
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn rel_count(&self) -> usize {
        self.rels.len()
    }

    pub fn node_label(&self, v: VertexId) -> &str {
        &self.dict[self.nodes[v as usize].label as usize]
    }

    pub fn node_prop(&self, v: VertexId) -> f32 {
        self.nodes[v as usize].prop
    }

    /// Label scan: all node ids with the given label.
    pub fn nodes_with_label(&self, label: &str) -> Vec<VertexId> {
        let Some(&id) = self.dict_index.get(label) else {
            return Vec::new();
        };
        (0..self.nodes.len() as u32).filter(|&v| self.nodes[v as usize].label == id).collect()
    }

    /// Out-neighborhood expansion (follows the out-chain): `(dst, type,
    /// weight)` triples, optionally filtered by relationship type.
    pub fn expand_out(&self, v: VertexId, rel_type: Option<&str>) -> Vec<(VertexId, &str, f32)> {
        let filter = rel_type.and_then(|t| self.dict_index.get(t).copied());
        let mut out = Vec::new();
        let mut cur = self.nodes[v as usize].first_out;
        while cur != NIL {
            let r = &self.rels[cur as usize];
            if filter.map(|f| f == r.rel_type).unwrap_or(true) {
                out.push((r.dst, self.dict[r.rel_type as usize].as_str(), r.weight));
            }
            cur = r.next_out;
        }
        out
    }

    /// In-neighborhood expansion (follows the in-chain).
    pub fn expand_in(&self, v: VertexId, rel_type: Option<&str>) -> Vec<(VertexId, &str, f32)> {
        let filter = rel_type.and_then(|t| self.dict_index.get(t).copied());
        let mut out = Vec::new();
        let mut cur = self.nodes[v as usize].first_in;
        while cur != NIL {
            let r = &self.rels[cur as usize];
            if filter.map(|f| f == r.rel_type).unwrap_or(true) {
                out.push((r.src, self.dict[r.rel_type as usize].as_str(), r.weight));
            }
            cur = r.next_in;
        }
        out
    }

    /// The FIFO bridge: project the store onto a weighted edge list,
    /// optionally restricted to one relationship type.
    pub fn to_edgelist(&self, rel_type: Option<&str>) -> EdgeList {
        let filter = rel_type.and_then(|t| self.dict_index.get(t).copied());
        let mut el = EdgeList::with_vertices(self.nodes.len());
        for r in &self.rels {
            if filter.map(|f| f == r.rel_type).unwrap_or(true) {
                el.push(r.src, r.dst, r.weight);
            }
        }
        el.num_vertices = self.nodes.len();
        el
    }

    /// Import an edge list as a store (every node labelled `label`, every
    /// relationship typed `rel_type`). Inverse-ish of [`Self::to_edgelist`].
    pub fn from_edgelist(el: &EdgeList, label: &str, rel_type: &str) -> Self {
        let mut s = Self::new();
        for _ in 0..el.num_vertices {
            s.create_node(label, 0.0);
        }
        for e in &el.edges {
            s.create_rel(e.src, e.dst, rel_type, e.weight).expect("valid edge list");
        }
        s
    }

    // --- persistence -----------------------------------------------------

    const MAGIC: &'static [u8; 8] = b"JGSTORE1";

    /// Serialize to the compact binary format.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        w.write_all(Self::MAGIC)?;
        w.write_all(&(self.dict.len() as u64).to_le_bytes())?;
        for s in &self.dict {
            let b = s.as_bytes();
            w.write_all(&(b.len() as u32).to_le_bytes())?;
            w.write_all(b)?;
        }
        w.write_all(&(self.nodes.len() as u64).to_le_bytes())?;
        for nrec in &self.nodes {
            for v in [nrec.label, nrec.first_out, nrec.first_in] {
                w.write_all(&v.to_le_bytes())?;
            }
            w.write_all(&nrec.prop.to_le_bytes())?;
        }
        w.write_all(&(self.rels.len() as u64).to_le_bytes())?;
        for r in &self.rels {
            for v in [r.src, r.dst, r.rel_type, r.next_out, r.next_in] {
                w.write_all(&v.to_le_bytes())?;
            }
            w.write_all(&r.weight.to_le_bytes())?;
        }
        Ok(())
    }

    /// Load the binary format; validates magic and record pointers.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).context("truncated store header")?;
        if &magic != Self::MAGIC {
            bail!("not a jgraph store file");
        }
        let mut u64buf = [0u8; 8];
        let mut u32buf = [0u8; 4];
        let mut read_u64 =
            |f: &mut dyn Read| -> Result<u64> { f.read_exact(&mut u64buf)?; Ok(u64::from_le_bytes(u64buf)) };
        let dict_len = read_u64(&mut f)? as usize;
        let mut store = Self::new();
        for _ in 0..dict_len {
            f.read_exact(&mut u32buf)?;
            let len = u32::from_le_bytes(u32buf) as usize;
            let mut s = vec![0u8; len];
            f.read_exact(&mut s)?;
            store.intern(&String::from_utf8(s).context("non-utf8 dictionary entry")?);
        }
        let node_len = read_u64(&mut f)? as usize;
        for _ in 0..node_len {
            let mut vals = [0u32; 3];
            for v in &mut vals {
                f.read_exact(&mut u32buf)?;
                *v = u32::from_le_bytes(u32buf);
            }
            f.read_exact(&mut u32buf)?;
            let prop = f32::from_le_bytes(u32buf);
            store.nodes.push(NodeRecord {
                label: vals[0],
                first_out: vals[1],
                first_in: vals[2],
                prop,
            });
        }
        let rel_len = read_u64(&mut f)? as usize;
        for i in 0..rel_len {
            let mut vals = [0u32; 5];
            for v in &mut vals {
                f.read_exact(&mut u32buf).with_context(|| format!("truncated at rel {i}"))?;
                *v = u32::from_le_bytes(u32buf);
            }
            f.read_exact(&mut u32buf)?;
            let weight = f32::from_le_bytes(u32buf);
            store.rels.push(RelRecord {
                src: vals[0],
                dst: vals[1],
                rel_type: vals[2],
                next_out: vals[3],
                next_in: vals[4],
                weight,
            });
        }
        store.validate()?;
        Ok(store)
    }

    /// Structural integrity: every pointer in range, chains acyclic.
    pub fn validate(&self) -> Result<()> {
        let nn = self.nodes.len() as u32;
        let nr = self.rels.len() as u32;
        for (i, n) in self.nodes.iter().enumerate() {
            if n.label as usize >= self.dict.len() {
                bail!("node {i}: label id out of range");
            }
            for p in [n.first_out, n.first_in] {
                if p != NIL && p >= nr {
                    bail!("node {i}: relationship pointer out of range");
                }
            }
        }
        for (i, r) in self.rels.iter().enumerate() {
            if r.src >= nn || r.dst >= nn {
                bail!("rel {i}: endpoint out of range");
            }
            if r.rel_type as usize >= self.dict.len() {
                bail!("rel {i}: type id out of range");
            }
        }
        // chain acyclicity: total chain steps cannot exceed rel count
        for v in 0..nn {
            let mut steps = 0u32;
            let mut cur = self.nodes[v as usize].first_out;
            while cur != NIL {
                steps += 1;
                if steps > nr {
                    bail!("node {v}: cyclic out-chain");
                }
                cur = self.rels[cur as usize].next_out;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn social() -> GraphStore {
        let mut s = GraphStore::new();
        let alice = s.create_node("Person", 30.0);
        let bob = s.create_node("Person", 25.0);
        let post = s.create_node("Post", 0.0);
        s.create_rel(alice, bob, "FOLLOWS", 1.0).unwrap();
        s.create_rel(bob, alice, "FOLLOWS", 1.0).unwrap();
        s.create_rel(alice, post, "LIKES", 0.5).unwrap();
        s
    }

    #[test]
    fn create_and_expand() {
        let s = social();
        assert_eq!(s.node_count(), 3);
        assert_eq!(s.rel_count(), 3);
        assert_eq!(s.node_label(2), "Post");
        let out = s.expand_out(0, None);
        assert_eq!(out.len(), 2);
        let follows = s.expand_out(0, Some("FOLLOWS"));
        assert_eq!(follows.len(), 1);
        assert_eq!(follows[0].0, 1);
        let inn = s.expand_in(0, None);
        assert_eq!(inn.len(), 1);
        assert_eq!(inn[0].0, 1);
    }

    #[test]
    fn label_scan() {
        let s = social();
        assert_eq!(s.nodes_with_label("Person"), vec![0, 1]);
        assert_eq!(s.nodes_with_label("Post"), vec![2]);
        assert!(s.nodes_with_label("Absent").is_empty());
    }

    #[test]
    fn fifo_bridge_to_edgelist() {
        let s = social();
        let all = s.to_edgelist(None);
        assert_eq!(all.num_edges(), 3);
        assert_eq!(all.num_vertices, 3);
        let follows = s.to_edgelist(Some("FOLLOWS"));
        assert_eq!(follows.num_edges(), 2);
        assert!(follows.is_valid());
    }

    #[test]
    fn edgelist_roundtrip_through_store() {
        let g = generate::erdos_renyi(50, 300, 4);
        let s = GraphStore::from_edgelist(&g, "V", "E");
        let back = s.to_edgelist(None).sorted();
        let want = g.sorted();
        assert_eq!(back.num_edges(), want.num_edges());
        for (a, b) in back.edges.iter().zip(&want.edges) {
            assert_eq!((a.src, a.dst), (b.src, b.dst));
        }
    }

    #[test]
    fn persistence_roundtrip() {
        let s = social();
        let dir = std::env::temp_dir().join("jgraph_store");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("social.db");
        s.save(&p).unwrap();
        let loaded = GraphStore::load(&p).unwrap();
        assert_eq!(loaded.node_count(), 3);
        assert_eq!(loaded.rel_count(), 3);
        assert_eq!(loaded.expand_out(0, Some("FOLLOWS")).len(), 1);
        assert_eq!(loaded.node_prop(0), 30.0);
    }

    #[test]
    fn corrupt_store_rejected() {
        let dir = std::env::temp_dir().join("jgraph_store_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.db");
        std::fs::write(&p, b"NOTSTORE").unwrap();
        assert!(GraphStore::load(&p).is_err());
        std::fs::write(&p, b"JGSTORE1").unwrap(); // truncated after magic
        assert!(GraphStore::load(&p).is_err());
    }

    #[test]
    fn bad_endpoints_rejected() {
        let mut s = GraphStore::new();
        s.create_node("V", 0.0);
        assert!(s.create_rel(0, 5, "E", 1.0).is_err());
    }

    #[test]
    fn big_store_stays_consistent() {
        let g = generate::rmat(9, 5_000, 0.57, 0.19, 0.19, 8);
        let s = GraphStore::from_edgelist(&g, "V", "E");
        s.validate().unwrap();
        // out-degrees via chains match the edge list
        let deg = g.out_degrees();
        for v in (0..g.num_vertices as u32).step_by(37) {
            assert_eq!(s.expand_out(v, None).len(), deg[v as usize] as usize);
        }
    }
}
