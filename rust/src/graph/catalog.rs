//! Named graph specs: one resolver mapping a spec string to an
//! [`EdgeList`], shared by the CLI (`jgraph run --graph …`) and the
//! serving registry ([`crate::serve::registry::ServeRegistry`]), so a
//! graph name means the same dataset everywhere.
//!
//! A spec is either a synthetic preset (deterministic under `seed`), a
//! graph-store database (`*.db` — the paper's "read data from database
//! directly" FIFO path), or a file path handed to [`super::io::load`].

use anyhow::Result;

use super::edgelist::EdgeList;
use super::{generate, io};

/// The synthetic preset names [`load_spec`] understands.
pub const PRESETS: &[&str] = &["email", "slashdot", "grid", "rmat", "er", "chain", "star"];

/// Resolve one spec to `(display name, edges)`. Presets are synthetic
/// stand-ins for the paper's SNAP datasets; anything else is treated as
/// a path (`.db` via the graph store, otherwise text/binary edge files).
pub fn load_spec(spec: &str, seed: u64) -> Result<(String, EdgeList)> {
    Ok(match spec {
        "email" => ("email-Eu-core (synthetic)".into(), generate::email_eu_core_like(seed)),
        "slashdot" => ("soc-Slashdot0922 (synthetic)".into(), generate::soc_slashdot_like(seed)),
        "grid" => ("grid 64x64".into(), generate::grid2d(64, 64, seed)),
        "rmat" => ("rmat-13".into(), generate::rmat(13, 120_000, 0.57, 0.19, 0.19, seed)),
        "er" => ("erdos-renyi".into(), generate::erdos_renyi(4_096, 65_536, seed)),
        "chain" => ("chain-1k".into(), generate::chain(1_000)),
        "star" => ("star-1k".into(), generate::star(1_000)),
        path if path.ends_with(".db") => {
            (path.to_string(), super::store::GraphStore::load(path)?.to_edgelist(None))
        }
        path => (path.to_string(), io::load(path)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_deterministically() {
        for preset in PRESETS {
            let (name, a) = load_spec(preset, 42).unwrap();
            let (_, b) = load_spec(preset, 42).unwrap();
            assert!(!name.is_empty());
            assert_eq!(a.num_vertices, b.num_vertices, "{preset}");
            assert_eq!(a.edges, b.edges, "{preset} must be seed-deterministic");
            assert!(a.num_edges() > 0, "{preset}");
        }
    }

    #[test]
    fn missing_path_is_an_error() {
        assert!(load_spec("/nonexistent/graph.txt", 1).is_err());
        assert!(load_spec("/nonexistent/graph.db", 1).is_err());
    }
}
