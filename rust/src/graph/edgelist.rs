//! Raw edge-list form: what the *FIFO* stage reads from disk and what the
//! *Layout* stage converts to CSR/CSC (paper §IV-C).

use super::{VertexId, DEFAULT_WEIGHT};

/// A directed edge `(src, dst, weight)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: VertexId,
    pub dst: VertexId,
    pub weight: f32,
}

/// A directed graph as a flat edge list, the interchange form between
/// preprocessing stages. Invariant: every endpoint is `< num_vertices`.
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    pub num_vertices: usize,
    pub edges: Vec<Edge>,
}

impl EdgeList {
    /// Empty graph with `n` isolated vertices.
    pub fn with_vertices(n: usize) -> Self {
        Self { num_vertices: n, edges: Vec::new() }
    }

    /// Build from `(src, dst)` pairs with unit weights. Grows
    /// `num_vertices` to cover every endpoint.
    pub fn from_pairs(pairs: impl IntoIterator<Item = (VertexId, VertexId)>) -> Self {
        let mut el = Self::default();
        for (s, d) in pairs {
            el.push(s, d, DEFAULT_WEIGHT);
        }
        el
    }

    /// Append an edge, growing the vertex count as needed.
    pub fn push(&mut self, src: VertexId, dst: VertexId, weight: f32) {
        self.num_vertices = self.num_vertices.max(src.max(dst) as usize + 1);
        self.edges.push(Edge { src, dst, weight });
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Check the endpoint invariant (used by io/loaders and proptests).
    pub fn is_valid(&self) -> bool {
        self.edges
            .iter()
            .all(|e| (e.src as usize) < self.num_vertices && (e.dst as usize) < self.num_vertices)
    }

    /// Out-degree per vertex.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices];
        for e in &self.edges {
            deg[e.src as usize] += 1;
        }
        deg
    }

    /// In-degree per vertex.
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices];
        for e in &self.edges {
            deg[e.dst as usize] += 1;
        }
        deg
    }

    /// Remove exact-duplicate `(src, dst)` pairs, keeping the first
    /// occurrence's weight. Stable order of survivors.
    pub fn dedup(&mut self) {
        let mut seen = std::collections::HashSet::with_capacity(self.edges.len());
        self.edges.retain(|e| seen.insert((e.src, e.dst)));
    }

    /// Drop self-loops (`src == dst`). BFS/PR treat them as no-ops but they
    /// waste pipeline slots in the simulator.
    pub fn drop_self_loops(&mut self) {
        self.edges.retain(|e| e.src != e.dst);
    }

    /// Add the reverse of every edge (directed → symmetric). Weights copied.
    pub fn symmetrize(&mut self) {
        let rev: Vec<Edge> = self
            .edges
            .iter()
            .map(|e| Edge { src: e.dst, dst: e.src, weight: e.weight })
            .collect();
        self.edges.extend(rev);
        self.dedup();
    }

    /// Apply a vertex permutation: `perm[old] = new`. Used by the *Reorder*
    /// preprocessing stage. Panics if `perm.len() != num_vertices`.
    pub fn permute(&self, perm: &[VertexId]) -> EdgeList {
        assert_eq!(perm.len(), self.num_vertices, "permutation length mismatch");
        EdgeList {
            num_vertices: self.num_vertices,
            edges: self
                .edges
                .iter()
                .map(|e| Edge {
                    src: perm[e.src as usize],
                    dst: perm[e.dst as usize],
                    weight: e.weight,
                })
                .collect(),
        }
    }

    /// Sort edges by `(src, dst)` — canonical order used by tests to compare
    /// graphs structurally.
    pub fn sorted(&self) -> EdgeList {
        let mut el = self.clone();
        el.edges
            .sort_by(|a, b| (a.src, a.dst).cmp(&(b.src, b.dst)).then(a.weight.total_cmp(&b.weight)));
        el
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> EdgeList {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        EdgeList::from_pairs([(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn from_pairs_grows_vertices() {
        let g = diamond();
        assert_eq!(g.num_vertices, 4);
        assert_eq!(g.num_edges(), 4);
        assert!(g.is_valid());
    }

    #[test]
    fn degrees() {
        let g = diamond();
        assert_eq!(g.out_degrees(), vec![2, 1, 1, 0]);
        assert_eq!(g.in_degrees(), vec![0, 1, 1, 2]);
    }

    #[test]
    fn dedup_keeps_first() {
        let mut g = EdgeList::default();
        g.push(0, 1, 5.0);
        g.push(0, 1, 9.0);
        g.push(1, 0, 1.0);
        g.dedup();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges[0].weight, 5.0);
    }

    #[test]
    fn self_loops_dropped() {
        let mut g = EdgeList::from_pairs([(0, 0), (0, 1), (1, 1)]);
        g.drop_self_loops();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn symmetrize_doubles_and_dedups() {
        let mut g = EdgeList::from_pairs([(0, 1), (1, 0), (1, 2)]);
        g.symmetrize();
        let s = g.sorted();
        let pairs: Vec<_> = s.edges.iter().map(|e| (e.src, e.dst)).collect();
        assert_eq!(pairs, vec![(0, 1), (1, 0), (1, 2), (2, 1)]);
    }

    #[test]
    fn permute_relabels_endpoints() {
        let g = diamond();
        // swap 0 <-> 3
        let perm = vec![3, 1, 2, 0];
        let p = g.permute(&perm);
        assert!(p.is_valid());
        let s = p.sorted();
        let pairs: Vec<_> = s.edges.iter().map(|e| (e.src, e.dst)).collect();
        assert_eq!(pairs, vec![(1, 0), (2, 0), (3, 1), (3, 2)]);
    }

    #[test]
    #[should_panic(expected = "permutation length mismatch")]
    fn permute_rejects_bad_length() {
        diamond().permute(&[0, 1]);
    }
}
