//! Compressed Sparse Row — the paper's on-device graph format (§IV-A):
//! `Vertices` (per-vertex values), `Edge_offset` (row pointers), `Edges`
//! (column ids + weights). "CSR saves memory and is easy for memory
//! accessing" — the accelerator streams `Edges` sequentially and the
//! simulator models exactly that access pattern.

use super::edgelist::{Edge, EdgeList};
use super::{EdgeId, VertexId, DEFAULT_WEIGHT};

/// CSR adjacency. Depending on how it was built this stores out-edges
/// (CSR proper) or in-edges (CSC — the transpose); the DSL's
/// `Get_out_edges_list` / `Get_in_edges_list` pick the right one.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// `Edge_offset` array: `offsets[v]..offsets[v+1]` indexes `targets`.
    pub offsets: Vec<u32>,
    /// `Edges` array: neighbor vertex ids, grouped by source.
    pub targets: Vec<VertexId>,
    /// Edge weights, parallel to `targets`.
    pub weights: Vec<f32>,
}

impl Csr {
    /// Build out-edge CSR from an edge list. Counting sort: O(V + E),
    /// stable in input order within a row.
    pub fn from_edgelist(el: &EdgeList) -> Self {
        Self::build(el.num_vertices, el.edges.iter().map(|e| (e.src, e.dst, e.weight)))
    }

    /// Build in-edge CSR (i.e. CSC) from an edge list: rows are
    /// destinations, targets are sources.
    pub fn csc_from_edgelist(el: &EdgeList) -> Self {
        Self::build(el.num_vertices, el.edges.iter().map(|e| (e.dst, e.src, e.weight)))
    }

    fn build(n: usize, edges: impl Iterator<Item = (VertexId, VertexId, f32)> + Clone) -> Self {
        let mut counts = vec![0u32; n + 1];
        for (row, _, _) in edges.clone() {
            counts[row as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let m = offsets[n] as usize;
        let mut targets = vec![0 as VertexId; m];
        let mut weights = vec![DEFAULT_WEIGHT; m];
        let mut cursor = offsets.clone();
        for (row, col, w) in edges {
            let slot = cursor[row as usize] as usize;
            targets[slot] = col;
            weights[slot] = w;
            cursor[row as usize] += 1;
        }
        Csr { offsets, targets, weights }
    }

    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Degree of `v` in this orientation.
    pub fn degree(&self, v: VertexId) -> u32 {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Degree of every vertex in this orientation (out-degrees on a CSR,
    /// in-degrees on a CSC) — the flat array the engine's pull heuristic
    /// and PageRank contribution scaling consume.
    pub fn out_degrees(&self) -> Vec<u32> {
        (0..self.num_vertices() as VertexId).map(|v| self.degree(v)).collect()
    }

    /// Each row id repeated once per edge of its row, in row-major order.
    /// On a CSC this is the pull direction's destination stream
    /// (ascending runs) — its exact order is load-bearing for the trace
    /// contract and the simulator's run-compressed reduce model, so every
    /// consumer derives it through this one helper.
    pub fn row_run_stream(&self) -> Vec<VertexId> {
        (0..self.num_vertices() as VertexId)
            .flat_map(|v| std::iter::repeat(v).take(self.degree(v) as usize))
            .collect()
    }

    /// Neighbor ids of `v` (the DSL's `Get_dest_V_list` on CSR,
    /// `Get_src_V_list` on CSC).
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let (a, b) = self.row_range(v);
        &self.targets[a..b]
    }

    /// Edge weights of `v`'s row.
    pub fn row_weights(&self, v: VertexId) -> &[f32] {
        let (a, b) = self.row_range(v);
        &self.weights[a..b]
    }

    /// `(edge_id, neighbor, weight)` triples of `v`'s row — the DSL's
    /// `Get_out_edges_list` return shape.
    pub fn row_edges(
        &self,
        v: VertexId,
    ) -> impl Iterator<Item = (EdgeId, VertexId, f32)> + Clone + '_ {
        let (a, b) = self.row_range(v);
        (a..b).map(move |i| (i as EdgeId, self.targets[i], self.weights[i]))
    }

    fn row_range(&self, v: VertexId) -> (usize, usize) {
        (self.offsets[v as usize] as usize, self.offsets[v as usize + 1] as usize)
    }

    /// Which row an edge id belongs to (the DSL's `Get_src_V_id` on CSR):
    /// binary search over `offsets`.
    pub fn edge_row(&self, e: EdgeId) -> VertexId {
        debug_assert!((e as usize) < self.num_edges());
        // partition_point: first row whose offset exceeds e.
        let row = self.offsets.partition_point(|&off| off <= e) - 1;
        row as VertexId
    }

    /// Flatten back to an edge list (row = src). Inverse of
    /// `from_edgelist` up to edge order within a row.
    pub fn to_edgelist(&self) -> EdgeList {
        let mut el = EdgeList::with_vertices(self.num_vertices());
        for v in 0..self.num_vertices() as VertexId {
            for (_, t, w) in self.row_edges(v) {
                el.edges.push(Edge { src: v, dst: t, weight: w });
            }
        }
        el
    }

    /// Transpose (CSR ↔ CSC): a direct counting-sort build over the edge
    /// arrays — no intermediate `EdgeList` materialization. Shares
    /// [`Csr::build`] with the other constructors.
    ///
    /// **Ordering contract:** `build`'s scatter is stable in input order,
    /// and the input here is the CSR stream (row-major), so within each
    /// transposed row the neighbors appear in CSR-stream order. The pull
    /// direction of the GAS engine relies on this: per-destination
    /// reductions over a CSC built by `transpose()` accumulate messages in
    /// exactly the order the push direction produces them, which is what
    /// makes pull supersteps **bit-identical** to push even for
    /// non-associative f32/f64 sums.
    pub fn transpose(&self) -> Csr {
        let n = self.num_vertices();
        let stream = (0..n as VertexId)
            .flat_map(|v| self.row_edges(v).map(move |(_, t, w)| (t, v, w)));
        Self::build(n, stream)
    }

    /// Padded COO arrays in the artifact ABI (src, dst, w, real edge count)
    /// — what [`crate::runtime`] feeds the AOT superstep. `m_pad >= E`.
    pub fn to_padded_coo(&self, m_pad: usize) -> PaddedCoo {
        assert!(m_pad >= self.num_edges(), "padding smaller than edge count");
        let mut src = vec![0i32; m_pad];
        let mut dst = vec![0i32; m_pad];
        let mut w = vec![0f32; m_pad];
        let mut k = 0;
        for v in 0..self.num_vertices() as VertexId {
            for (_, t, ww) in self.row_edges(v) {
                src[k] = v as i32;
                dst[k] = t as i32;
                w[k] = ww;
                k += 1;
            }
        }
        PaddedCoo { src, dst, w, num_edges: k }
    }

    /// Total bytes of the three arrays — what the communication manager
    /// transports over (simulated) PCIe.
    pub fn byte_size(&self) -> usize {
        self.offsets.len() * 4 + self.targets.len() * 4 + self.weights.len() * 4
    }
}

/// COO arrays padded to an artifact bucket; padding slots carry
/// `src = dst = 0, w = 0` and are masked out by `num_edges` on device
/// (see python/compile/kernels/ref.py).
#[derive(Debug, Clone)]
pub struct PaddedCoo {
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub w: Vec<f32>,
    pub num_edges: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> EdgeList {
        EdgeList::from_pairs([(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn build_and_rows() {
        let c = Csr::from_edgelist(&diamond());
        assert_eq!(c.num_vertices(), 4);
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.neighbors(0), &[1, 2]);
        assert_eq!(c.neighbors(1), &[3]);
        assert_eq!(c.neighbors(3), &[] as &[u32]);
        assert_eq!(c.degree(0), 2);
    }

    #[test]
    fn csc_is_in_edges() {
        let c = Csr::csc_from_edgelist(&diamond());
        assert_eq!(c.neighbors(3), &[1, 2]);
        assert_eq!(c.neighbors(0), &[] as &[u32]);
    }

    #[test]
    fn edge_row_binary_search() {
        let c = Csr::from_edgelist(&diamond());
        assert_eq!(c.edge_row(0), 0);
        assert_eq!(c.edge_row(1), 0);
        assert_eq!(c.edge_row(2), 1);
        assert_eq!(c.edge_row(3), 2);
    }

    #[test]
    fn roundtrip_edgelist() {
        let el = diamond();
        let rt = Csr::from_edgelist(&el).to_edgelist().sorted();
        assert_eq!(rt.num_vertices, el.num_vertices);
        let a: Vec<_> = rt.edges.iter().map(|e| (e.src, e.dst)).collect();
        let b: Vec<_> = el.sorted().edges.iter().map(|e| (e.src, e.dst)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn transpose_twice_is_identity() {
        let c = Csr::from_edgelist(&diamond());
        assert_eq!(c.transpose().transpose(), c);
    }

    #[test]
    fn transpose_twice_is_identity_on_rmat() {
        // power-law structure with duplicate edges, self-loops, and
        // isolated vertices — not just the diamond toy
        for seed in [3, 17, 99] {
            let el = crate::graph::generate::rmat(9, 6_000, 0.57, 0.19, 0.19, seed);
            let c = Csr::from_edgelist(&el);
            assert_eq!(c.transpose().transpose(), c, "seed {seed}");
        }
    }

    #[test]
    fn transpose_matches_csc_from_edgelist() {
        // the direct counting-sort transpose and the EdgeList-based CSC
        // constructor share `build`; on an edge list already in CSR stream
        // order (src-major) the two stable scatters see the same input
        // sequence and must produce identical arrays
        let el = crate::graph::generate::rmat(8, 3_000, 0.57, 0.19, 0.19, 7).sorted();
        let csr = Csr::from_edgelist(&el);
        assert_eq!(csr.transpose(), Csr::csc_from_edgelist(&el));
    }

    #[test]
    fn transpose_rows_preserve_csr_stream_order() {
        // within a CSC row, sources must appear in CSR-stream order (the
        // stability the pull direction's bit-exactness rests on)
        let el = crate::graph::generate::rmat(7, 1_500, 0.57, 0.19, 0.19, 5);
        let csr = Csr::from_edgelist(&el);
        let csc = csr.transpose();
        // expected: scan the CSR stream and append each edge's source to
        // its destination's row
        let mut expect: Vec<Vec<u32>> = vec![Vec::new(); csr.num_vertices()];
        for v in 0..csr.num_vertices() as VertexId {
            for (_, t, _) in csr.row_edges(v) {
                expect[t as usize].push(v);
            }
        }
        for v in 0..csc.num_vertices() as VertexId {
            assert_eq!(csc.neighbors(v), &expect[v as usize][..], "row {v}");
        }
    }

    #[test]
    fn padded_coo_masks_tail() {
        let c = Csr::from_edgelist(&diamond());
        let coo = c.to_padded_coo(8);
        assert_eq!(coo.num_edges, 4);
        assert_eq!(&coo.src[4..], &[0; 4]);
        assert_eq!(&coo.dst[4..], &[0; 4]);
        assert_eq!(coo.src[..4], [0, 0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "padding smaller")]
    fn padded_coo_rejects_short_pad() {
        Csr::from_edgelist(&diamond()).to_padded_coo(2);
    }

    #[test]
    fn byte_size_counts_all_arrays() {
        let c = Csr::from_edgelist(&diamond());
        assert_eq!(c.byte_size(), (5 + 4 + 4) * 4);
    }
}
