//! Structural statistics: degree distribution, skew, connectivity. Used by
//! the report layer (dataset tables), the simulator's locality model, and
//! tests (e.g. "R-MAT presets are power-law").

use super::edgelist::EdgeList;

/// Summary statistics of a directed graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphStats {
    pub num_vertices: usize,
    pub num_edges: usize,
    pub max_out_degree: u32,
    pub max_in_degree: u32,
    pub avg_degree: f64,
    /// Fraction of edges owned by the top 1% highest-out-degree vertices —
    /// the skew measure the simulator's conflict model consumes.
    pub hub_edge_fraction: f64,
    /// MLE power-law exponent fitted on out-degrees >= 2 (None when the
    /// graph is too small/uniform to fit).
    pub power_law_alpha: Option<f64>,
    /// Number of weakly-connected components.
    pub num_weak_components: usize,
}

impl GraphStats {
    pub fn compute(el: &EdgeList) -> GraphStats {
        let out = el.out_degrees();
        let inn = el.in_degrees();
        let n = el.num_vertices.max(1);
        let m = el.num_edges();
        let max_out = out.iter().copied().max().unwrap_or(0);
        let max_in = inn.iter().copied().max().unwrap_or(0);

        // hub fraction: sort degrees descending, take top 1% of vertices
        let mut sorted = out.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top = (n / 100).max(1);
        let hub_edges: u64 = sorted[..top.min(sorted.len())].iter().map(|&d| d as u64).sum();
        let hub_edge_fraction = if m > 0 { hub_edges as f64 / m as f64 } else { 0.0 };

        GraphStats {
            num_vertices: el.num_vertices,
            num_edges: m,
            max_out_degree: max_out,
            max_in_degree: max_in,
            avg_degree: m as f64 / n as f64,
            hub_edge_fraction,
            power_law_alpha: power_law_alpha(&out),
            num_weak_components: weak_components(el),
        }
    }
}

/// MLE estimator for the power-law exponent: alpha = 1 + n / Σ ln(d/dmin),
/// over degrees >= dmin = 2. Returns None with < 10 qualifying samples.
pub fn power_law_alpha(degrees: &[u32]) -> Option<f64> {
    const DMIN: f64 = 2.0;
    let samples: Vec<f64> = degrees.iter().filter(|&&d| d >= 2).map(|&d| d as f64).collect();
    if samples.len() < 10 {
        return None;
    }
    let s: f64 = samples.iter().map(|d| (d / DMIN).ln()).sum();
    if s <= 0.0 {
        return None;
    }
    Some(1.0 + samples.len() as f64 / s)
}

/// Degree histogram as (degree, count) pairs, ascending, zero counts
/// omitted. Feeds the report layer's dataset descriptions.
pub fn degree_histogram(degrees: &[u32]) -> Vec<(u32, usize)> {
    let mut map = std::collections::BTreeMap::new();
    for &d in degrees {
        *map.entry(d).or_insert(0usize) += 1;
    }
    map.into_iter().collect()
}

/// Weakly-connected component count via union-find with path halving.
pub fn weak_components(el: &EdgeList) -> usize {
    if el.num_vertices == 0 {
        return 0;
    }
    let mut parent: Vec<u32> = (0..el.num_vertices as u32).collect();
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }
    for e in &el.edges {
        let (a, b) = (find(&mut parent, e.src), find(&mut parent, e.dst));
        if a != b {
            parent[a as usize] = b;
        }
    }
    let mut roots = std::collections::HashSet::new();
    for v in 0..el.num_vertices as u32 {
        roots.insert(find(&mut parent, v));
    }
    roots.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn stats_on_star() {
        let s = generate::star(101);
        let st = GraphStats::compute(&s);
        assert_eq!(st.max_out_degree, 100);
        assert_eq!(st.num_weak_components, 1);
        // hub (top 1% = 1 vertex) owns half the edges (hub->spoke direction)
        assert!(st.hub_edge_fraction >= 0.5);
    }

    #[test]
    fn components_counted() {
        // two disjoint chains + one isolated vertex
        let mut el = generate::chain(3);
        let off = el.num_vertices as u32;
        el.push(off, off + 1, 1.0);
        el.num_vertices += 1; // isolated vertex
        assert_eq!(weak_components(&el), 3);
    }

    #[test]
    fn histogram_sums_to_n() {
        let g = generate::erdos_renyi(64, 300, 5);
        let h = degree_histogram(&g.out_degrees());
        let total: usize = h.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn alpha_fits_skewed_but_not_tiny() {
        assert!(power_law_alpha(&[1, 1, 1]).is_none());
        let g = generate::rmat(10, 20_000, 0.57, 0.19, 0.19, 3);
        let alpha = power_law_alpha(&g.out_degrees()).unwrap();
        assert!(alpha > 1.0 && alpha < 5.0, "alpha={alpha}");
    }

    #[test]
    fn empty_graph_stats() {
        let el = crate::graph::edgelist::EdgeList::default();
        let st = GraphStats::compute(&el);
        assert_eq!(st.num_edges, 0);
        assert_eq!(st.num_weak_components, 0);
    }
}
