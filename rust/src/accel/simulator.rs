//! The accelerator simulator: executes a design's [`PipelineSpec`] over
//! per-superstep edge batches and accounts cycles per the module models.
//!
//! The timing claim structure (see DESIGN.md §6): who wins and by what
//! factor is decided by (a) II × lanes from the translator's schedule,
//! (b) bank conflicts from the real destination distribution, (c) the
//! BRAM-vertex-cache flag, and (d) per-superstep launch overhead — so
//! translator quality and graph structure drive the result, not hardcoded
//! outputs.

use super::bram::BankModel;
use super::device::DeviceModel;
use super::memctrl;
use super::stats::{CycleBreakdown, SimStats, SuperstepSim};
use crate::dsl::program::Direction;
use crate::translator::pipeline::PipelineSpec;

/// Host→device superstep launch overhead (seconds): control-register write
/// + doorbell over PCIe, amortized measurement from XRT-class shells.
pub const LAUNCH_SECONDS: f64 = 5.0e-6;

/// MSHR depth of the memory subsystem for random vertex access overlap
/// (XDMA-class shells keep ~32 outstanding reads per channel group).
const VERTEX_MSHRS: u32 = 32;

/// One superstep's workload as seen by the accelerator.
#[derive(Debug, Clone, Copy)]
pub struct EdgeBatch<'a> {
    /// Destination vertex id per processed edge, in stream order (drives
    /// the reduce bank-conflict model).
    pub dsts: &'a [u32],
    /// Distinct CSR rows opened this superstep (active vertices).
    pub active_rows: u64,
    /// Bytes fetched from DDR per edge (8 unweighted, 12 weighted).
    pub bytes_per_edge: u64,
    /// Mean |src-dst| id gap of the batch (locality proxy; see
    /// [`memctrl::locality_factor`]).
    pub avg_edge_gap: f64,
    /// Traversal direction of this superstep. Pull batches stream `dsts`
    /// as ascending CSC-order runs — the banked reduce sees its real
    /// (conflict-light) write pattern straight from the stream content;
    /// the flag makes the contract explicit and feeds the push/pull
    /// accounting in [`SimStats`].
    pub direction: Direction,
}

/// Simulator for one run of one design on one device.
#[derive(Debug)]
pub struct AccelSimulator {
    device: DeviceModel,
    pipeline: PipelineSpec,
    banks: BankModel,
    stats: SimStats,
    superstep_index: u32,
    /// Scratch window buffer for the pull direction's run-compressed
    /// reduce writes, reused across supersteps (hot path: no per-window
    /// allocation).
    run_scratch: Vec<u32>,
}

impl AccelSimulator {
    pub fn new(device: DeviceModel, pipeline: PipelineSpec) -> Self {
        let banks = BankModel::new(device.reduce_banks);
        let stats = SimStats { clock_hz: pipeline.clock_hz, ..Default::default() };
        Self { device, pipeline, banks, stats, superstep_index: 0, run_scratch: Vec::new() }
    }

    /// Simulate one superstep; returns its cycle account and accumulates
    /// into the run stats.
    pub fn superstep(&mut self, batch: &EdgeBatch) -> SuperstepSim {
        let edges = batch.dsts.len() as u64;
        let lanes = self.pipeline.total_lanes().max(1) as usize;
        let ii = self.pipeline.ii;

        let mut cycles = CycleBreakdown::default();

        // (1)+(2) issue + conflicts: windows of `lanes` edges; each window
        // costs max(ii, worst-bank-collision) plus the flow's per-edge
        // control overhead.
        //
        // Direction matters for the banked reduce: a push superstep
        // scatters one read-modify-write per edge, so every destination
        // in the window contends. A pull superstep streams its edges as
        // runs of the same destination (CSC row order); the gather
        // datapath chains a run through a per-row accumulator register
        // and commits **one** banked write per run segment — so only
        // distinct-destination writes inside a window can collide.
        let mut issue: u64 = 0;
        // Pull supersteps commit one vertex write per destination *run*
        // (the CSC-order sequential writeback), not one per edge; the run
        // count feeds the uncached-vertex memory model below.
        let mut pull_runs: u64 = 0;
        match batch.direction {
            Direction::Push => {
                for window in batch.dsts.chunks(lanes) {
                    issue += self.banks.window_cycles(window, ii) as u64;
                }
            }
            Direction::Pull => {
                for window in batch.dsts.chunks(lanes) {
                    self.run_scratch.clear();
                    let mut prev = None;
                    for &d in window {
                        if prev != Some(d) {
                            self.run_scratch.push(d);
                            prev = Some(d);
                        }
                    }
                    pull_runs += self.run_scratch.len() as u64;
                    issue += self.banks.window_cycles(&self.run_scratch, ii) as u64;
                }
            }
        }
        let ideal = edges.div_ceil(lanes as u64) * ii as u64;
        cycles.compute = ideal + (edges as f64 * self.pipeline.per_edge_overhead) as u64;
        cycles.conflict = issue.saturating_sub(edges.div_ceil(lanes as u64) * ii as u64);

        // (3) memory: edge streaming only costs what exceeds the compute
        // time (perfectly overlapped prefetch otherwise).
        let stream = memctrl::stream_cycles(&self.device, edges * batch.bytes_per_edge);
        cycles.stream = stream.saturating_sub(cycles.compute + cycles.conflict);

        let locality = memctrl::locality_factor(batch.avg_edge_gap);
        cycles.row_start = memctrl::row_start_cycles(&self.device, batch.active_rows, locality);

        if !self.pipeline.bram_vertex_cache {
            // Uncached vertex state hits DRAM directly. The gather read
            // side is one access per edge either way; the writeback side
            // is direction-dependent: push scatters one random write per
            // edge, while pull's per-destination accumulator commits one
            // sequential write per run of equal destinations.
            let accesses = match batch.direction {
                Direction::Push => 2 * edges,
                Direction::Pull => edges + pull_runs,
            };
            cycles.vertex_random =
                memctrl::vertex_random_cycles(&self.device, accesses, VERTEX_MSHRS);
        }

        cycles.fill_drain = self.pipeline.depth as u64;

        let sim = SuperstepSim {
            index: self.superstep_index,
            edges,
            active_vertices: batch.active_rows,
            direction: batch.direction,
            shards: 0,
            cycles,
            launch_seconds: LAUNCH_SECONDS,
        };
        self.superstep_index += 1;
        self.stats.supersteps += 1;
        if batch.direction == Direction::Pull {
            self.stats.pull_supersteps += 1;
        }
        self.stats.total_edges += edges;
        self.stats.cycles.add(&cycles);
        self.stats.launch_seconds += LAUNCH_SECONDS;
        sim
    }

    /// Consume the simulator, returning the run aggregate.
    pub fn finish(self) -> SimStats {
        self.stats
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ParallelismPlan;
    use crate::translator::pipeline::schedule;
    use crate::translator::TranslatorKind;

    fn sim(kind: TranslatorKind, plan: ParallelismPlan) -> AccelSimulator {
        let dev = DeviceModel::u200();
        let clock = dev.clock_hz;
        AccelSimulator::new(dev, schedule(kind, plan, 20, clock))
    }

    fn batch(dsts: &[u32]) -> EdgeBatch<'_> {
        EdgeBatch {
            dsts,
            active_rows: 10,
            bytes_per_edge: 8,
            avg_edge_gap: 100.0,
            direction: Direction::Push,
        }
    }

    #[test]
    fn jgraph_beats_vivado_beats_spatial() {
        // same workload through the three flows: Table V's ordering must
        // emerge from the model, not be asserted
        let mut rng = crate::graph::SplitMix64::new(3);
        let dsts: Vec<u32> = (0..100_000).map(|_| rng.next_below(10_000) as u32).collect();
        let mut m = std::collections::HashMap::new();
        for kind in TranslatorKind::all() {
            let mut s = sim(kind, ParallelismPlan::default());
            s.superstep(&EdgeBatch {
                dsts: &dsts,
                active_rows: 10_000,
                bytes_per_edge: 8,
                avg_edge_gap: 3000.0,
                direction: Direction::Push,
            });
            m.insert(kind, s.finish().mteps());
        }
        let j = m[&TranslatorKind::JGraph];
        let v = m[&TranslatorKind::VivadoHls];
        let s = m[&TranslatorKind::Spatial];
        assert!(j > v, "jgraph {j:.0} <= vivado {v:.0}");
        assert!(v > 4.0 * s, "vivado {v:.0} not >> spatial {s:.0}");
    }

    #[test]
    fn conflicts_increase_with_skew() {
        // all edges to one destination = worst case for the banked reduce
        let uniform: Vec<u32> = (0..8_000).collect();
        let skewed = vec![7u32; 8_000];
        let mut a = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        a.superstep(&batch(&uniform));
        let mut b = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        b.superstep(&batch(&skewed));
        assert!(
            b.stats().cycles.conflict > 4 * a.stats().cycles.conflict.max(1),
            "skewed {} vs uniform {}",
            b.stats().cycles.conflict,
            a.stats().cycles.conflict
        );
    }

    #[test]
    fn more_lanes_fewer_cycles() {
        let mut rng = crate::graph::SplitMix64::new(5);
        let dsts: Vec<u32> = (0..50_000).map(|_| rng.next_below(50_000) as u32).collect();
        let mut narrow = sim(TranslatorKind::JGraph, ParallelismPlan::new(2, 1));
        narrow.superstep(&batch(&dsts));
        let mut wide = sim(TranslatorKind::JGraph, ParallelismPlan::new(16, 1));
        wide.superstep(&batch(&dsts));
        assert!(wide.stats().cycles.total() < narrow.stats().cycles.total());
    }

    #[test]
    fn launch_overhead_accumulates_per_superstep() {
        let mut s = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        for _ in 0..10 {
            s.superstep(&batch(&[1, 2, 3]));
        }
        let st = s.finish();
        assert_eq!(st.supersteps, 10);
        assert!((st.launch_seconds - 10.0 * LAUNCH_SECONDS).abs() < 1e-12);
    }

    #[test]
    fn pull_order_stream_conflicts_less_and_is_accounted() {
        // same destination multiset, two stream orders: scattered (push)
        // vs ascending CSC-order runs (pull). The banked reduce must see
        // the pull stream's sequential writes as fewer conflicts — the
        // whole point of carrying the real access pattern in the trace.
        let mut rng = crate::graph::SplitMix64::new(11);
        let push_order: Vec<u32> =
            (0..80_000).map(|_| rng.next_below(4_000) as u32).collect();
        let mut pull_order = push_order.clone();
        pull_order.sort_unstable();
        let mut a = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        a.superstep(&EdgeBatch {
            dsts: &push_order,
            active_rows: 4_000,
            bytes_per_edge: 8,
            avg_edge_gap: 100.0,
            direction: Direction::Push,
        });
        let mut b = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        b.superstep(&EdgeBatch {
            dsts: &pull_order,
            active_rows: 4_000,
            bytes_per_edge: 8,
            avg_edge_gap: 100.0,
            direction: Direction::Pull,
        });
        assert!(
            b.stats().cycles.conflict < a.stats().cycles.conflict,
            "pull {} !< push {}",
            b.stats().cycles.conflict,
            a.stats().cycles.conflict
        );
        assert_eq!(a.stats().pull_supersteps, 0);
        assert_eq!(b.stats().pull_supersteps, 1);
        assert_eq!(b.stats().supersteps, 1);
    }

    #[test]
    fn pull_writeback_is_sequential_per_run_not_per_edge() {
        // Uncached flows (Vivado-HLS-like) pay DRAM for vertex traffic.
        // Pull's accumulator commits one write per destination run, so on
        // the same multiset of destinations the pull superstep must cost
        // fewer random vertex cycles than the push superstep's
        // write-per-edge scatter.
        let mut rng = crate::graph::SplitMix64::new(17);
        let mut dsts: Vec<u32> =
            (0..60_000).map(|_| rng.next_below(2_000) as u32).collect();
        dsts.sort_unstable(); // CSC order: long same-destination runs
        let mk = |direction| EdgeBatch {
            dsts: &dsts,
            active_rows: 2_000,
            bytes_per_edge: 8,
            avg_edge_gap: 100.0,
            direction,
        };
        let mut push = sim(TranslatorKind::VivadoHls, ParallelismPlan::default());
        push.superstep(&mk(Direction::Push));
        let mut pull = sim(TranslatorKind::VivadoHls, ParallelismPlan::default());
        pull.superstep(&mk(Direction::Pull));
        let pv = pull.stats().cycles.vertex_random;
        let sv = push.stats().cycles.vertex_random;
        assert!(pv < sv, "pull {pv} !< push {sv}");
        // the BRAM-cached flow never touches DRAM for vertices, so its
        // reports are untouched by the direction-dependent model
        let mut cached = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        cached.superstep(&mk(Direction::Pull));
        assert_eq!(cached.stats().cycles.vertex_random, 0);
    }

    #[test]
    fn locality_reduces_row_start() {
        let dsts: Vec<u32> = (0..10_000).collect();
        let mut far = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        far.superstep(&EdgeBatch {
            dsts: &dsts,
            active_rows: 10_000,
            bytes_per_edge: 8,
            avg_edge_gap: 100_000.0,
            direction: Direction::Push,
        });
        let mut near = sim(TranslatorKind::JGraph, ParallelismPlan::default());
        near.superstep(&EdgeBatch {
            dsts: &dsts,
            active_rows: 10_000,
            bytes_per_edge: 8,
            avg_edge_gap: 2.0,
            direction: Direction::Push,
        });
        assert!(near.stats().cycles.row_start < far.stats().cycles.row_start);
    }

    #[test]
    fn weighted_edges_stream_more_bytes() {
        let dsts: Vec<u32> = (0..2_000_000).map(|i| i % 1000).collect();
        let mut light = sim(TranslatorKind::JGraph, ParallelismPlan::new(64, 2));
        light.superstep(&EdgeBatch {
            dsts: &dsts,
            active_rows: 100,
            bytes_per_edge: 8,
            avg_edge_gap: 10.0,
            direction: Direction::Push,
        });
        let mut heavy = sim(TranslatorKind::JGraph, ParallelismPlan::new(64, 2));
        heavy.superstep(&EdgeBatch {
            dsts: &dsts,
            active_rows: 100,
            bytes_per_edge: 24,
            avg_edge_gap: 10.0,
            direction: Direction::Push,
        });
        assert!(heavy.stats().cycles.stream >= light.stats().cycles.stream);
    }
}
