//! DDR4 memory-controller model: streaming bandwidth, CSR row-start
//! activates, and random vertex-state access for designs without the BRAM
//! vertex cache. Constants are derived from the U200 datasheet values in
//! [`super::device::DeviceModel`]; locality sensitivity comes from the
//! average edge gap so the Reorder ablation has a physical effect.

use super::device::DeviceModel;

/// Cycles to stream `bytes` from DDR at the device's aggregate bandwidth.
pub fn stream_cycles(device: &DeviceModel, bytes: u64) -> u64 {
    let bytes_per_cycle = device.dram_bw() / device.clock_hz;
    (bytes as f64 / bytes_per_cycle).ceil() as u64
}

/// Row-activate penalty in cycles for starting one CSR row (fetching a new
/// adjacency segment usually opens a new DRAM row). Scaled by a locality
/// factor: well-reordered graphs place consecutive rows in the same DRAM
/// row, amortizing activates.
pub fn row_start_cycles(device: &DeviceModel, rows: u64, locality: f64) -> u64 {
    // tRCD+tRP ~ 30ns -> cycles at kernel clock; 4 channels overlap.
    let activate = device.dram_random_latency * 0.6 * device.clock_hz;
    let per_row = activate / device.dram_channels as f64;
    (rows as f64 * per_row * locality.clamp(0.05, 1.0)) as u64
}

/// Random vertex-state access cycles for `accesses` 4-byte reads+writes,
/// assuming `mshrs` outstanding misses overlap.
pub fn vertex_random_cycles(device: &DeviceModel, accesses: u64, mshrs: u32) -> u64 {
    let per_access = device.dram_random_latency * device.clock_hz / mshrs as f64;
    (accesses as f64 * per_access) as u64
}

/// Locality factor from the average |src-dst| id gap: 0.05 (perfectly
/// local, rows co-resident) … 1.0 (random). Log-shaped: locality effects
/// saturate once the working set spans many DRAM rows.
pub fn locality_factor(avg_edge_gap: f64) -> f64 {
    // a DRAM row holds ~1024 x 4B vertex entries
    let rows_spanned = 1.0 + avg_edge_gap / 1024.0;
    (rows_spanned.log2() / 8.0 + 0.05).clamp(0.05, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_matches_bandwidth() {
        let d = DeviceModel::u200();
        // 76.8 GB/s at 250 MHz = 307.2 B/cycle
        let c = stream_cycles(&d, 307_200);
        assert!((999..=1001).contains(&c), "{c}");
    }

    #[test]
    fn row_start_scales_with_locality() {
        let d = DeviceModel::u200();
        let random = row_start_cycles(&d, 10_000, 1.0);
        let local = row_start_cycles(&d, 10_000, 0.1);
        assert!(local < random / 5);
    }

    #[test]
    fn random_vertex_overlap() {
        let d = DeviceModel::u200();
        let a = vertex_random_cycles(&d, 1_000_000, 1);
        let b = vertex_random_cycles(&d, 1_000_000, 16);
        assert!((a as f64 / b as f64 - 16.0).abs() < 0.1);
    }

    #[test]
    fn locality_factor_monotone_and_bounded() {
        let f0 = locality_factor(0.0);
        let f1 = locality_factor(1_000.0);
        let f2 = locality_factor(100_000.0);
        assert!(f0 <= f1 && f1 <= f2);
        assert!((0.05..=1.0).contains(&f0));
        assert!((0.05..=1.0).contains(&f2));
    }
}
