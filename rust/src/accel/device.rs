//! FPGA device models — datasheet numbers for the resource-fit check and
//! the cycle simulator. Default is the paper's testbed: Xilinx Alveo U200
//! (A-U200-A64G-PQ-G), per §VI: "1,182K LUTs, 2,364K registers, 6,840
//! slice DSPs, 960 UltraRAMs and 64 GB DDR4 DRAM... PCI Express Gen3x16".


/// Static device description.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: &'static str,
    pub luts: u64,
    pub registers: u64,
    pub dsps: u64,
    /// BRAM capacity in kilobits (U200: 4,320 x 18Kb blocks = 75.9 Mb).
    pub bram_kb: u64,
    /// UltraRAM blocks (288 Kb each).
    pub urams: u64,
    /// DDR4 capacity in bytes.
    pub dram_bytes: u64,
    /// DDR4 channels and per-channel peak bandwidth (bytes/s).
    pub dram_channels: u32,
    pub dram_channel_bw: f64,
    /// Kernel clock (Hz). SDAccel-era U200 designs close timing ~250 MHz.
    pub clock_hz: f64,
    /// DDR4 random-access penalty (seconds) — row activate + CAS on a miss.
    pub dram_random_latency: f64,
    /// Reduce-unit BRAM banks (destination-conflict model).
    pub reduce_banks: u32,
}

impl DeviceModel {
    /// The paper's card: Alveo U200.
    pub fn u200() -> Self {
        DeviceModel {
            name: "xilinx-alveo-u200",
            luts: 1_182_000,
            registers: 2_364_000,
            dsps: 6_840,
            bram_kb: 4_320 * 18,
            urams: 960,
            dram_bytes: 64 << 30,
            dram_channels: 4,
            dram_channel_bw: 19.2e9, // DDR4-2400 x 64b
            clock_hz: 250.0e6,
            dram_random_latency: 50.0e-9,
            reduce_banks: 16,
        }
    }

    /// A smaller card (half a U200) for over-capacity failure tests and
    /// the resource-pressure ablation.
    pub fn small() -> Self {
        DeviceModel {
            name: "small-fpga",
            luts: 120_000,
            registers: 240_000,
            dsps: 680,
            bram_kb: 432 * 18,
            urams: 96,
            dram_bytes: 8 << 30,
            dram_channels: 1,
            dram_channel_bw: 19.2e9,
            clock_hz: 200.0e6,
            dram_random_latency: 55.0e-9,
            reduce_banks: 8,
        }
    }

    /// Total DRAM bandwidth (bytes/s).
    pub fn dram_bw(&self) -> f64 {
        self.dram_channels as f64 * self.dram_channel_bw
    }

    /// On-chip memory capacity in bytes (BRAM + URAM) — the budget for
    /// the vertex BRAM cache.
    pub fn onchip_bytes(&self) -> u64 {
        self.bram_kb * 1024 / 8 + self.urams * (288 * 1024 / 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u200_matches_paper_datasheet() {
        let d = DeviceModel::u200();
        assert_eq!(d.luts, 1_182_000);
        assert_eq!(d.registers, 2_364_000);
        assert_eq!(d.dsps, 6_840);
        assert_eq!(d.urams, 960);
        assert_eq!(d.dram_bytes, 64 << 30);
    }

    #[test]
    fn bandwidth_and_onchip_sane() {
        let d = DeviceModel::u200();
        assert!(d.dram_bw() > 7.0e10); // ~76.8 GB/s
        // 75.9Mb BRAM + 270Mb URAM ~ 43 MB on-chip
        let mb = d.onchip_bytes() / (1 << 20);
        assert!((30..60).contains(&mb), "{mb} MB");
        // largest bucket's vertex state (512 KB) must fit comfortably
        assert!(d.onchip_bytes() > 8 * 524_288);
    }

    #[test]
    fn small_is_smaller() {
        let (u, s) = (DeviceModel::u200(), DeviceModel::small());
        assert!(s.luts < u.luts && s.urams < u.urams && s.dram_bw() < u.dram_bw());
    }
}
