//! Cycle-level model of the generated FPGA design — the stand-in for the
//! paper's Alveo U200 silicon (DESIGN.md §2). Timing comes from four
//! components, each traceable to a real mechanism in the paper's Fig. 4
//! datapath:
//!
//! 1. **compute**: edges enter `lanes` pipelines at the design's
//!    initiation interval (+ per-edge control overhead for the baseline
//!    flows);
//! 2. **reduce-bank conflicts**: concurrent messages to the same BRAM bank
//!    serialize (the data-conflict problem the paper cites \[12\]);
//! 3. **memory**: DDR4 streaming of the edge arrays, CSR row-start
//!    activates, and (for flows without the BRAM vertex cache) random
//!    vertex-state accesses;
//! 4. **launch**: per-superstep host→device kick over PCIe.
//!
//! The simulator is deliberately *per-edge* for (2): conflicts depend on
//! the destination-id distribution, which is what makes the Reorder and
//! Partition ablations measurable. That loop is the L3 hot path profiled
//! in EXPERIMENTS.md §Perf.

pub mod bram;
pub mod multipe;
pub mod device;
pub mod memctrl;
pub mod simulator;
pub mod stats;

pub use device::DeviceModel;
pub use simulator::{AccelSimulator, EdgeBatch};
pub use stats::{CycleBreakdown, SimStats, SuperstepSim};
