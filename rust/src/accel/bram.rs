//! Reduce-unit BRAM bank-conflict model. The reduce stage performs a
//! read-modify-write on the destination vertex's accumulator; the BRAM is
//! banked (`dst % banks`), and two messages hitting the same bank in the
//! same dispatch window serialize — the "parallel data conflict" problem
//! the paper cites (Yao et al., PACT'18 \[12\]).
//!
//! This is the simulator's innermost loop (see EXPERIMENTS.md §Perf for
//! its optimization history): a generation-stamped counter table avoids
//! clearing per window.

/// Banked-conflict counter. Counts, per dispatch window of `lanes`
/// destinations, the maximum number of messages that landed in one bank;
/// the window then needs `max(ii, max_per_bank)` cycles instead of `ii`.
///
/// Perf notes (EXPERIMENTS.md §Perf, L3): bank count is a power of two so
/// the modulo is a mask, and stamp+count share one u32 slot
/// (`generation << 8 | count`) so each edge touches exactly one cache
/// word — no per-window reset.
#[derive(Debug)]
pub struct BankModel {
    /// `banks - 1`; banks is a power of two.
    mask: u32,
    /// Per-bank `generation << COUNT_BITS | count` (O(1) window reset:
    /// stale generations read as count 0).
    slot: Vec<u32>,
    generation: u32,
}

/// Low bits of a slot hold the in-window count. Window sizes (lane
/// counts) are far below 2^8.
const COUNT_BITS: u32 = 8;
const COUNT_MASK: u32 = (1 << COUNT_BITS) - 1;

impl BankModel {
    pub fn new(banks: u32) -> Self {
        assert!(banks > 0 && banks.is_power_of_two(), "banks must be a power of two");
        Self { mask: banks - 1, slot: vec![0; banks as usize], generation: 0 }
    }

    /// Cycles a window of destination ids occupies the reduce stage given
    /// base initiation interval `ii`: `max(ii, worst bank collision)`.
    #[inline]
    pub fn window_cycles(&mut self, dsts: &[u32], ii: u32) -> u32 {
        debug_assert!(dsts.len() < COUNT_MASK as usize);
        // wrap before the generation tag would collide with live counts
        self.generation = (self.generation + 1) & (u32::MAX >> COUNT_BITS);
        if self.generation == 0 {
            self.slot.fill(0);
            self.generation = 1;
        }
        let tag = self.generation << COUNT_BITS;
        let mut worst = 0u32;
        for &d in dsts {
            // banks is a power of two and slot.len() == mask + 1, so the
            // index is always in range; the mask also elides bounds checks
            let b = (d & self.mask) as usize;
            let s = self.slot[b];
            // stale generation -> restart the count at 0
            let cnt = if s & !COUNT_MASK == tag { (s & COUNT_MASK) + 1 } else { 1 };
            self.slot[b] = tag | cnt;
            worst = worst.max(cnt);
        }
        worst.max(ii)
    }

    pub fn banks(&self) -> u32 {
        self.mask + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_conflict_when_distinct_banks() {
        let mut m = BankModel::new(16);
        assert_eq!(m.window_cycles(&[0, 1, 2, 3, 4, 5, 6, 7], 1), 1);
    }

    #[test]
    fn full_conflict_serializes() {
        let mut m = BankModel::new(16);
        // all 8 messages to bank 0
        assert_eq!(m.window_cycles(&[0, 16, 32, 48, 64, 80, 96, 112], 1), 8);
    }

    #[test]
    fn ii_floor_respected() {
        let mut m = BankModel::new(16);
        assert_eq!(m.window_cycles(&[0, 1], 2), 2);
        assert_eq!(m.window_cycles(&[0, 16, 32], 2), 3);
    }

    #[test]
    fn generations_do_not_leak_between_windows() {
        let mut m = BankModel::new(4);
        assert_eq!(m.window_cycles(&[0, 4], 1), 2);
        // a fresh window must not see the previous counts
        assert_eq!(m.window_cycles(&[1, 2], 1), 1);
        assert_eq!(m.window_cycles(&[0], 1), 1);
    }

    #[test]
    fn empty_window_costs_ii() {
        let mut m = BankModel::new(8);
        assert_eq!(m.window_cycles(&[], 1), 1);
    }
}
