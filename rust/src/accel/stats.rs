//! Simulation statistics: per-superstep and aggregate cycle accounting.

use crate::dsl::program::Direction;


/// Where the cycles went (per superstep or aggregated).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CycleBreakdown {
    /// Ideal pipeline issue cycles (edges × II / lanes).
    pub compute: u64,
    /// Added serialization from reduce-unit bank conflicts.
    pub conflict: u64,
    /// DDR row-activate cost of starting CSR rows.
    pub row_start: u64,
    /// Random vertex-state DRAM accesses (flows without the BRAM cache).
    pub vertex_random: u64,
    /// Edge-array streaming bandwidth cycles (when it exceeds compute).
    pub stream: u64,
    /// Pipeline fill/drain.
    pub fill_drain: u64,
}

impl CycleBreakdown {
    pub fn total(&self) -> u64 {
        self.compute
            + self.conflict
            + self.row_start
            + self.vertex_random
            + self.stream
            + self.fill_drain
    }

    pub fn add(&mut self, other: &CycleBreakdown) {
        self.compute += other.compute;
        self.conflict += other.conflict;
        self.row_start += other.row_start;
        self.vertex_random += other.vertex_random;
        self.stream += other.stream;
        self.fill_drain += other.fill_drain;
    }
}

/// One superstep's simulation result.
#[derive(Debug, Clone, Copy)]
pub struct SuperstepSim {
    pub index: u32,
    pub edges: u64,
    pub active_vertices: u64,
    /// Traversal direction the engine chose for this superstep (push =
    /// CSR out-edge scatter, pull = CSC in-edge gather).
    pub direction: Direction,
    /// Shards this superstep executed across (0 = monolithic, no
    /// sharding; sharded supersteps record the shard count and derive
    /// `cycles` from the multi-PE critical path).
    pub shards: u32,
    pub cycles: CycleBreakdown,
    /// Host launch overhead (seconds — not cycles; it happens off-chip).
    pub launch_seconds: f64,
}

/// Aggregate over a run.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub supersteps: u32,
    /// How many of `supersteps` ran in the pull (CSC) direction.
    pub pull_supersteps: u32,
    pub total_edges: u64,
    pub cycles: CycleBreakdown,
    pub launch_seconds: f64,
    pub clock_hz: f64,
}

impl SimStats {
    /// On-device execution seconds.
    pub fn device_seconds(&self) -> f64 {
        self.cycles.total() as f64 / self.clock_hz
    }

    /// Full simulated execution seconds (device + launches).
    pub fn exec_seconds(&self) -> f64 {
        self.device_seconds() + self.launch_seconds
    }

    /// Simulated throughput in traversed-edges-per-second.
    pub fn teps(&self) -> f64 {
        if self.total_edges == 0 {
            return 0.0;
        }
        self.total_edges as f64 / self.exec_seconds()
    }

    /// MTEPS, the paper's headline unit.
    pub fn mteps(&self) -> f64 {
        self.teps() / 1.0e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_and_add() {
        let mut a = CycleBreakdown { compute: 10, conflict: 5, ..Default::default() };
        let b = CycleBreakdown { compute: 1, stream: 2, ..Default::default() };
        a.add(&b);
        assert_eq!(a.total(), 18);
    }

    #[test]
    fn mteps_math() {
        let s = SimStats {
            supersteps: 1,
            pull_supersteps: 0,
            total_edges: 1_000_000,
            cycles: CycleBreakdown { compute: 2_500_000, ..Default::default() },
            launch_seconds: 0.0,
            clock_hz: 250.0e6,
        };
        // 2.5e6 cycles @ 250MHz = 10ms -> 100 MTEPS
        assert!((s.mteps() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn zero_edges_zero_teps() {
        let s = SimStats::default();
        assert_eq!(s.teps(), 0.0);
    }
}
