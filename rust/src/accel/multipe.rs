//! Multi-PE partitioned execution model. The paper's runtime scheduler
//! deploys several processing elements, each owning a graph partition
//! (§V-C2); messages crossing partitions travel over the on-card
//! interconnect (the Foregraph-style "interconnection controller" of
//! Table III). This module models that: per-PE pipelines process their
//! own edges in parallel; cut edges add interconnect traffic; superstep
//! time is the slowest PE plus the crossing cost — so partition quality
//! (balance and cut, `prep::partition`) becomes measurable end-to-end.

use super::bram::BankModel;
use super::device::DeviceModel;
use super::stats::CycleBreakdown;
use crate::prep::partition::Partitioning;
use crate::translator::pipeline::PipelineSpec;

/// On-card interconnect between PEs (AXI-stream mesh class numbers).
#[derive(Debug, Clone, Copy)]
pub struct InterconnectModel {
    /// Payload bytes per message (dst id + value).
    pub bytes_per_msg: u32,
    /// Interconnect bandwidth in bytes/cycle (shared).
    pub bytes_per_cycle: f64,
    /// Router latency per superstep (fill).
    pub latency_cycles: u32,
}

impl Default for InterconnectModel {
    fn default() -> Self {
        // 512-bit ring at kernel clock, 8-byte messages
        Self { bytes_per_msg: 8, bytes_per_cycle: 64.0, latency_cycles: 24 }
    }
}

impl InterconnectModel {
    /// Multi-FPGA preset (Foregraph-class, Table III "multiple FPGA"):
    /// PEs on separate cards linked by serial transceivers — two orders
    /// of magnitude less bandwidth and far higher latency than the
    /// on-card ring, which is why cut fraction dominates multi-card
    /// partitioning decisions.
    pub fn multi_fpga() -> Self {
        Self { bytes_per_msg: 8, bytes_per_cycle: 4.0, latency_cycles: 600 }
    }
}

/// Result of one multi-PE superstep.
#[derive(Debug, Clone)]
pub struct MultiPeSuperstep {
    /// Issue+conflict cycles per PE (the slowest bounds the superstep).
    pub pe_cycles: Vec<u64>,
    /// Cut messages routed this superstep.
    pub crossing_msgs: u64,
    /// Interconnect cycles (serialized on the shared ring).
    pub interconnect_cycles: u64,
    /// The superstep's critical path: max(PE) + interconnect.
    pub critical_cycles: u64,
}

/// Simulator for `pes` processing elements executing one design.
#[derive(Debug)]
pub struct MultiPeSimulator {
    pipeline: PipelineSpec,
    interconnect: InterconnectModel,
    banks: Vec<BankModel>,
    /// Aggregate over the run.
    pub total: CycleBreakdown,
    pub total_crossing: u64,
    pub supersteps: u32,
    clock_hz: f64,
}

impl MultiPeSimulator {
    pub fn new(
        device: DeviceModel,
        pipeline: PipelineSpec,
        interconnect: InterconnectModel,
    ) -> Self {
        let pes = pipeline.pes.max(1) as usize;
        Self {
            pipeline,
            interconnect,
            banks: (0..pes).map(|_| BankModel::new(device.reduce_banks)).collect(),
            total: CycleBreakdown::default(),
            total_crossing: 0,
            supersteps: 0,
            clock_hz: device.clock_hz,
        }
    }

    /// Simulate one superstep: `edges` are `(src, dst)` pairs in stream
    /// order; `partitioning.assignment` maps vertices to PEs (the
    /// scheduler's placement collapses parts onto PEs round-robin before
    /// calling this).
    ///
    /// The apply/reduce read-modify-write for every message runs in the
    /// bank of the PE **owning the destination** — a crossing message
    /// consumes the receiving PE's reduce stage, not just interconnect
    /// bandwidth (previously cut edges were charged to the wire only,
    /// making cut-heavy partitions look free on the PE side). The source
    /// PE still streams its outgoing cut edges at pipeline issue rate.
    pub fn superstep(
        &mut self,
        edges: impl Iterator<Item = (u32, u32)>,
        partitioning: &Partitioning,
        pe_of_part: &[u32],
    ) -> MultiPeSuperstep {
        let pes = self.banks.len();
        let lanes = self.pipeline.lanes.max(1) as usize;
        let ii = self.pipeline.ii;
        // per-PE window accumulation buffers
        let mut windows: Vec<Vec<u32>> = vec![Vec::with_capacity(lanes); pes];
        let mut pe_cycles = vec![0u64; pes];
        let mut crossing = 0u64;
        // outgoing cut edges each source PE issues (streamed there,
        // reduced at the destination)
        let mut crossing_issued = vec![0u64; pes];
        for (src, dst) in edges {
            let pe_s = pe_of_part[partitioning.assignment[src as usize] as usize] as usize;
            let pe_d = pe_of_part[partitioning.assignment[dst as usize] as usize] as usize;
            if pe_s != pe_d {
                crossing += 1;
                crossing_issued[pe_s] += 1;
            }
            let w = &mut windows[pe_d];
            w.push(dst);
            if w.len() == lanes {
                pe_cycles[pe_d] += self.banks[pe_d].window_cycles(w, ii) as u64;
                w.clear();
            }
        }
        for (pe, w) in windows.iter().enumerate() {
            if !w.is_empty() {
                pe_cycles[pe] += self.banks[pe].window_cycles(w, ii) as u64;
            }
        }
        for (pe, &issued) in crossing_issued.iter().enumerate() {
            pe_cycles[pe] += ii as u64 * issued.div_ceil(lanes as u64);
        }
        self.finish_superstep(pe_cycles, crossing)
    }

    /// Simulate one superstep from **real per-shard traces** — the entry
    /// point the sharded engine drives. `shard_dsts[s]` is shard `s`'s
    /// destination stream this superstep (the engine's
    /// [`ShardedSuperstepTrace`](crate::engine::ShardedSuperstepTrace)),
    /// `shard_crossing[s]` its boundary messages, and `pe_of_shard[s]`
    /// the PE the scheduler placed it on. Destination ownership means a
    /// shard's whole stream reduces in its own PE's banks; boundary
    /// traffic is serialized on the interconnect.
    pub fn superstep_shards(
        &mut self,
        shard_dsts: &[&[u32]],
        shard_crossing: &[u64],
        pe_of_shard: &[u32],
    ) -> MultiPeSuperstep {
        let pes = self.banks.len();
        let lanes = self.pipeline.lanes.max(1) as usize;
        let ii = self.pipeline.ii;
        let mut pe_cycles = vec![0u64; pes];
        let mut crossing = 0u64;
        for (s, dsts) in shard_dsts.iter().enumerate() {
            let pe = pe_of_shard[s] as usize;
            for w in dsts.chunks(lanes) {
                pe_cycles[pe] += self.banks[pe].window_cycles(w, ii) as u64;
            }
            crossing += shard_crossing[s];
        }
        self.finish_superstep(pe_cycles, crossing)
    }

    fn finish_superstep(&mut self, pe_cycles: Vec<u64>, crossing: u64) -> MultiPeSuperstep {
        let interconnect_cycles = self.interconnect.latency_cycles as u64
            + (crossing as f64 * self.interconnect.bytes_per_msg as f64
                / self.interconnect.bytes_per_cycle) as u64;
        let critical = pe_cycles.iter().copied().max().unwrap_or(0) + interconnect_cycles;
        self.total.compute += critical;
        self.total.fill_drain += self.pipeline.depth as u64;
        self.total_crossing += crossing;
        self.supersteps += 1;
        MultiPeSuperstep {
            pe_cycles,
            crossing_msgs: crossing,
            interconnect_cycles,
            critical_cycles: critical,
        }
    }

    /// Simulated seconds so far.
    pub fn seconds(&self) -> f64 {
        self.total.total() as f64 / self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::prep::partition::{partition, PartitionStrategy};
    use crate::sched::ParallelismPlan;
    use crate::translator::pipeline::schedule;
    use crate::translator::TranslatorKind;

    fn sim(pes: u32) -> MultiPeSimulator {
        let dev = DeviceModel::u200();
        let spec = schedule(TranslatorKind::JGraph, ParallelismPlan::new(8, pes), 20, dev.clock_hz);
        MultiPeSimulator::new(dev, spec, InterconnectModel::default())
    }

    #[test]
    fn balanced_partitions_split_work() {
        let g = generate::erdos_renyi(1_000, 40_000, 3);
        let p = partition(&g, 4, PartitionStrategy::Hash).unwrap();
        let mut s = sim(4);
        let step = s.superstep(g.edges.iter().map(|e| (e.src, e.dst)), &p, &[0, 1, 2, 3]);
        // each PE gets roughly a quarter of the edges' issue cycles
        let max = *step.pe_cycles.iter().max().unwrap() as f64;
        let min = *step.pe_cycles.iter().min().unwrap() as f64;
        assert!(max / min < 1.3, "imbalance {max}/{min}");
    }

    #[test]
    fn skewed_partition_bounds_critical_path() {
        // everything in one part: critical path == that PE's cycles
        let g = generate::erdos_renyi(500, 10_000, 5);
        let mut p = partition(&g, 4, PartitionStrategy::Range).unwrap();
        p.assignment.iter_mut().for_each(|a| *a = 0);
        let mut s = sim(4);
        let step = s.superstep(g.edges.iter().map(|e| (e.src, e.dst)), &p, &[0, 1, 2, 3]);
        assert_eq!(step.pe_cycles[1], 0);
        assert_eq!(step.crossing_msgs, 0);
        assert!(step.critical_cycles >= step.pe_cycles[0]);
    }

    #[test]
    fn cut_edges_cost_interconnect() {
        let g = generate::grid2d(30, 30, 2);
        let hash = partition(&g, 4, PartitionStrategy::Hash).unwrap();
        let grow = partition(&g, 4, PartitionStrategy::BfsGrow).unwrap();
        let run = |p: &Partitioning| {
            let mut s = sim(4);
            let st = s.superstep(g.edges.iter().map(|e| (e.src, e.dst)), p, &[0, 1, 2, 3]);
            st.interconnect_cycles
        };
        assert!(
            run(&grow) < run(&hash),
            "locality-aware partition must cut interconnect cycles"
        );
    }

    #[test]
    fn more_pes_shorter_critical_path() {
        let g = generate::erdos_renyi(2_000, 100_000, 7);
        let crit = |pes: u32, k: usize| {
            let p = partition(&g, k, PartitionStrategy::Hash).unwrap();
            let pe_of: Vec<u32> = (0..k as u32).map(|i| i % pes).collect();
            let mut s = sim(pes);
            s.superstep(g.edges.iter().map(|e| (e.src, e.dst)), &p, &pe_of).critical_cycles
        };
        assert!(crit(4, 4) < crit(1, 4));
    }

    #[test]
    fn multi_fpga_interconnect_punishes_cuts_harder() {
        let g = generate::erdos_renyi(800, 30_000, 4);
        let p = partition(&g, 4, PartitionStrategy::Hash).unwrap();
        let dev = DeviceModel::u200();
        let spec =
            schedule(TranslatorKind::JGraph, ParallelismPlan::new(8, 4), 20, dev.clock_hz);
        let run = |ic: InterconnectModel| {
            let mut s = MultiPeSimulator::new(DeviceModel::u200(), spec, ic);
            s.superstep(g.edges.iter().map(|e| (e.src, e.dst)), &p, &[0, 1, 2, 3])
                .interconnect_cycles
        };
        let on_card = run(InterconnectModel::default());
        let multi_card = run(InterconnectModel::multi_fpga());
        assert!(multi_card > 10 * on_card, "{multi_card} vs {on_card}");
    }

    #[test]
    fn crossing_messages_bill_the_receiving_pe() {
        // 100 edges, all from part-0 sources to part-1 destinations.
        use crate::graph::edgelist::{Edge, EdgeList};
        let edges: Vec<Edge> =
            (0..100u32).map(|i| Edge { src: i, dst: 100 + i, weight: 1.0 }).collect();
        let el = EdgeList { num_vertices: 200, edges };
        let cut = partition(&el, 2, PartitionStrategy::Range).unwrap();
        let mut s = sim(2);
        let step = s.superstep(el.edges.iter().map(|e| (e.src, e.dst)), &cut, &[0, 1]);
        assert_eq!(step.crossing_msgs, 100);
        // the receiving PE does the apply/reduce work for every incoming
        // boundary message...
        assert!(step.pe_cycles[1] > 0, "destination PE must be billed, got {:?}", step.pe_cycles);
        // ...and the source PE still pays to issue the stream
        assert!(step.pe_cycles[0] > 0, "source PE must pay issue cycles, got {:?}", step.pe_cycles);

        // the same edges uncut (everything collapsed into part 0) must be
        // strictly cheaper: no interconnect serialization, no double-side
        // billing
        let mut uncut = cut.clone();
        uncut.assignment.iter_mut().for_each(|a| *a = 0);
        let mut s2 = sim(2);
        let local = s2.superstep(el.edges.iter().map(|e| (e.src, e.dst)), &uncut, &[0, 1]);
        assert_eq!(local.crossing_msgs, 0);
        assert!(
            step.critical_cycles > local.critical_cycles,
            "cut-heavy layout must cost more: cut {} vs uncut {}",
            step.critical_cycles,
            local.critical_cycles
        );
    }

    #[test]
    fn shard_traces_drive_per_pe_banks() {
        let mut s = sim(2);
        // shard 0 on PE 0 (12 conflict-free dsts), shard 1 on PE 1
        // (4 dsts all in one bank), shard 1 reports 3 boundary messages
        let d0: Vec<u32> = (0..12).collect();
        let d1: Vec<u32> = vec![0, 16, 32, 48];
        let step = s.superstep_shards(&[&d0, &d1], &[0, 3], &[0, 1]);
        assert_eq!(step.crossing_msgs, 3);
        assert!(step.pe_cycles[0] > 0 && step.pe_cycles[1] > 0);
        assert_eq!(
            step.critical_cycles,
            step.pe_cycles.iter().copied().max().unwrap() + step.interconnect_cycles
        );
        assert_eq!(s.supersteps, 1);
        assert_eq!(s.total_crossing, 3);
        assert!(s.seconds() > 0.0);
    }

    #[test]
    fn seconds_accumulate() {
        let g = generate::erdos_renyi(100, 2_000, 9);
        let p = partition(&g, 2, PartitionStrategy::Hash).unwrap();
        let mut s = sim(2);
        for _ in 0..3 {
            s.superstep(g.edges.iter().map(|e| (e.src, e.dst)), &p, &[0, 1]);
        }
        assert_eq!(s.supersteps, 3);
        assert!(s.seconds() > 0.0);
    }
}
