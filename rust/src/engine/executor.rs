//! The legacy one-shot executor, kept as a thin **deprecated shim** over
//! the compile-once / run-many lifecycle ([`super::session::Session`] →
//! [`super::compiled::CompiledPipeline`] → [`super::bound::BoundPipeline`]).
//!
//! `Executor::run` re-pays translation bookkeeping, graph preparation, and
//! the modeled bitstream flash on every call — exactly the costs the new
//! API amortizes. It remains so downstream code migrates gradually; see
//! CHANGES.md for the old-call → new-call table.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::graph::edgelist::EdgeList;
use crate::graph::VertexId;
use crate::prep::partition::PartitionStrategy;
use crate::prep::prepared::PrepOptions;
use crate::prep::reorder::ReorderStrategy;
use crate::runtime::KernelRegistry;

use super::compiled::{CompiledPipeline, RunOptions};
use super::metrics::RunReport;
use crate::dsl::program::GasProgram;
use crate::translator::Design;

/// Modeled xclbin flash/configure time (Fig. 5's deployment period):
/// loading a U200 bitstream through XRT takes seconds. Accounted once per
/// compile under the `Session` lifecycle.
pub const FLASH_SECONDS: f64 = 2.5;

/// Acceptable XLA-vs-oracle relative deviation before we declare the
/// artifact wrong (f32 vs f64 accumulation explains small drift on PR).
pub const ORACLE_TOLERANCE: f64 = 1e-3;

/// Execution options of the legacy one-shot API. Mixes per-deployment
/// knobs (`reorder`, `partition`, `use_xla`) with per-query knobs
/// (`root`, `tolerance`) — the new API splits them into
/// [`PrepOptions`] and [`RunOptions`].
#[derive(Debug, Clone)]
#[allow(deprecated)] // the derives touch the deprecated `graph_name` field
#[deprecated(
    since = "0.2.0",
    note = "split into SessionConfig (deployment) + PrepOptions (per graph) \
            + RunOptions (per query)"
)]
pub struct ExecutorConfig {
    /// Source vertex for rooted algorithms.
    pub root: VertexId,
    /// Optional Reorder preprocessing.
    pub reorder: Option<ReorderStrategy>,
    /// Optional Partition preprocessing (parts, strategy).
    pub partition: Option<(usize, PartitionStrategy)>,
    /// Drive the AOT/XLA kernels when the program has one.
    pub use_xla: bool,
    /// Cross-check XLA against the software oracle (costs one extra
    /// software run; the oracle run also feeds the simulator regardless).
    pub verify: bool,
    /// PageRank tolerance.
    pub tolerance: f64,
    /// Label for reports.
    #[deprecated(
        since = "0.2.0",
        note = "graph naming belongs to the graph-loading stage: use \
                PrepOptions::graph_name with CompiledPipeline::load"
    )]
    pub graph_name: String,
    /// Write a per-superstep CSV trace here (None = no trace).
    pub trace_path: Option<std::path::PathBuf>,
}

#[allow(deprecated)]
impl Default for ExecutorConfig {
    fn default() -> Self {
        Self {
            root: 0,
            reorder: None,
            partition: None,
            use_xla: true,
            verify: true,
            tolerance: 1e-6,
            graph_name: "graph".into(),
            trace_path: None,
        }
    }
}

/// The legacy one-shot executor. Reuse one across runs to share the PJRT
/// registry (artifacts compile once per process) — but prefer the
/// lifecycle API, which also amortizes translation, preparation, and
/// flash.
#[deprecated(
    since = "0.2.0",
    note = "use Session::compile(..) -> CompiledPipeline::load(..) -> \
            BoundPipeline::run(..) to pay translate/prep/flash once"
)]
pub struct Executor {
    pub config: ExecutorConfig,
    registry: Option<Arc<KernelRegistry>>,
}

#[allow(deprecated)]
impl Executor {
    pub fn new(config: ExecutorConfig) -> Self {
        Self { config, registry: None }
    }

    /// Inject a shared registry (benches/tests); otherwise opened lazily.
    pub fn with_registry(mut self, registry: Arc<KernelRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    fn registry(&mut self) -> Result<Arc<KernelRegistry>> {
        if let Some(r) = &self.registry {
            return Ok(r.clone());
        }
        let r = Arc::new(KernelRegistry::open_default().context("opening artifact registry")?);
        self.registry = Some(r.clone());
        Ok(r)
    }

    /// Execute `program`'s `design` over `graph`. Returns the full report.
    ///
    /// Every call re-binds: preparation, deployment, and the modeled flash
    /// are charged again. The body delegates to the lifecycle API.
    pub fn run(
        &mut self,
        program: &GasProgram,
        design: &Design,
        graph: &EdgeList,
    ) -> Result<RunReport> {
        // --- admission: the design must fit the device (legacy message)
        let device = crate::accel::device::DeviceModel::u200();
        if !design.fits(&device) {
            bail!(
                "design {:?}/{} does not fit {}",
                design.kind,
                design.program_name,
                device.name
            );
        }

        // Legacy strictness: with XLA requested for a canonical program,
        // a missing artifact registry is an error (the Session lifecycle
        // instead falls back to the software oracle).
        let registry = if self.config.use_xla && program.kind.is_some() {
            Some(self.registry()?)
        } else {
            None
        };

        let compiled = CompiledPipeline::from_parts(
            program.clone(),
            design.clone(),
            device,
            registry,
            FLASH_SECONDS,
            0.0, // no compile stage was timed on this path
        );
        let prep = PrepOptions {
            graph_name: self.config.graph_name.clone(),
            reorder: self.config.reorder,
            partition: self.config.partition,
        };
        let mut bound = compiled.load(graph, prep)?;
        // The shim inherits the lifecycle defaults — including
        // `DirectionPolicy::Adaptive` — because its tested contract is
        // equivalence with `Session`/`BoundPipeline`, not bug-for-bug
        // reproduction of the pre-lifecycle engine. Paper-reproduction
        // paths pin `PushOnly` explicitly (report::tables, the headline
        // band test).
        let mut opts = RunOptions {
            root: self.config.root,
            tolerance: self.config.tolerance,
            use_xla: self.config.use_xla,
            verify: self.config.verify,
            trace_path: self.config.trace_path.clone(),
            ..Default::default()
        };
        // Legacy semantics: the config tolerance governs the run. On
        // programs that declare `tolerance` as a runtime parameter it must
        // arrive as a binding, or the declared default would win.
        if program.params.get("tolerance").is_some() {
            opts.params.set("tolerance", self.config.tolerance);
        }
        bound.run(&opts)
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::engine::metrics::FunctionalPath;
    use crate::graph::generate;
    use crate::translator::Translator;

    fn run_sw(program: &crate::dsl::program::GasProgram, g: &EdgeList) -> RunReport {
        let design = Translator::jgraph().translate(program).unwrap();
        let mut ex = Executor::new(ExecutorConfig {
            use_xla: false,
            graph_name: "test".into(),
            ..Default::default()
        });
        ex.run(program, &design, g).unwrap()
    }

    #[test]
    fn software_path_end_to_end() {
        let g = generate::erdos_renyi(200, 2000, 7);
        let r = run_sw(&algorithms::bfs(), &g);
        assert_eq!(r.functional_path, FunctionalPath::Software);
        assert!(r.simulated_mteps > 0.0);
        assert!(r.rt_seconds > r.compile_seconds);
        assert!(r.supersteps > 0);
        assert_eq!(r.num_vertices, 200);
    }

    #[test]
    fn custom_program_runs_without_kernel() {
        let g = generate::grid2d(10, 10, 1);
        let r = run_sw(&algorithms::widest_path(), &g);
        assert_eq!(r.functional_path, FunctionalPath::Software);
        assert!(r.edges_traversed > 0);
    }

    #[test]
    fn reorder_config_applies() {
        let g = generate::rmat(8, 2000, 0.57, 0.19, 0.19, 3);
        let design = Translator::jgraph().translate(&algorithms::wcc()).unwrap();
        let mut ex = Executor::new(ExecutorConfig {
            use_xla: false,
            reorder: Some(ReorderStrategy::DegreeSort),
            ..Default::default()
        });
        let r = ex.run(&algorithms::wcc(), &design, &g).unwrap();
        assert!(r.prep_seconds > 0.0);
    }

    #[test]
    fn fig5_periods_are_disjoint_and_positive() {
        let g = generate::erdos_renyi(100, 800, 2);
        let r = run_sw(&algorithms::sssp(), &g);
        assert!(r.prep_seconds >= 0.0);
        assert!(r.compile_seconds > 1.0, "modeled synthesis must show up");
        assert!(r.deploy_seconds >= FLASH_SECONDS);
        let sum = r.prep_seconds
            + r.compile_seconds
            + r.deploy_seconds
            + r.sim_exec_seconds
            + r.functional_exec_seconds
            + r.transfer_seconds;
        assert!((r.rt_seconds - sum).abs() < 1e-9);
    }

    #[test]
    fn shim_reports_the_setup_query_split() {
        let g = generate::erdos_renyi(150, 1_000, 4);
        let r = run_sw(&algorithms::bfs(), &g);
        assert!((r.setup_seconds - (r.prep_seconds + r.compile_seconds + r.deploy_seconds)).abs()
            < 1e-12);
        assert!((r.rt_seconds - (r.setup_seconds + r.query_seconds)).abs() < 1e-12);
        assert!(r.query_seconds > 0.0);
    }
}
