//! The end-to-end executor — the paper's Algorithm 1 as code:
//! Read → Layout → (Reorder/Partition) → Get_FPGA_Message → Transport →
//! Set Pipeline/PE → superstep loop → Update vertices.
//!
//! The functional result comes from the AOT/XLA path when the program has
//! a canonical kernel (cross-checked against the software oracle); timing
//! comes from the cycle simulator fed in lockstep with the superstep
//! trace.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::accel::simulator::{AccelSimulator, EdgeBatch};
use crate::comm::CommManager;
use crate::dsl::program::GasProgram;
use crate::graph::csr::Csr;
use crate::graph::edgelist::EdgeList;
use crate::graph::VertexId;
use crate::prep::partition::PartitionStrategy;
use crate::prep::reorder::ReorderStrategy;
use crate::runtime::KernelRegistry;
use crate::sched::{ParallelismPlan, RuntimeScheduler};
use crate::translator::Design;

use super::gas;
use super::metrics::{FunctionalPath, RunReport};
use super::xla_engine;

/// Modeled xclbin flash/configure time (Fig. 5's deployment period):
/// loading a U200 bitstream through XRT takes seconds.
pub const FLASH_SECONDS: f64 = 2.5;

/// Acceptable XLA-vs-oracle relative deviation before we declare the
/// artifact wrong (f32 vs f64 accumulation explains small drift on PR).
pub const ORACLE_TOLERANCE: f64 = 1e-3;

/// Execution options.
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Source vertex for rooted algorithms.
    pub root: VertexId,
    /// Optional Reorder preprocessing.
    pub reorder: Option<ReorderStrategy>,
    /// Optional Partition preprocessing (parts, strategy).
    pub partition: Option<(usize, PartitionStrategy)>,
    /// Drive the AOT/XLA kernels when the program has one.
    pub use_xla: bool,
    /// Cross-check XLA against the software oracle (costs one extra
    /// software run; the oracle run also feeds the simulator regardless).
    pub verify: bool,
    /// PageRank tolerance.
    pub tolerance: f64,
    /// Label for reports.
    pub graph_name: String,
    /// Write a per-superstep CSV trace here (None = no trace).
    pub trace_path: Option<std::path::PathBuf>,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        Self {
            root: 0,
            reorder: None,
            partition: None,
            use_xla: true,
            verify: true,
            tolerance: 1e-6,
            graph_name: "graph".into(),
            trace_path: None,
        }
    }
}

/// The executor. Reuse one across runs to share the PJRT registry
/// (artifacts compile once per process).
pub struct Executor {
    pub config: ExecutorConfig,
    registry: Option<Arc<KernelRegistry>>,
}

impl Executor {
    pub fn new(config: ExecutorConfig) -> Self {
        Self { config, registry: None }
    }

    /// Inject a shared registry (benches/tests); otherwise opened lazily.
    pub fn with_registry(mut self, registry: Arc<KernelRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    fn registry(&mut self) -> Result<Arc<KernelRegistry>> {
        if let Some(r) = &self.registry {
            return Ok(r.clone());
        }
        let r = Arc::new(KernelRegistry::open_default().context("opening artifact registry")?);
        self.registry = Some(r.clone());
        Ok(r)
    }

    /// Execute `program`'s `design` over `graph`. Returns the full report.
    pub fn run(
        &mut self,
        program: &GasProgram,
        design: &Design,
        graph: &EdgeList,
    ) -> Result<RunReport> {
        // --- preparation period: Layout (+ Reorder / Partition)
        let t_prep = Instant::now();
        let working = match self.config.reorder {
            Some(strategy) => crate::prep::reorder::reorder(graph, strategy).0,
            None => graph.clone(),
        };
        if let Some((parts, strategy)) = self.config.partition {
            // partitioning feeds PE placement; cut stats land in traces
            let p = crate::prep::partition::partition(&working, parts, strategy)?;
            let _ = p.cut_edges; // recorded by benches; placement below
        }
        let csr = Csr::from_edgelist(&working);
        let prep_seconds = t_prep.elapsed().as_secs_f64();

        // --- deployment period: flash + transport
        let mut comm = CommManager::new();
        let plan = ParallelismPlan::new(design.pipeline.lanes, design.pipeline.pes);
        comm.shell
            .configure(&format!("{}.xclbin", design.program_name), plan.pipelines, plan.pes)?;
        let transfer = comm.transport_graph(&csr)?;
        let deploy_seconds = FLASH_SECONDS + transfer.seconds;

        // --- admission: the design must fit the device
        let device = crate::accel::device::DeviceModel::u200();
        if !design.fits(&device) {
            anyhow::bail!(
                "design {:?}/{} does not fit {}",
                design.kind,
                design.program_name,
                device.name
            );
        }
        let mut scheduler = RuntimeScheduler::admit(
            plan,
            &design.resources,
            &device,
            program.max_supersteps(csr.num_vertices()).max(200),
        )?;

        // --- functional run (software oracle) in lockstep with the
        //     cycle simulator
        let mut sim = AccelSimulator::new(device, design.pipeline);
        let mut trace_log = super::trace::Trace::default();
        let want_trace = self.config.trace_path.is_some();
        let bytes_per_edge = if program.uses_weights { 12 } else { 8 };
        let gap = gas::avg_edge_gap(&csr);
        let oracle = gas::run(program, &csr, self.config.root, |trace| {
            let _ = scheduler.begin_superstep(trace.active_rows as usize);
            let step = sim.superstep(&EdgeBatch {
                dsts: trace.dsts,
                active_rows: trace.active_rows,
                bytes_per_edge,
                avg_edge_gap: gap,
            });
            if want_trace {
                trace_log.record(step);
            }
            scheduler.end_superstep(trace.dsts.len());
        })?;
        scheduler.converged();
        let sim_stats = sim.finish();

        // --- XLA path for canonical programs
        let mut functional_path = FunctionalPath::Software;
        let mut functional_exec_seconds = 0.0;
        let mut oracle_deviation = None;
        let mut edges_traversed = oracle.edges_traversed;
        let mut supersteps = oracle.supersteps;
        if self.config.use_xla {
            if let Some(kind) = program.kind {
                let registry = self.registry()?;
                let xla = xla_engine::run(
                    &registry,
                    kind,
                    &csr,
                    self.config.root,
                    self.config.tolerance,
                )?;
                functional_path = FunctionalPath::Xla;
                functional_exec_seconds = xla.exec_seconds;
                edges_traversed = xla.edges_traversed.max(edges_traversed);
                supersteps = xla.supersteps;
                if self.config.verify {
                    let dev = xla_engine::max_deviation(&xla.values, &oracle.values);
                    if dev > ORACLE_TOLERANCE {
                        anyhow::bail!(
                            "XLA functional result deviates from the software \
                             oracle by {dev:.3e} (> {ORACLE_TOLERANCE:.0e})"
                        );
                    }
                    oracle_deviation = Some(dev);
                }
            }
        }

        // results DMA back (vertex values)
        comm.read_back(4 * csr.num_vertices() as u64);

        if let Some(path) = &self.config.trace_path {
            trace_log.write_csv(path)?;
        }

        let compile_seconds = design.compile_seconds();
        let sim_exec_seconds = sim_stats.exec_seconds();
        Ok(RunReport {
            program: program.name.clone(),
            translator: design.kind.label(),
            graph_name: self.config.graph_name.clone(),
            num_vertices: csr.num_vertices(),
            num_edges: csr.num_edges(),
            prep_seconds,
            compile_seconds,
            deploy_seconds,
            sim_exec_seconds,
            functional_exec_seconds,
            functional_path,
            supersteps,
            edges_traversed,
            hdl_lines: design.hdl_lines,
            rt_seconds: prep_seconds + compile_seconds + deploy_seconds + sim_exec_seconds,
            simulated_mteps: sim_stats.mteps(),
            sim: sim_stats,
            oracle_deviation,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::graph::generate;
    use crate::translator::Translator;

    fn run_sw(program: &crate::dsl::program::GasProgram, g: &EdgeList) -> RunReport {
        let design = Translator::jgraph().translate(program).unwrap();
        let mut ex = Executor::new(ExecutorConfig {
            use_xla: false,
            graph_name: "test".into(),
            ..Default::default()
        });
        ex.run(program, &design, g).unwrap()
    }

    #[test]
    fn software_path_end_to_end() {
        let g = generate::erdos_renyi(200, 2000, 7);
        let r = run_sw(&algorithms::bfs(), &g);
        assert_eq!(r.functional_path, FunctionalPath::Software);
        assert!(r.simulated_mteps > 0.0);
        assert!(r.rt_seconds > r.compile_seconds);
        assert!(r.supersteps > 0);
        assert_eq!(r.num_vertices, 200);
    }

    #[test]
    fn custom_program_runs_without_kernel() {
        let g = generate::grid2d(10, 10, 1);
        let r = run_sw(&algorithms::widest_path(), &g);
        assert_eq!(r.functional_path, FunctionalPath::Software);
        assert!(r.edges_traversed > 0);
    }

    #[test]
    fn reorder_config_applies() {
        let g = generate::rmat(8, 2000, 0.57, 0.19, 0.19, 3);
        let design = Translator::jgraph().translate(&algorithms::wcc()).unwrap();
        let mut ex = Executor::new(ExecutorConfig {
            use_xla: false,
            reorder: Some(ReorderStrategy::DegreeSort),
            ..Default::default()
        });
        let r = ex.run(&algorithms::wcc(), &design, &g).unwrap();
        assert!(r.prep_seconds > 0.0);
    }

    #[test]
    fn fig5_periods_are_disjoint_and_positive() {
        let g = generate::erdos_renyi(100, 800, 2);
        let r = run_sw(&algorithms::sssp(), &g);
        assert!(r.prep_seconds >= 0.0);
        assert!(r.compile_seconds > 1.0, "modeled synthesis must show up");
        assert!(r.deploy_seconds >= FLASH_SECONDS);
        let sum = r.prep_seconds + r.compile_seconds + r.deploy_seconds + r.sim_exec_seconds;
        assert!((r.rt_seconds - sum).abs() < 1e-9);
    }
}
