//! Per-superstep execution traces: CSV rows for offline analysis/plotting
//! (frontier growth, stall composition over time). Enabled with
//! `ExecutorConfig::trace_path` or `jgraph run --trace out.csv`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::accel::stats::SuperstepSim;
use crate::dsl::program::Direction;

/// Collects superstep samples during a run.
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub rows: Vec<SuperstepSim>,
}

impl Trace {
    pub fn record(&mut self, s: SuperstepSim) {
        self.rows.push(s);
    }

    /// CSV header + one row per superstep.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "superstep,edges,active_vertices,compute,conflict,row_start,\
             vertex_random,stream,fill_drain,total_cycles,launch_seconds,\
             direction,shards\n",
        );
        for r in &self.rows {
            out += &format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.index,
                r.edges,
                r.active_vertices,
                r.cycles.compute,
                r.cycles.conflict,
                r.cycles.row_start,
                r.cycles.vertex_random,
                r.cycles.stream,
                r.cycles.fill_drain,
                r.cycles.total(),
                r.launch_seconds,
                match r.direction {
                    Direction::Push => "push",
                    Direction::Pull => "pull",
                },
                r.shards,
            );
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_csv())
            .with_context(|| format!("writing trace to {:?}", path.as_ref()))
    }

    /// Frontier profile: active vertices per superstep (BFS's ramp).
    pub fn frontier_profile(&self) -> Vec<u64> {
        self.rows.iter().map(|r| r.active_vertices).collect()
    }

    /// Direction chosen per superstep (the adaptive engine's push/pull
    /// trajectory).
    pub fn direction_profile(&self) -> Vec<Direction> {
        self.rows.iter().map(|r| r.direction).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::stats::CycleBreakdown;

    fn sample(i: u32, edges: u64) -> SuperstepSim {
        SuperstepSim {
            index: i,
            edges,
            active_vertices: edges / 2,
            direction: if i % 2 == 0 { Direction::Push } else { Direction::Pull },
            shards: 0,
            cycles: CycleBreakdown { compute: 10 * edges, ..Default::default() },
            launch_seconds: 5e-6,
        }
    }

    #[test]
    fn csv_shape() {
        let mut t = Trace::default();
        t.record(sample(0, 4));
        t.record(sample(1, 8));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().starts_with("0,4,2,40,"));
        assert!(csv.lines().next().unwrap().ends_with(",direction,shards"));
        assert!(csv.lines().nth(1).unwrap().ends_with(",push,0"));
        assert!(csv.lines().nth(2).unwrap().ends_with(",pull,0"));
        assert_eq!(t.frontier_profile(), vec![2, 4]);
        assert_eq!(t.direction_profile(), vec![Direction::Push, Direction::Pull]);
    }

    #[test]
    fn write_and_readback() {
        let mut t = Trace::default();
        t.record(sample(0, 100));
        let p = std::env::temp_dir().join("jgraph_trace.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("superstep,edges"));
    }
}
