//! Execution engine: ties the DSL, translator, scheduler, communication
//! manager, cycle simulator, and the AOT/XLA runtime into the paper's
//! Algorithm 1 flow. See [`executor::Executor`] for the entry point,
//! [`gas`] for the software oracle, and [`xla_engine`] for the AOT path.

pub mod executor;
pub mod gas;
pub mod metrics;
pub mod trace;
pub mod xla_engine;

pub use executor::{Executor, ExecutorConfig};
pub use gas::{GasResult, SuperstepTrace};
pub use metrics::{FunctionalPath, RunReport};
pub use trace::Trace;
pub use xla_engine::XlaRunResult;
