//! Execution engine: ties the DSL, translator, scheduler, communication
//! manager, cycle simulator, and the AOT/XLA runtime into the paper's
//! Algorithm 1 flow — as a **compile-once / run-many lifecycle**:
//!
//! * [`session::Session`] owns process-wide state (device model, default
//!   translator, the lazily-opened PJRT [`crate::runtime::KernelRegistry`]);
//! * [`compiled::CompiledPipeline`] is one program translated, scheduled,
//!   and (modeled) flashed, exactly once;
//! * [`bound::BoundPipeline`] binds a compiled pipeline to a
//!   [`crate::prep::PreparedGraph`] and serves cheap per-query
//!   [`compiled::RunOptions`]-driven runs.
//!
//! ## The `&self` query model
//!
//! A binding is **immutable while serving queries**: scheduler admission
//! happens once at bind time ([`crate::sched::AdmittedPlan`]), and every
//! piece of per-query mutable state — the superstep scheduler, the cycle
//! simulator, the trace log, the query's DMA records — lives in a
//! per-query [`bound::QueryContext`]. [`bound::BoundPipeline::query`]
//! therefore takes `&self`, and [`bound::BoundPipeline::run_batch_parallel`]
//! fans a multi-root sweep out over OS threads sharing one binding, with
//! every modeled report field identical to the sequential path and DMA
//! accounting merged deterministically after the join. `run(&mut
//! self)`/`run_batch` remain as compatibility wrappers over the same core.
//!
//! ## Direction-optimizing execution
//!
//! The software oracle ([`gas`]) runs each superstep **push** (stream the
//! frontier's out-edges over the CSR) or **pull** (sweep in-edges over
//! the CSC cached in [`crate::prep::PreparedGraph`]), chosen per
//! superstep by the standard frontier-size heuristic over a hybrid
//! sparse-list/bitmap frontier ([`frontier::Frontier`]). Adaptive
//! execution is **bit-identical** to the push-only reference in `values`
//! and `supersteps` (property-tested); the per-superstep choice travels
//! through the lockstep trace ([`gas::SuperstepTrace::direction`]) into
//! the simulator and lands in [`metrics::RunReport::pull_supersteps`].
//!
//! ## Runtime parameters
//!
//! Programs may declare named parameters ([`crate::dsl::params`]); values
//! bind **per query** via [`compiled::RunOptions::bind`] and are resolved
//! against the declared signature inside the query core — the program is
//! [`crate::dsl::program::GasProgram::instantiate`]d once per query, the
//! compiled design and binding are shared across every value, and binding
//! mistakes surface as typed [`crate::dsl::params::ParamError`]s. A batch
//! can therefore sweep parameters as well as roots.
//!
//! Every [`metrics::RunReport`] satisfies `rt_seconds = setup_seconds +
//! query_seconds` with `query_seconds = sim_exec_seconds +
//! functional_exec_seconds + transfer_seconds` — on both functional paths.
//!
//! The legacy one-shot [`executor::Executor`] remains as a deprecated shim
//! delegating to the lifecycle. See [`gas`] for the software oracle and
//! [`xla_engine`] for the AOT path.

pub mod bound;
pub mod compiled;
pub mod executor;
pub mod frontier;
pub mod gas;
pub mod metrics;
pub mod session;
pub mod sharded;
pub mod trace;
pub mod xla_engine;

pub use bound::{BoundPipeline, QueryFailure};
pub use compiled::{CompiledPipeline, RunOptions};
#[allow(deprecated)]
pub use executor::{Executor, ExecutorConfig};
pub use frontier::Frontier;
pub use gas::{Crossover, DirectionPolicy, EngineGraph, GasResult, SuperstepTrace};
pub use metrics::{FunctionalPath, RunReport};
pub use session::{CompileError, Session, SessionConfig};
pub use sharded::{run_sharded, run_sharded_with_faults, ShardedRun, ShardedSuperstepTrace};
pub use trace::Trace;
pub use xla_engine::XlaRunResult;
