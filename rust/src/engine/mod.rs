//! Execution engine: ties the DSL, translator, scheduler, communication
//! manager, cycle simulator, and the AOT/XLA runtime into the paper's
//! Algorithm 1 flow — as a **compile-once / run-many lifecycle**:
//!
//! * [`session::Session`] owns process-wide state (device model, default
//!   translator, the lazily-opened PJRT [`crate::runtime::KernelRegistry`]);
//! * [`compiled::CompiledPipeline`] is one program translated, scheduled,
//!   and (modeled) flashed, exactly once;
//! * [`bound::BoundPipeline`] binds a compiled pipeline to a
//!   [`crate::prep::PreparedGraph`] and serves cheap per-query
//!   [`compiled::RunOptions`]-driven runs.
//!
//! The legacy one-shot [`executor::Executor`] remains as a deprecated shim
//! delegating to the lifecycle. See [`gas`] for the software oracle and
//! [`xla_engine`] for the AOT path.

pub mod bound;
pub mod compiled;
pub mod executor;
pub mod gas;
pub mod metrics;
pub mod session;
pub mod trace;
pub mod xla_engine;

pub use bound::BoundPipeline;
pub use compiled::{CompiledPipeline, RunOptions};
#[allow(deprecated)]
pub use executor::{Executor, ExecutorConfig};
pub use gas::{GasResult, SuperstepTrace};
pub use metrics::{FunctionalPath, RunReport};
pub use session::{CompileError, Session, SessionConfig};
pub use trace::Trace;
pub use xla_engine::XlaRunResult;
