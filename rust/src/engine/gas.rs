//! Software GAS engine — the rust-side functional oracle. Interprets any
//! [`GasProgram`] (including custom ones with no AOT kernel) edge-by-edge,
//! emitting a per-superstep trace the accelerator simulator consumes in
//! lockstep. The AOT/XLA path ([`super::xla_engine`]) is cross-checked
//! against this engine for the five canonical algorithms.

use anyhow::Result;

use crate::dsl::apply::ApplyEnv;
use crate::dsl::params::ParamSet;
use crate::dsl::program::{
    Convergence, EdgeOpKind, FrontierPolicy, GasProgram, InitPolicy, ReduceOp, Writeback,
};
use crate::graph::csr::Csr;
use crate::graph::VertexId;

/// Per-superstep trace passed to the lockstep observer (the simulator).
pub struct SuperstepTrace<'a> {
    pub index: u32,
    /// Destination vertex of every edge processed this superstep, stream
    /// order.
    pub dsts: &'a [u32],
    /// Active CSR rows this superstep.
    pub active_rows: u64,
}

/// Result of a run.
#[derive(Debug, Clone)]
pub struct GasResult {
    /// Final vertex values (f64-interpreted; i32 programs hold integers).
    pub values: Vec<f64>,
    pub supersteps: u32,
    pub edges_traversed: u64,
    /// Whether the program's own convergence condition was met. `false`
    /// means the interpreter's internal superstep bound expired first —
    /// the values are a truncated fixpoint iteration, not an answer. The
    /// engine turns this into an iteration-cap error; standalone callers
    /// can decide for themselves.
    pub converged: bool,
}

/// PageRank constants matching python/compile/kernels/ref.py.
const PR_MAX_ITERS: u32 = 200;

/// Run `program` over `graph` from `root` (ignored by non-rooted
/// programs). `observer` sees each superstep's edge trace before state is
/// committed — the simulator hooks in here.
pub fn run(
    program: &GasProgram,
    graph: &Csr,
    root: VertexId,
    mut observer: impl FnMut(&SuperstepTrace<'_>),
) -> Result<GasResult> {
    run_observed(program, graph, root, |trace| {
        observer(trace);
        Ok(())
    })
}

/// Like [`run`], but the observer is fallible: an `Err` **aborts the run
/// before the superstep's state is committed** and propagates out. This is
/// how the engine enforces the scheduler's iteration cap — the safety net
/// against non-converging programs must stop the loop, not merely log.
pub fn run_observed(
    program: &GasProgram,
    graph: &Csr,
    root: VertexId,
    mut observer: impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    // A still-parameterized program closes over its declared defaults
    // here; the engine lifecycle instantiates with the query's ParamSet
    // *before* calling in, so this is the standalone-caller convenience.
    let owned;
    let program = if program.has_runtime_params() {
        owned = program.instantiate(&ParamSet::new())?;
        &owned
    } else {
        program
    };
    if program.kind == Some(EdgeOpKind::Pr)
        || matches!(program.writeback, Writeback::DampedSum(_))
    {
        return run_pagerank(program, graph, &mut observer);
    }
    run_generic(program, graph, root, &mut observer)
}

fn init_values(program: &GasProgram, n: usize, root: VertexId) -> Vec<f64> {
    match &program.init {
        InitPolicy::RootAndDefault { root_value, default } => {
            let mut v = vec![default.lit(); n];
            if (root as usize) < n {
                v[root as usize] = root_value.lit();
            }
            v
        }
        InitPolicy::VertexId => (0..n).map(|i| i as f64).collect(),
        InitPolicy::UniformFraction => vec![1.0 / n.max(1) as f64; n],
        InitPolicy::Constant(c) => vec![c.lit(); n],
    }
}

fn reduce_identity(op: ReduceOp) -> f64 {
    match op {
        ReduceOp::Min => f64::INFINITY,
        ReduceOp::Max => f64::NEG_INFINITY,
        ReduceOp::Sum => 0.0,
    }
}

fn reduce_combine(op: ReduceOp, a: f64, b: f64) -> f64 {
    match op {
        ReduceOp::Min => a.min(b),
        ReduceOp::Max => a.max(b),
        ReduceOp::Sum => a + b,
    }
}

fn run_generic(
    program: &GasProgram,
    graph: &Csr,
    root: VertexId,
    observer: &mut impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    let n = graph.num_vertices();
    let mut values = init_values(program, n, root);
    let unvisited = match &program.init {
        InitPolicy::RootAndDefault { default, .. } => default.lit(),
        _ => f64::NAN,
    };

    // initial frontier
    let mut frontier: Vec<VertexId> = match (program.frontier, &program.init) {
        (FrontierPolicy::Active, InitPolicy::RootAndDefault { .. }) => vec![root],
        _ => (0..n as VertexId).collect(),
    };

    // Bounded-depth traversal: converging at the depth horizon is a met
    // condition (a legitimate answer), unlike exhausting `max_steps`.
    let depth_cap: f64 =
        program.depth_limit.as_ref().map(|s| s.lit()).unwrap_or(f64::INFINITY);

    let max_steps = program.max_supersteps(n);
    let mut edges_traversed = 0u64;
    let mut supersteps = 0u32;
    // Specialize the Apply expression once (the software analogue of the
    // translator's fixed ALU chain); the general tree interpreter remains
    // the fallback for custom expressions. §Perf: ~2x on the oracle loop.
    let compiled = crate::dsl::apply::CompiledApply::compile(&program.apply);
    // reused scratch (hot loop: no per-superstep allocation)
    let mut acc = vec![reduce_identity(program.reduce); n];
    let mut touched_flag = vec![false; n];
    let mut touched: Vec<VertexId> = Vec::with_capacity(n);
    let mut dsts: Vec<u32> = Vec::new();

    let mut converged = false;
    for iter in 0..max_steps {
        if frontier.is_empty() {
            converged = true;
            break;
        }
        dsts.clear();
        touched.clear();

        // constant-per-superstep messages (BFS) evaluate once, not per edge
        let const_msg = program.apply.eval(&ApplyEnv {
            src_value: 0.0,
            dst_value: 0.0,
            edge_weight: 0.0,
            iter_count: iter as f64,
        });
        for &u in &frontier {
            let src_value = values[u as usize];
            for (_, v, w) in graph.row_edges(u) {
                use crate::dsl::apply::CompiledApply as C;
                let msg = match compiled {
                    C::ConstPerIter => const_msg,
                    C::Src => src_value,
                    C::SrcPlusWeight => src_value + w as f64,
                    C::SrcTimesWeight => src_value * w as f64,
                    C::General => program.apply.eval(&ApplyEnv {
                        src_value,
                        dst_value: values[v as usize],
                        edge_weight: w as f64,
                        iter_count: iter as f64,
                    }),
                };
                if !touched_flag[v as usize] {
                    touched_flag[v as usize] = true;
                    touched.push(v);
                }
                let slot = &mut acc[v as usize];
                *slot = reduce_combine(program.reduce, *slot, msg);
                dsts.push(v);
            }
        }
        edges_traversed += dsts.len() as u64;

        observer(&SuperstepTrace { index: iter, dsts: &dsts, active_rows: frontier.len() as u64 })?;

        // writeback
        let mut next_frontier: Vec<VertexId> = Vec::new();
        let mut changed = 0usize;
        // Sweep-overwrite semantics (SpMV/degree-count): vertices that
        // received no message this sweep take the Sum identity (y = A·x
        // leaves rows without nonzeros at 0), matching the XLA kernels'
        // `zeros().at[dst].add(...)` shape. Must run before the touched
        // loop clears the flags.
        if program.writeback == Writeback::Overwrite
            && program.frontier == FrontierPolicy::All
            && program.reduce == ReduceOp::Sum
        {
            for v in 0..n {
                if !touched_flag[v] && values[v] != 0.0 {
                    values[v] = 0.0;
                    changed += 1;
                }
            }
        }
        for &v in &touched {
            let reduced = acc[v as usize];
            let old = values[v as usize];
            let new = match program.writeback {
                Writeback::MinCombine => old.min(reduced),
                Writeback::MaxCombine => old.max(reduced),
                Writeback::IfUnvisited => {
                    if old == unvisited || (old.is_nan() && unvisited.is_nan()) {
                        reduced
                    } else {
                        old
                    }
                }
                Writeback::Overwrite => reduced,
                Writeback::DampedSum(_) => unreachable!("damped programs run in run_pagerank"),
            };
            if new != old {
                values[v as usize] = new;
                changed += 1;
                next_frontier.push(v);
            }
            acc[v as usize] = reduce_identity(program.reduce);
            touched_flag[v as usize] = false;
        }
        supersteps = iter + 1;

        // convergence
        let done = match &program.convergence {
            Convergence::EmptyFrontier => next_frontier.is_empty(),
            Convergence::NoChange => changed == 0,
            Convergence::FixedIterations(k) => supersteps >= *k,
            Convergence::DeltaBelow(_) => unreachable!("PR handled separately"),
        } || supersteps as f64 >= depth_cap;
        if done {
            converged = true;
            break;
        }
        frontier = match program.frontier {
            FrontierPolicy::Active => {
                next_frontier.sort_unstable();
                next_frontier.dedup();
                next_frontier
            }
            FrontierPolicy::All => (0..n as VertexId).collect(),
        };
    }

    Ok(GasResult { values, supersteps, edges_traversed, converged })
}

/// PageRank with damping + uniform dangling redistribution, numerically
/// matching python/compile/kernels/ref.py::pr_step. Both constants come
/// from the (instantiated) program: damping from the `DampedSum`
/// writeback, tolerance from the `DeltaBelow` convergence — the engine
/// honors the query's bound values, never a baked-in default.
fn run_pagerank(
    program: &GasProgram,
    graph: &Csr,
    observer: &mut impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    let damping = match &program.writeback {
        Writeback::DampedSum(d) => d.lit(),
        // Pr-kind programs hand-built with a plain Overwrite writeback
        // keep the reference kernel's constant.
        _ => 0.85,
    };
    let tol = match &program.convergence {
        Convergence::DeltaBelow(t) => t.lit(),
        _ => 1e-6,
    };
    let n = graph.num_vertices();
    let nf = n.max(1) as f64;
    let mut rank = vec![1.0 / nf; n];
    let out_deg: Vec<u32> = (0..n as VertexId).map(|v| graph.degree(v)).collect();
    // Edge stream in CSR row-major order — the exact order the accelerator
    // streams `Edges` and the order every other algorithm's trace uses.
    // (Deriving it through `to_edgelist()` routes the stream through an
    // intermediate representation whose ordering is not contractual, which
    // would skew the simulator's bank-conflict model if it ever diverged.)
    let all_dsts: Vec<u32> = (0..n as VertexId)
        .flat_map(|v| graph.row_edges(v).map(|(_, d, _)| d))
        .collect();
    let mut edges_traversed = 0u64;
    let mut supersteps = 0u32;
    let mut converged = false;

    for iter in 0..PR_MAX_ITERS {
        let mut sums = vec![0f64; n];
        for v in 0..n as VertexId {
            let contrib = rank[v as usize] / out_deg[v as usize].max(1) as f64;
            for (_, d, _) in graph.row_edges(v) {
                sums[d as usize] += contrib;
            }
        }
        edges_traversed += graph.num_edges() as u64;
        observer(&SuperstepTrace { index: iter, dsts: &all_dsts, active_rows: n as u64 })?;

        let dangling: f64 = (0..n)
            .filter(|&v| out_deg[v] == 0)
            .map(|v| rank[v])
            .sum();
        let base = (1.0 - damping) / nf + damping * dangling / nf;
        let mut delta = 0.0;
        let mut new_rank = vec![0f64; n];
        for v in 0..n {
            new_rank[v] = base + damping * sums[v];
            delta += (new_rank[v] - rank[v]).abs();
        }
        rank = new_rank;
        supersteps = iter + 1;
        if delta < tol {
            converged = true;
            break;
        }
    }
    Ok(GasResult { values: rank, supersteps, edges_traversed, converged })
}

/// Naive reference PageRank (damping + uniform dangling redistribution)
/// for a fixed iteration count, written independently of [`run_pagerank`]
/// — no shared constants, no early exit. Test-support only: both the unit
/// suite and the integration suite check the engine against this one
/// implementation so the reference cannot drift between them.
#[doc(hidden)]
pub fn reference_pagerank(graph: &Csr, damping: f64, iters: u32) -> Vec<f64> {
    let n = graph.num_vertices();
    let nf = n as f64;
    let mut rank = vec![1.0 / nf; n];
    for _ in 0..iters {
        let mut next = vec![0.0f64; n];
        let mut dangling = 0.0;
        for v in 0..n as VertexId {
            let deg = graph.degree(v);
            if deg == 0 {
                dangling += rank[v as usize];
                continue;
            }
            let share = rank[v as usize] / deg as f64;
            for (_, d, _) in graph.row_edges(v) {
                next[d as usize] += share;
            }
        }
        for slot in next.iter_mut() {
            *slot = (1.0 - damping) / nf + damping * (*slot + dangling / nf);
        }
        rank = next;
    }
    rank
}

/// Average |src-dst| gap of a CSR graph (locality input for the
/// simulator).
pub fn avg_edge_gap(graph: &Csr) -> f64 {
    let m = graph.num_edges();
    if m == 0 {
        return 0.0;
    }
    let mut total = 0u64;
    for v in 0..graph.num_vertices() as VertexId {
        for (_, d, _) in graph.row_edges(v) {
            total += (v as i64 - d as i64).unsigned_abs();
        }
    }
    total as f64 / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::graph::{edgelist::EdgeList, generate};

    fn csr(el: &EdgeList) -> Csr {
        Csr::from_edgelist(el)
    }

    fn run_silent(p: &crate::dsl::program::GasProgram, g: &Csr, root: u32) -> GasResult {
        run(p, g, root, |_| {}).unwrap()
    }

    #[test]
    fn bfs_levels_on_diamond() {
        let g = csr(&EdgeList::from_pairs([(0, 1), (0, 2), (1, 3), (2, 3)]));
        let r = run_silent(&algorithms::bfs(), &g, 0);
        assert_eq!(r.values, vec![0.0, 1.0, 1.0, 2.0]);
        assert_eq!(r.edges_traversed, 4);
    }

    #[test]
    fn bfs_unreachable_stays_unvisited() {
        let mut el = EdgeList::from_pairs([(0, 1)]);
        el.num_vertices = 3; // vertex 2 isolated
        let r = run_silent(&algorithms::bfs(), &csr(&el), 0);
        assert_eq!(r.values[2], -1.0);
    }

    #[test]
    fn bfs_on_chain_takes_n_minus_1_steps() {
        let g = csr(&generate::chain(6));
        let r = run_silent(&algorithms::bfs(), &g, 0);
        assert_eq!(r.values[5], 5.0);
        // 5 discovery supersteps + 1 final sweep that finds the frontier
        // empty (the paper's `while Get_active_vertex()` does the same)
        assert_eq!(r.supersteps, 6);
    }

    #[test]
    fn sssp_matches_dijkstra_intuition() {
        // 0 ->(1) 1 ->(1) 2, and 0 ->(5) 2: shortest is 2
        let mut el = EdgeList::default();
        el.push(0, 1, 1.0);
        el.push(1, 2, 1.0);
        el.push(0, 2, 5.0);
        let r = run_silent(&algorithms::sssp(), &csr(&el), 0);
        assert_eq!(r.values[2], 2.0);
    }

    #[test]
    fn wcc_labels_components() {
        let mut el = EdgeList::from_pairs([(0, 1), (1, 0), (2, 3), (3, 2)]);
        el.num_vertices = 4;
        let r = run_silent(&algorithms::wcc(), &csr(&el), 0);
        assert_eq!(r.values, vec![0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn pagerank_sums_to_one_and_ranks_hub_highest() {
        use crate::dsl::params::ParamSet;
        let g = csr(&generate::star(20)); // hub 0
        let p = algorithms::pagerank()
            .instantiate(&ParamSet::new().bind("tolerance", 1e-9))
            .unwrap();
        let r = run_silent(&p, &g, 0);
        let sum: f64 = r.values.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum={sum}");
        let hub = r.values[0];
        assert!(r.values[1..].iter().all(|&v| v < hub));
    }

    #[test]
    fn spmv_is_one_matvec() {
        // y[dst] += w * x[src], x = 1
        let mut el = EdgeList::default();
        el.push(0, 1, 2.0);
        el.push(0, 2, 3.0);
        el.push(1, 2, 4.0);
        let r = run_silent(&algorithms::spmv(), &csr(&el), 0);
        assert_eq!(r.supersteps, 1);
        assert_eq!(r.values, vec![0.0, 2.0, 7.0]);
    }

    #[test]
    fn degree_count_counts_in_degrees() {
        let el = EdgeList::from_pairs([(0, 2), (1, 2), (0, 1)]);
        let r = run_silent(&algorithms::degree_count(), &csr(&el), 0);
        assert_eq!(r.values, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn widest_path_on_bottleneck() {
        // 0 -(5)- 1 -(2)- 2 and 0 -(1)- 2: widest to 2 is min(5,2)=2
        let mut el = EdgeList::default();
        el.push(0, 1, 5.0);
        el.push(1, 2, 2.0);
        el.push(0, 2, 1.0);
        let r = run_silent(&algorithms::widest_path(), &csr(&el), 0);
        assert_eq!(r.values[2], 2.0);
    }

    #[test]
    fn observer_sees_every_superstep() {
        let g = csr(&generate::chain(5));
        let mut steps = 0;
        let mut edges = 0u64;
        let r = run(&algorithms::bfs(), &g, 0, |t| {
            steps += 1;
            edges += t.dsts.len() as u64;
        })
        .unwrap();
        assert_eq!(steps, r.supersteps);
        assert_eq!(edges, r.edges_traversed);
    }

    #[test]
    fn pagerank_trace_is_csr_stream_order() {
        // CSR stream order = targets[] as laid out on device. The PR trace
        // must present edges to the simulator in exactly this order every
        // superstep, like every other algorithm's row-major sweep does —
        // a different order would skew the bank-conflict model.
        let g = csr(&generate::rmat(8, 2_000, 0.57, 0.19, 0.19, 9));
        let stream: Vec<u32> = (0..g.num_vertices() as u32)
            .flat_map(|v| g.neighbors(v).iter().copied().collect::<Vec<_>>())
            .collect();
        assert_eq!(stream, g.targets, "row-major sweep is the CSR stream");
        let mut observed = 0;
        run(&algorithms::pagerank(), &g, 0, |t| {
            assert_eq!(t.dsts, &stream[..], "superstep {} trace order", t.index);
            observed += 1;
        })
        .unwrap();
        assert!(observed > 0);
    }

    #[test]
    fn convergence_flag_distinguishes_truncation_from_fixpoint() {
        let g = csr(&generate::chain(30));
        // BFS reaches its empty-frontier fixpoint well within the bound
        assert!(run_silent(&algorithms::bfs(), &g, 0).converged);
        // an impossible tolerance can never be met: the interpreter stops
        // at its internal bound and must say so instead of lying
        let p = algorithms::pagerank()
            .instantiate(&crate::dsl::params::ParamSet::new().bind("tolerance", -1.0))
            .unwrap();
        let r = run_silent(&p, &g, 0);
        assert!(!r.converged, "delta < -1 is unsatisfiable");
        assert_eq!(r.supersteps, PR_MAX_ITERS);
    }

    #[test]
    fn pagerank_honors_the_bound_damping_value() {
        // Regression: the engine used to hard-code damping = 0.85, so any
        // other bound value silently computed with the wrong constant.
        use crate::dsl::params::ParamSet;
        let g = csr(&generate::rmat(7, 900, 0.57, 0.19, 0.19, 11));
        let mut ranks = Vec::new();
        for damping in [0.5, 0.9] {
            let p = algorithms::pagerank()
                .instantiate(&ParamSet::new().bind("damping", damping).bind("tolerance", 1e-12))
                .unwrap();
            let r = run_silent(&p, &g, 0);
            let expected = reference_pagerank(&g, damping, r.supersteps);
            for (a, b) in r.values.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-9, "damping {damping}: {a} vs {b}");
            }
            ranks.push(r.values);
        }
        let diff: f64 =
            ranks[0].iter().zip(&ranks[1]).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "damping 0.5 vs 0.9 must produce different ranks (diff {diff})");
    }

    #[test]
    fn bfs_max_depth_truncates_and_converges() {
        use crate::dsl::params::ParamSet;
        let g = csr(&generate::chain(10));
        let p = algorithms::bfs()
            .instantiate(&ParamSet::new().bind("max_depth", 3.0))
            .unwrap();
        let r = run_silent(&p, &g, 0);
        assert!(r.converged, "reaching the depth horizon is convergence, not truncation");
        assert_eq!(r.supersteps, 3);
        assert_eq!(&r.values[..4], &[0.0, 1.0, 2.0, 3.0]);
        assert!(r.values[4..].iter().all(|&v| v == -1.0), "beyond-horizon stays unvisited");
        // unbound, the default horizon is infinite: full traversal
        let full = run_silent(&algorithms::bfs(), &g, 0);
        assert_eq!(full.values[9], 9.0);
    }

    #[test]
    fn observer_error_aborts_the_run() {
        let g = csr(&generate::chain(10));
        let mut steps = 0;
        let err = run_observed(&algorithms::bfs(), &g, 0, |t| {
            steps += 1;
            if t.index >= 2 {
                anyhow::bail!("cap hit in superstep {}", t.index)
            }
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("cap hit in superstep 2"));
        assert_eq!(steps, 3, "run must stop at the failing superstep");
    }

    #[test]
    fn avg_gap_chain_is_one() {
        let g = csr(&generate::chain(100));
        assert!((avg_edge_gap(&g) - 1.0).abs() < 1e-9);
    }
}
