//! Software GAS engine — the rust-side functional oracle. Interprets any
//! [`GasProgram`] (including custom ones with no AOT kernel) edge-by-edge,
//! emitting a per-superstep trace the accelerator simulator consumes in
//! lockstep. The AOT/XLA path ([`super::xla_engine`]) is cross-checked
//! against this engine for the five canonical algorithms.
//!
//! ## Direction-optimizing execution
//!
//! The engine runs each superstep in one of two directions:
//!
//! * **push** — stream the frontier's out-edges over the CSR and scatter
//!   messages to their destinations (the reference path, and the only
//!   path of [`run`]/[`run_observed`]);
//! * **pull** — sweep destination vertices over the cached CSC and gather
//!   messages from in-neighbors that are in the frontier, testing
//!   membership against the frontier bitmap
//!   ([`super::frontier::Frontier`]). Dense frontiers (the middle of a
//!   BFS on power-law graphs, every PageRank superstep) are much cheaper
//!   this way: the sweep is sequential, needs no frontier sort, and
//!   BFS-shaped programs stop scanning a vertex at its first frontier
//!   neighbor.
//!
//! [`run_adaptive`] picks the direction per superstep with the standard
//! frontier-size heuristic and reports the choice in every
//! [`SuperstepTrace`] (and, aggregated, in [`GasResult::pull_supersteps`]).
//!
//! **Exactness contract:** adaptive execution returns bit-identical
//! `values` and the same `supersteps` as the push-only reference.
//! This holds even for non-associative float `Sum` reductions because
//! [`crate::graph::csr::Csr::transpose`] is stable in CSR-stream order:
//! within each CSC row, in-neighbors appear in exactly the order the push
//! direction would deliver their messages, so per-destination
//! accumulation performs the identical float operations in the identical
//! order. `edges_traversed` and the trace streams *do* differ by design —
//! they describe the work actually performed, which is the whole point of
//! changing direction.

use anyhow::Result;

use crate::dsl::apply::{ApplyEnv, ApplyExpr, CompiledApply};
use crate::dsl::params::ParamSet;
use crate::dsl::program::{
    Convergence, Direction, FrontierPolicy, GasProgram, InitPolicy, ReduceOp, Writeback,
};
use crate::graph::csr::Csr;
use crate::graph::VertexId;

use super::frontier::Frontier;

/// Per-superstep trace passed to the lockstep observer (the simulator).
pub struct SuperstepTrace<'a> {
    pub index: u32,
    /// Destination vertex of every edge processed this superstep, stream
    /// order. Push supersteps stream the frontier's out-edges in CSR
    /// order (scattered destinations); pull supersteps stream swept
    /// vertices' in-edges in CSC order (destinations arrive as ascending
    /// runs). The simulator's bank-conflict model consumes exactly this
    /// stream, so it sees the real access pattern of either direction.
    pub dsts: &'a [u32],
    /// Rows opened this superstep: active CSR rows when pushing, swept
    /// CSC rows when pulling.
    pub active_rows: u64,
    /// Which direction this superstep ran — part of the lockstep contract
    /// so downstream models and reports can account push and pull work
    /// separately.
    pub direction: Direction,
}

/// Result of a run.
#[derive(Debug, Clone)]
pub struct GasResult {
    /// Final vertex values (f64-interpreted; i32 programs hold integers).
    pub values: Vec<f64>,
    pub supersteps: u32,
    pub edges_traversed: u64,
    /// Whether the program's own convergence condition was met. `false`
    /// means the interpreter's internal superstep bound expired first —
    /// the values are a truncated fixpoint iteration, not an answer. The
    /// engine turns this into an iteration-cap error; standalone callers
    /// can decide for themselves.
    pub converged: bool,
    /// Supersteps executed in the pull (CSC) direction; the remaining
    /// `supersteps - pull_supersteps` ran push. Always 0 on the push-only
    /// reference path.
    pub pull_supersteps: u32,
}

/// How the engine chooses the traversal direction each superstep.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DirectionPolicy {
    /// Push from the frontier over the CSR every superstep — the
    /// reference path ([`run`]/[`run_observed`]).
    PushOnly,
    /// Choose per superstep by the frontier-size heuristic. Requires a
    /// CSC in the [`EngineGraph`]; falls back to push without one.
    #[default]
    Adaptive,
    /// Pull every superstep that structurally can (needs a CSC). Exists
    /// so tests and benches can pin the pull kernels even on sparse
    /// frontiers where the heuristic would push.
    ForcePull,
}

/// The graph arrays one engine run executes over. The CSR is mandatory;
/// the CSC (for pull supersteps) and the out-degree array are optional
/// accelerators normally cached once per graph by
/// [`crate::prep::prepared::PreparedGraph`] and shared by every query in
/// a binding.
#[derive(Debug, Clone, Copy)]
pub struct EngineGraph<'a> {
    pub csr: &'a Csr,
    /// Transposed adjacency (in-edges). Must be `csr.transpose()` — the
    /// pull direction's bit-exactness relies on its stable row order.
    pub csc: Option<&'a Csr>,
    /// Cached out-degrees (`csr.degree(v)` for all v); derived on the fly
    /// when absent.
    pub out_deg: Option<&'a [u32]>,
    /// Cached CSC-order destination stream (`v` repeated in-degree(`v`)
    /// times, ascending): the trace of a full-sweep pull superstep.
    /// Full-sweep pull runs (PageRank) rebuild it per run when absent.
    pub pull_dsts: Option<&'a [u32]>,
    /// Push↔pull crossover constants the adaptive policy reads; defaults
    /// to the hand-set `PULL_ALPHA_*` values, replaced by fitted ones
    /// when the binding's graph has been calibrated.
    pub crossover: Crossover,
}

impl<'a> EngineGraph<'a> {
    /// A push-only view: no CSC, so every superstep pushes.
    pub fn push_only(csr: &'a Csr) -> Self {
        Self { csr, csc: None, out_deg: None, pull_dsts: None, crossover: Crossover::default() }
    }

    /// A view with the transpose cached — what
    /// [`crate::prep::prepared::PreparedGraph`] hands every query.
    pub fn with_csc(csr: &'a Csr, csc: &'a Csr, out_deg: Option<&'a [u32]>) -> Self {
        debug_assert_eq!(csr.num_vertices(), csc.num_vertices(), "csc must transpose csr");
        debug_assert_eq!(csr.num_edges(), csc.num_edges(), "csc must transpose csr");
        if let Some(d) = out_deg {
            debug_assert_eq!(d.len(), csr.num_vertices());
        }
        Self { csr, csc: Some(csc), out_deg, pull_dsts: None, crossover: Crossover::default() }
    }

    /// Attach the cached CSC-order destination stream (see
    /// [`crate::prep::prepared::PreparedGraph::pull_stream`]) so
    /// full-sweep pull runs skip rebuilding it per query.
    pub fn with_pull_stream(mut self, pull_dsts: &'a [u32]) -> Self {
        debug_assert_eq!(pull_dsts.len(), self.csr.num_edges());
        self.pull_dsts = Some(pull_dsts);
        self
    }

    /// Replace the default push↔pull crossover with fitted constants
    /// (see [`crate::prep::calibrate`]).
    pub fn with_crossover(mut self, crossover: Crossover) -> Self {
        self.crossover = crossover;
        self
    }

    #[inline]
    fn out_degree(&self, v: VertexId) -> u32 {
        match self.out_deg {
            Some(d) => d[v as usize],
            None => self.csr.degree(v),
        }
    }
}

/// Frontier-size thresholds for switching to pull: pull when the
/// frontier's out-edges exceed `E / alpha`. BFS-shaped programs
/// (constant message, visited-once writeback) pull earlier because their
/// pull sweep stops scanning a vertex at its first frontier in-neighbor;
/// full-scan pulls must read every in-edge of every swept vertex, so
/// they only pay off near frontier saturation.
pub(crate) const PULL_ALPHA_EARLY_EXIT: u64 = 8;
pub(crate) const PULL_ALPHA_FULL_SCAN: u64 = 2;

/// The push↔pull crossover constants one run decides directions with.
/// The defaults are the hand-set `PULL_ALPHA_*` values above;
/// `jgraph calibrate` fits per-graph replacements
/// ([`crate::prep::calibrate`]) that
/// [`crate::prep::prepared::PreparedGraph`] then hands every query via
/// [`EngineGraph::with_crossover`]. Only the direction *choice* depends
/// on these — values stay bit-identical under any crossover because push
/// and pull reduce in the same delivery order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Crossover {
    /// Alpha for early-exit-capable pulls (BFS-shaped programs).
    pub alpha_early_exit: u64,
    /// Alpha for full-scan pulls (every in-edge of every swept vertex).
    pub alpha_full_scan: u64,
}

impl Default for Crossover {
    fn default() -> Self {
        Crossover {
            alpha_early_exit: PULL_ALPHA_EARLY_EXIT,
            alpha_full_scan: PULL_ALPHA_FULL_SCAN,
        }
    }
}

impl Crossover {
    /// The alpha the adaptive policy compares frontier edge mass against,
    /// picked by whether the program's pull sweep can early-exit.
    #[inline]
    pub(crate) fn alpha(&self, early_exit_ok: bool) -> u64 {
        if early_exit_ok {
            self.alpha_early_exit
        } else {
            self.alpha_full_scan
        }
    }
}

/// Run `program` over `graph` from `root` (ignored by non-rooted
/// programs). `observer` sees each superstep's edge trace before state is
/// committed — the simulator hooks in here. **Push-only reference path**;
/// see [`run_adaptive`] for direction-optimized execution.
pub fn run(
    program: &GasProgram,
    graph: &Csr,
    root: VertexId,
    mut observer: impl FnMut(&SuperstepTrace<'_>),
) -> Result<GasResult> {
    run_observed(program, graph, root, |trace| {
        observer(trace);
        Ok(())
    })
}

/// Like [`run`], but the observer is fallible: an `Err` **aborts the run
/// before the superstep's state is committed** and propagates out. This is
/// how the engine enforces the scheduler's iteration cap — the safety net
/// against non-converging programs must stop the loop, not merely log.
pub fn run_observed(
    program: &GasProgram,
    graph: &Csr,
    root: VertexId,
    observer: impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    run_with_policy(
        program,
        &EngineGraph::push_only(graph),
        root,
        DirectionPolicy::PushOnly,
        observer,
    )
}

/// Direction-optimized execution: per superstep, push over the CSR or
/// pull over the cached CSC, whichever the frontier-size heuristic says
/// is cheaper. Returns bit-identical `values` and the same `supersteps`
/// as the push-only [`run`] (see the module docs for why), while
/// `edges_traversed`/traces reflect the work actually done.
pub fn run_adaptive(
    program: &GasProgram,
    graph: &EngineGraph<'_>,
    root: VertexId,
    observer: impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    run_with_policy(program, graph, root, DirectionPolicy::Adaptive, observer)
}

/// [`run_adaptive`] with an explicit [`DirectionPolicy`] — the
/// test/bench entry point that can pin push-only or pull-always.
pub fn run_with_policy(
    program: &GasProgram,
    graph: &EngineGraph<'_>,
    root: VertexId,
    policy: DirectionPolicy,
    mut observer: impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    // A still-parameterized program closes over its declared defaults
    // here; the engine lifecycle instantiates with the query's ParamSet
    // *before* calling in, so this is the standalone-caller convenience.
    let owned;
    let program = if program.has_runtime_params() {
        owned = program.instantiate(&ParamSet::new())?;
        &owned
    } else {
        program
    };
    // Derive the program's facts once per run: dispatch and the pull
    // early-exit gate read the analyzer, not ad-hoc shape checks.
    let facts = crate::analysis::analyze(program);
    if facts.damped_iteration {
        return run_pagerank(program, graph, policy, &mut observer);
    }
    run_generic(program, &facts, graph, root, policy, &mut observer)
}

pub(crate) fn init_values(program: &GasProgram, n: usize, root: VertexId) -> Vec<f64> {
    match &program.init {
        InitPolicy::RootAndDefault { root_value, default } => {
            let mut v = vec![default.lit(); n];
            if (root as usize) < n {
                v[root as usize] = root_value.lit();
            }
            v
        }
        InitPolicy::VertexId => (0..n).map(|i| i as f64).collect(),
        InitPolicy::UniformFraction => vec![1.0 / n.max(1) as f64; n],
        InitPolicy::Constant(c) => vec![c.lit(); n],
    }
}

pub(crate) fn reduce_identity(op: ReduceOp) -> f64 {
    match op {
        ReduceOp::Min => f64::INFINITY,
        ReduceOp::Max => f64::NEG_INFINITY,
        ReduceOp::Sum => 0.0,
    }
}

pub(crate) fn reduce_combine(op: ReduceOp, a: f64, b: f64) -> f64 {
    match op {
        ReduceOp::Min => a.min(b),
        ReduceOp::Max => a.max(b),
        ReduceOp::Sum => a + b,
    }
}

/// One edge's message under the specialized Apply forms — shared by the
/// push and pull inner loops so the two directions cannot drift.
/// `dst_value` is a thunk: only the general tree interpreter reads the
/// destination value, and the push hot loop must not pay the load for
/// the closed forms.
#[inline(always)]
pub(crate) fn eval_msg(
    compiled: CompiledApply,
    apply: &ApplyExpr,
    const_msg: f64,
    src_value: f64,
    dst_value: impl FnOnce() -> f64,
    weight: f32,
    iter: u32,
) -> f64 {
    use CompiledApply as C;
    match compiled {
        C::ConstPerIter => const_msg,
        C::Src => src_value,
        C::SrcPlusWeight => src_value + weight as f64,
        C::SrcTimesWeight => src_value * weight as f64,
        C::General => apply.eval(&ApplyEnv {
            src_value,
            dst_value: dst_value(),
            edge_weight: weight as f64,
            iter_count: iter as f64,
        }),
    }
}

fn run_generic(
    program: &GasProgram,
    facts: &crate::analysis::ProgramFacts,
    g: &EngineGraph<'_>,
    root: VertexId,
    policy: DirectionPolicy,
    observer: &mut impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    let csr = g.csr;
    let n = csr.num_vertices();
    let mut values = init_values(program, n, root);
    if n == 0 {
        // nothing to traverse and no frontier to drain: an empty graph is
        // a converged fixpoint, not a panic
        return Ok(GasResult {
            values,
            supersteps: 0,
            edges_traversed: 0,
            converged: true,
            pull_supersteps: 0,
        });
    }
    // Rooted programs must reject a root outside the graph instead of
    // returning a plausible-looking all-unreachable result (previously
    // this was an index panic; non-rooted programs ignore `root`).
    if matches!(program.init, InitPolicy::RootAndDefault { .. }) && (root as usize) >= n {
        anyhow::bail!("root {root} out of range for a {n}-vertex graph");
    }
    let unvisited = match &program.init {
        InitPolicy::RootAndDefault { default, .. } => default.lit(),
        _ => f64::NAN,
    };

    // `Active` programs evolve a materialized frontier; `All` programs
    // sweep every vertex every superstep (no set to maintain).
    let active_policy = program.frontier == FrontierPolicy::Active;
    let mut cur = Frontier::new(n);
    let mut next = Frontier::new(n);
    if active_policy {
        match &program.init {
            InitPolicy::RootAndDefault { .. } => cur.push(root),
            _ => {
                for v in 0..n as VertexId {
                    cur.push(v);
                }
            }
        }
    }

    // Bounded-depth traversal: converging at the depth horizon is a met
    // condition (a legitimate answer), unlike exhausting `max_steps`.
    let depth_cap: f64 =
        program.depth_limit.as_ref().map(|s| s.lit()).unwrap_or(f64::INFINITY);

    let max_steps = program.max_supersteps(n);
    let m_total = csr.num_edges() as u64;
    let mut edges_traversed = 0u64;
    let mut supersteps = 0u32;
    let mut pull_supersteps = 0u32;
    // Specialize the Apply expression once (the software analogue of the
    // translator's fixed ALU chain); the general tree interpreter remains
    // the fallback for custom expressions. §Perf: ~2x on the oracle loop.
    let compiled = CompiledApply::compile(&program.apply);
    // A pull sweep may stop scanning a vertex at its first frontier
    // in-neighbor when one message decides the outcome. The legality is
    // an analyzer fact now (superstep-constant message + visited-gate
    // writeback + idempotent-monotone reduce), property-tested equivalent
    // to the previous inline `ConstPerIter && IfUnvisited && != Sum`.
    let early_exit_ok = facts.pull_early_exit;
    // ... and such once-written vertices can never change again, so pull
    // sweeps skip the already-visited ones entirely.
    let sweep_unvisited_only = active_policy && program.writeback == Writeback::IfUnvisited;
    let is_unvisited = |x: f64| x == unvisited || (x.is_nan() && unvisited.is_nan());

    // reused scratch (hot loop: no per-superstep allocation)
    let mut acc = vec![reduce_identity(program.reduce); n];
    let mut touched_flag = vec![false; n];
    let mut touched: Vec<VertexId> = Vec::with_capacity(n);
    let mut dsts: Vec<u32> = Vec::new();

    let mut converged = false;
    for iter in 0..max_steps {
        let frontier_len = if active_policy { cur.len() } else { n };
        if frontier_len == 0 {
            converged = true;
            break;
        }

        let direction = match (policy, g.csc) {
            (DirectionPolicy::PushOnly, _) | (_, None) => Direction::Push,
            (DirectionPolicy::ForcePull, Some(_)) => Direction::Pull,
            (DirectionPolicy::Adaptive, Some(_)) => {
                if !active_policy {
                    // an All-policy superstep is dense by definition
                    Direction::Pull
                } else {
                    let m_f: u64 = cur.as_slice().iter().map(|&v| g.out_degree(v) as u64).sum();
                    let alpha = g.crossover.alpha(early_exit_ok);
                    if m_f.saturating_mul(alpha) >= m_total.max(1) {
                        Direction::Pull
                    } else {
                        Direction::Push
                    }
                }
            }
        };

        dsts.clear();
        touched.clear();

        // constant-per-superstep messages (BFS) evaluate once, not per edge
        let const_msg = program.apply.eval(&ApplyEnv {
            src_value: 0.0,
            dst_value: 0.0,
            edge_weight: 0.0,
            iter_count: iter as f64,
        });

        let active_rows: u64;
        match direction {
            Direction::Push => {
                active_rows = frontier_len as u64;
                let mut process_src = |u: VertexId| {
                    let src_value = values[u as usize];
                    for (_, v, w) in csr.row_edges(u) {
                        let msg = eval_msg(
                            compiled,
                            &program.apply,
                            const_msg,
                            src_value,
                            || values[v as usize],
                            w,
                            iter,
                        );
                        if !touched_flag[v as usize] {
                            touched_flag[v as usize] = true;
                            touched.push(v);
                        }
                        let slot = &mut acc[v as usize];
                        *slot = reduce_combine(program.reduce, *slot, msg);
                        dsts.push(v);
                    }
                };
                if active_policy {
                    // `cur` is sealed ascending: the accumulation order
                    // per destination is fixed, which the pull direction
                    // reproduces exactly
                    for &u in cur.as_slice() {
                        process_src(u);
                    }
                } else {
                    for u in 0..n as VertexId {
                        process_src(u);
                    }
                }
            }
            Direction::Pull => {
                let csc = g.csc.expect("pull chosen only with a csc");
                if active_policy {
                    cur.ensure_bits();
                }
                let mut swept = 0u64;
                for v in 0..n as VertexId {
                    if sweep_unvisited_only && !is_unvisited(values[v as usize]) {
                        continue;
                    }
                    swept += 1;
                    let dst_value = values[v as usize];
                    for (_, u, w) in csc.row_edges(v) {
                        // every scanned in-edge is streamed work, whether
                        // or not its source is in the frontier
                        dsts.push(v);
                        if active_policy && !cur.contains(u) {
                            continue;
                        }
                        let src_value = values[u as usize];
                        let msg = eval_msg(
                            compiled,
                            &program.apply,
                            const_msg,
                            src_value,
                            || dst_value,
                            w,
                            iter,
                        );
                        if !touched_flag[v as usize] {
                            touched_flag[v as usize] = true;
                            touched.push(v);
                        }
                        let slot = &mut acc[v as usize];
                        *slot = reduce_combine(program.reduce, *slot, msg);
                        if early_exit_ok {
                            break;
                        }
                    }
                }
                active_rows = swept;
                pull_supersteps += 1;
            }
        }
        edges_traversed += dsts.len() as u64;

        observer(&SuperstepTrace { index: iter, dsts: &dsts, active_rows, direction })?;

        // writeback (direction-independent: `touched`/`acc` hold the same
        // reduced messages either way)
        next.clear();
        let mut changed = 0usize;
        // Sweep-overwrite semantics (SpMV/degree-count): vertices that
        // received no message this sweep take the Sum identity (y = A·x
        // leaves rows without nonzeros at 0), matching the XLA kernels'
        // `zeros().at[dst].add(...)` shape. Must run before the touched
        // loop clears the flags.
        if program.writeback == Writeback::Overwrite
            && program.frontier == FrontierPolicy::All
            && program.reduce == ReduceOp::Sum
        {
            for v in 0..n {
                if !touched_flag[v] && values[v] != 0.0 {
                    values[v] = 0.0;
                    changed += 1;
                }
            }
        }
        for &v in touched.iter() {
            let reduced = acc[v as usize];
            let old = values[v as usize];
            let new = match program.writeback {
                Writeback::MinCombine => old.min(reduced),
                Writeback::MaxCombine => old.max(reduced),
                Writeback::IfUnvisited => {
                    if is_unvisited(old) {
                        reduced
                    } else {
                        old
                    }
                }
                Writeback::Overwrite => reduced,
                Writeback::DampedSum(_) => unreachable!("damped programs run in run_pagerank"),
            };
            if new != old {
                values[v as usize] = new;
                changed += 1;
                if active_policy {
                    next.push(v);
                }
            }
            acc[v as usize] = reduce_identity(program.reduce);
            touched_flag[v as usize] = false;
        }
        supersteps = iter + 1;

        // convergence
        let done = match &program.convergence {
            Convergence::EmptyFrontier => {
                if active_policy {
                    next.is_empty()
                } else {
                    changed == 0
                }
            }
            Convergence::NoChange => changed == 0,
            Convergence::FixedIterations(k) => supersteps >= *k,
            Convergence::DeltaBelow(_) => unreachable!("PR handled separately"),
        } || supersteps as f64 >= depth_cap;
        if done {
            converged = true;
            break;
        }
        if active_policy {
            next.seal();
            std::mem::swap(&mut cur, &mut next);
        }
    }

    Ok(GasResult { values, supersteps, edges_traversed, converged, pull_supersteps })
}

/// PageRank with damping + uniform dangling redistribution, numerically
/// matching python/compile/kernels/ref.py::pr_step. Both constants come
/// from the (instantiated) program: damping from the `DampedSum`
/// writeback, tolerance from the `DeltaBelow` convergence — the engine
/// honors the query's bound values, never a baked-in default.
///
/// Every superstep is dense, so with a CSC available (and the policy
/// allowing it) the whole run pulls: per-destination sums accumulate over
/// the CSC row in the exact order the push scatter would deliver them
/// (see [`crate::graph::csr::Csr::transpose`]), making the ranks
/// bit-identical between directions. Both directions double-buffer
/// `rank`/`next` and reuse all scratch across iterations — zero heap
/// allocation in steady state.
fn run_pagerank(
    program: &GasProgram,
    g: &EngineGraph<'_>,
    policy: DirectionPolicy,
    observer: &mut impl FnMut(&SuperstepTrace<'_>) -> Result<()>,
) -> Result<GasResult> {
    let damping = match &program.writeback {
        Writeback::DampedSum(d) => d.lit(),
        // Dispatch is fact-driven (`ProgramFacts::damped_iteration` keys
        // on the writeback shape), so a non-damped program can no longer
        // slide into this path with a silently-assumed 0.85.
        other => unreachable!("run_pagerank dispatched on a non-damped writeback {other:?}"),
    };
    let tol = match &program.convergence {
        Convergence::DeltaBelow(t) => t.lit(),
        _ => 1e-6,
    };
    let csr = g.csr;
    let n = csr.num_vertices();
    let nf = n.max(1) as f64;
    let mut rank = vec![1.0 / nf; n];
    let mut next = vec![0f64; n];
    // out-degrees: cached by PreparedGraph ([`EngineGraph::out_deg`]) or
    // derived once per run — never per superstep, never per query twice
    let deg_storage;
    let out_deg: &[u32] = match g.out_deg {
        Some(d) => d,
        None => {
            deg_storage = csr.out_degrees();
            &deg_storage
        }
    };

    let pull = policy != DirectionPolicy::PushOnly && g.csc.is_some();
    let direction = if pull { Direction::Pull } else { Direction::Push };
    // Trace stream, fixed for the whole run: push streams the CSR edge
    // stream — which is literally `csr.targets`, cached, no rebuild —
    // while pull streams destinations in CSC order (ascending runs),
    // materialized once.
    let pull_stream: Vec<u32>;
    let dsts: &[u32] = if pull {
        match g.pull_dsts {
            // the per-load cache (PreparedGraph::pull_stream): no rebuild
            Some(stream) => stream,
            None => {
                pull_stream = g.csc.expect("pull requires a csc").row_run_stream();
                &pull_stream
            }
        }
    } else {
        &csr.targets
    };
    // push-direction scatter accumulator (reused across iterations; the
    // pull direction accumulates per destination in a register instead)
    let mut sums = vec![0f64; if pull { 0 } else { n }];
    // pull-direction contribution scratch: rank[u]/deg hoisted to one
    // division per vertex per iteration (the gather would otherwise
    // divide once per edge); reused across iterations. Bitwise identical
    // to push — each edge still adds the exact same quotient.
    let mut contrib = vec![0f64; if pull { n } else { 0 }];

    let mut edges_traversed = 0u64;
    let mut supersteps = 0u32;
    let mut pull_supersteps = 0u32;
    let mut converged = false;

    // The superstep safety net (`GasProgram::delta_bound`): the default
    // matches python/compile/kernels/ref.py; builders can override it.
    for iter in 0..program.delta_bound() {
        edges_traversed += csr.num_edges() as u64;
        observer(&SuperstepTrace { index: iter, dsts, active_rows: n as u64, direction })?;

        let dangling: f64 = (0..n)
            .filter(|&v| out_deg[v] == 0)
            .map(|v| rank[v])
            .sum();
        let base = (1.0 - damping) / nf + damping * dangling / nf;
        let mut delta = 0.0;
        if pull {
            let csc = g.csc.expect("pull requires a csc");
            for v in 0..n {
                contrib[v] = rank[v] / out_deg[v].max(1) as f64;
            }
            for v in 0..n {
                let mut sum = 0f64;
                for (_, u, _) in csc.row_edges(v as VertexId) {
                    sum += contrib[u as usize];
                }
                next[v] = base + damping * sum;
                delta += (next[v] - rank[v]).abs();
            }
            pull_supersteps += 1;
        } else {
            sums.fill(0.0);
            for v in 0..n as VertexId {
                let contrib = rank[v as usize] / out_deg[v as usize].max(1) as f64;
                for (_, d, _) in csr.row_edges(v) {
                    sums[d as usize] += contrib;
                }
            }
            for v in 0..n {
                next[v] = base + damping * sums[v];
                delta += (next[v] - rank[v]).abs();
            }
        }
        std::mem::swap(&mut rank, &mut next);
        supersteps = iter + 1;
        if delta < tol {
            converged = true;
            break;
        }
    }
    Ok(GasResult { values: rank, supersteps, edges_traversed, converged, pull_supersteps })
}

/// Naive reference PageRank (damping + uniform dangling redistribution)
/// for a fixed iteration count, written independently of [`run_pagerank`]
/// — no shared constants, no early exit. Test-support only: both the unit
/// suite and the integration suite check the engine against this one
/// implementation so the reference cannot drift between them.
#[doc(hidden)]
pub fn reference_pagerank(graph: &Csr, damping: f64, iters: u32) -> Vec<f64> {
    let n = graph.num_vertices();
    let nf = n as f64;
    let mut rank = vec![1.0 / nf; n];
    for _ in 0..iters {
        let mut next = vec![0.0f64; n];
        let mut dangling = 0.0;
        for v in 0..n as VertexId {
            let deg = graph.degree(v);
            if deg == 0 {
                dangling += rank[v as usize];
                continue;
            }
            let share = rank[v as usize] / deg as f64;
            for (_, d, _) in graph.row_edges(v) {
                next[d as usize] += share;
            }
        }
        for slot in next.iter_mut() {
            *slot = (1.0 - damping) / nf + damping * (*slot + dangling / nf);
        }
        rank = next;
    }
    rank
}

/// Average |src-dst| gap of a CSR graph (locality input for the
/// simulator).
pub fn avg_edge_gap(graph: &Csr) -> f64 {
    let m = graph.num_edges();
    if m == 0 {
        return 0.0;
    }
    let mut total = 0u64;
    for v in 0..graph.num_vertices() as VertexId {
        for (_, d, _) in graph.row_edges(v) {
            total += (v as i64 - d as i64).unsigned_abs();
        }
    }
    total as f64 / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::graph::{edgelist::EdgeList, generate};

    fn csr(el: &EdgeList) -> Csr {
        Csr::from_edgelist(el)
    }

    fn run_silent(p: &crate::dsl::program::GasProgram, g: &Csr, root: u32) -> GasResult {
        run(p, g, root, |_| {}).unwrap()
    }

    /// Adaptive run over a view with the CSC/out-degree caches built the
    /// way `PreparedGraph` builds them.
    fn run_adaptive_silent(
        p: &crate::dsl::program::GasProgram,
        g: &Csr,
        root: u32,
        policy: DirectionPolicy,
    ) -> GasResult {
        let csc = g.transpose();
        let deg = g.out_degrees();
        let view = EngineGraph::with_csc(g, &csc, Some(&deg));
        run_with_policy(p, &view, root, policy, |_| Ok(())).unwrap()
    }

    fn assert_same_values(a: &GasResult, b: &GasResult, ctx: &str) {
        assert_eq!(a.supersteps, b.supersteps, "{ctx}: supersteps");
        assert_eq!(a.converged, b.converged, "{ctx}: converged");
        assert_eq!(a.values.len(), b.values.len(), "{ctx}: len");
        for (i, (x, y)) in a.values.iter().zip(&b.values).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: vertex {i}: {x} vs {y}");
        }
    }

    #[test]
    fn bfs_levels_on_diamond() {
        let g = csr(&EdgeList::from_pairs([(0, 1), (0, 2), (1, 3), (2, 3)]));
        let r = run_silent(&algorithms::bfs(), &g, 0);
        assert_eq!(r.values, vec![0.0, 1.0, 1.0, 2.0]);
        assert_eq!(r.edges_traversed, 4);
        assert_eq!(r.pull_supersteps, 0, "reference path never pulls");
    }

    #[test]
    fn bfs_unreachable_stays_unvisited() {
        let mut el = EdgeList::from_pairs([(0, 1)]);
        el.num_vertices = 3; // vertex 2 isolated
        let r = run_silent(&algorithms::bfs(), &csr(&el), 0);
        assert_eq!(r.values[2], -1.0);
    }

    #[test]
    fn bfs_on_chain_takes_n_minus_1_steps() {
        let g = csr(&generate::chain(6));
        let r = run_silent(&algorithms::bfs(), &g, 0);
        assert_eq!(r.values[5], 5.0);
        // 5 discovery supersteps + 1 final sweep that finds the frontier
        // empty (the paper's `while Get_active_vertex()` does the same)
        assert_eq!(r.supersteps, 6);
    }

    #[test]
    fn sssp_matches_dijkstra_intuition() {
        // 0 ->(1) 1 ->(1) 2, and 0 ->(5) 2: shortest is 2
        let mut el = EdgeList::default();
        el.push(0, 1, 1.0);
        el.push(1, 2, 1.0);
        el.push(0, 2, 5.0);
        let r = run_silent(&algorithms::sssp(), &csr(&el), 0);
        assert_eq!(r.values[2], 2.0);
    }

    #[test]
    fn wcc_labels_components() {
        let mut el = EdgeList::from_pairs([(0, 1), (1, 0), (2, 3), (3, 2)]);
        el.num_vertices = 4;
        let r = run_silent(&algorithms::wcc(), &csr(&el), 0);
        assert_eq!(r.values, vec![0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn pagerank_sums_to_one_and_ranks_hub_highest() {
        use crate::dsl::params::ParamSet;
        let g = csr(&generate::star(20)); // hub 0
        let p = algorithms::pagerank()
            .instantiate(&ParamSet::new().bind("tolerance", 1e-9))
            .unwrap();
        let r = run_silent(&p, &g, 0);
        let sum: f64 = r.values.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum={sum}");
        let hub = r.values[0];
        assert!(r.values[1..].iter().all(|&v| v < hub));
    }

    #[test]
    fn spmv_is_one_matvec() {
        // y[dst] += w * x[src], x = 1
        let mut el = EdgeList::default();
        el.push(0, 1, 2.0);
        el.push(0, 2, 3.0);
        el.push(1, 2, 4.0);
        let r = run_silent(&algorithms::spmv(), &csr(&el), 0);
        assert_eq!(r.supersteps, 1);
        assert_eq!(r.values, vec![0.0, 2.0, 7.0]);
    }

    #[test]
    fn degree_count_counts_in_degrees() {
        let el = EdgeList::from_pairs([(0, 2), (1, 2), (0, 1)]);
        let r = run_silent(&algorithms::degree_count(), &csr(&el), 0);
        assert_eq!(r.values, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn widest_path_on_bottleneck() {
        // 0 -(5)- 1 -(2)- 2 and 0 -(1)- 2: widest to 2 is min(5,2)=2
        let mut el = EdgeList::default();
        el.push(0, 1, 5.0);
        el.push(1, 2, 2.0);
        el.push(0, 2, 1.0);
        let r = run_silent(&algorithms::widest_path(), &csr(&el), 0);
        assert_eq!(r.values[2], 2.0);
    }

    #[test]
    fn observer_sees_every_superstep() {
        let g = csr(&generate::chain(5));
        let mut steps = 0;
        let mut edges = 0u64;
        let r = run(&algorithms::bfs(), &g, 0, |t| {
            steps += 1;
            edges += t.dsts.len() as u64;
        })
        .unwrap();
        assert_eq!(steps, r.supersteps);
        assert_eq!(edges, r.edges_traversed);
    }

    #[test]
    fn pagerank_trace_is_csr_stream_order() {
        // CSR stream order = targets[] as laid out on device. The PR trace
        // must present edges to the simulator in exactly this order every
        // superstep, like every other algorithm's row-major sweep does —
        // a different order would skew the bank-conflict model.
        let g = csr(&generate::rmat(8, 2_000, 0.57, 0.19, 0.19, 9));
        let stream: Vec<u32> = (0..g.num_vertices() as u32)
            .flat_map(|v| g.neighbors(v).iter().copied().collect::<Vec<_>>())
            .collect();
        assert_eq!(stream, g.targets, "row-major sweep is the CSR stream");
        let mut observed = 0;
        run(&algorithms::pagerank(), &g, 0, |t| {
            assert_eq!(t.dsts, &stream[..], "superstep {} trace order", t.index);
            assert_eq!(t.direction, Direction::Push);
            observed += 1;
        })
        .unwrap();
        assert!(observed > 0);
    }

    #[test]
    fn pagerank_pull_trace_is_csc_stream_order() {
        // a pull superstep streams in-edges: destinations arrive as
        // ascending runs of length in-degree — the contract the simulator's
        // bank-conflict model relies on to see pull's sequential writes
        let g = csr(&generate::rmat(8, 2_000, 0.57, 0.19, 0.19, 9));
        let csc = g.transpose();
        let expect: Vec<u32> = (0..g.num_vertices() as u32)
            .flat_map(|v| std::iter::repeat(v).take(csc.degree(v) as usize))
            .collect();
        let view = EngineGraph::with_csc(&g, &csc, None);
        let mut observed = 0;
        run_adaptive(&algorithms::pagerank(), &view, 0, |t| {
            assert_eq!(t.direction, Direction::Pull, "every PR superstep is dense");
            assert_eq!(t.dsts, &expect[..], "superstep {} trace order", t.index);
            observed += 1;
            Ok(())
        })
        .unwrap();
        assert!(observed > 0);
    }

    #[test]
    fn convergence_flag_distinguishes_truncation_from_fixpoint() {
        let g = csr(&generate::chain(30));
        // BFS reaches its empty-frontier fixpoint well within the bound
        assert!(run_silent(&algorithms::bfs(), &g, 0).converged);
        // an impossible tolerance can never be met: the interpreter stops
        // at its internal bound and must say so instead of lying
        let p = algorithms::pagerank()
            .instantiate(&crate::dsl::params::ParamSet::new().bind("tolerance", -1.0))
            .unwrap();
        let r = run_silent(&p, &g, 0);
        assert!(!r.converged, "delta < -1 is unsatisfiable");
        assert_eq!(r.supersteps, crate::dsl::program::DELTA_CONVERGENCE_SUPERSTEP_BOUND);
    }

    #[test]
    fn overridden_delta_bound_truncates_at_the_override() {
        // Regression for the promoted constant: the per-program override
        // must reach the engine loop, and expiring it must still report
        // `converged = false` (the query layer turns that into an error,
        // never a silent truncation).
        use crate::dsl::apply::ApplyExpr;
        use crate::dsl::builder::GasProgramBuilder;
        use crate::dsl::program::Writeback;
        let g = csr(&generate::chain(30));
        let p = GasProgramBuilder::new("tight-pr")
            .apply(ApplyExpr::src())
            .reduce(ReduceOp::Sum)
            .writeback(Writeback::DampedSum(0.85.into()))
            .convergence(Convergence::DeltaBelow((-1.0).into()))
            .delta_iteration_bound(3)
            .build()
            .unwrap();
        assert_eq!(p.delta_bound(), 3);
        let r = run_silent(&p, &g, 0);
        assert!(!r.converged, "delta < -1 is unsatisfiable");
        assert_eq!(r.supersteps, 3, "the override bounds the loop");
    }

    #[test]
    fn pr_kind_tag_with_plain_overwrite_runs_the_generic_path() {
        // Regression for the old `_ => 0.85` fallback: a hand-built
        // program tagged EdgeOpKind::Pr whose writeback is a plain
        // Overwrite used to slide into the damped path and compute with a
        // silently-assumed damping constant. Dispatch now follows the
        // derived facts (writeback shape), so this shape runs the generic
        // engine — one fixed sweep here, not 200 damped iterations.
        use crate::dsl::apply::ApplyExpr;
        use crate::dsl::builder::GasProgramBuilder;
        use crate::dsl::program::EdgeOpKind;
        let mk = |name: &str, tagged: bool| {
            let b = GasProgramBuilder::new(name)
                .apply(ApplyExpr::src())
                .reduce(ReduceOp::Sum)
                .convergence(Convergence::FixedIterations(1));
            if tagged { b.kind(EdgeOpKind::Pr) } else { b }.build().unwrap()
        };
        let tagged = mk("fake-pr", true);
        assert!(!crate::analysis::analyze(&tagged).damped_iteration);
        assert!(
            crate::analysis::lint::lint(&tagged).iter().any(|d| d.code.code() == "JG104"),
            "the misleading tag warns"
        );
        let g = csr(&generate::rmat(7, 800, 0.57, 0.19, 0.19, 5));
        let r_tagged = run_silent(&tagged, &g, 0);
        let r_plain = run_silent(&mk("fake-pr-untagged", false), &g, 0);
        assert_eq!(r_tagged.supersteps, 1, "generic path honors FixedIterations(1)");
        assert_eq!(r_tagged.values, r_plain.values, "the kind tag must not change semantics");
    }

    #[test]
    fn pagerank_honors_the_bound_damping_value() {
        // Regression: the engine used to hard-code damping = 0.85, so any
        // other bound value silently computed with the wrong constant.
        use crate::dsl::params::ParamSet;
        let g = csr(&generate::rmat(7, 900, 0.57, 0.19, 0.19, 11));
        let mut ranks = Vec::new();
        for damping in [0.5, 0.9] {
            let p = algorithms::pagerank()
                .instantiate(&ParamSet::new().bind("damping", damping).bind("tolerance", 1e-12))
                .unwrap();
            let r = run_silent(&p, &g, 0);
            let expected = reference_pagerank(&g, damping, r.supersteps);
            for (a, b) in r.values.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-9, "damping {damping}: {a} vs {b}");
            }
            ranks.push(r.values);
        }
        let diff: f64 =
            ranks[0].iter().zip(&ranks[1]).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "damping 0.5 vs 0.9 must produce different ranks (diff {diff})");
    }

    #[test]
    fn bfs_max_depth_truncates_and_converges() {
        use crate::dsl::params::ParamSet;
        let g = csr(&generate::chain(10));
        let p = algorithms::bfs()
            .instantiate(&ParamSet::new().bind("max_depth", 3.0))
            .unwrap();
        let r = run_silent(&p, &g, 0);
        assert!(r.converged, "reaching the depth horizon is convergence, not truncation");
        assert_eq!(r.supersteps, 3);
        assert_eq!(&r.values[..4], &[0.0, 1.0, 2.0, 3.0]);
        assert!(r.values[4..].iter().all(|&v| v == -1.0), "beyond-horizon stays unvisited");
        // unbound, the default horizon is infinite: full traversal
        let full = run_silent(&algorithms::bfs(), &g, 0);
        assert_eq!(full.values[9], 9.0);
    }

    #[test]
    fn observer_error_aborts_the_run() {
        let g = csr(&generate::chain(10));
        let mut steps = 0;
        let err = run_observed(&algorithms::bfs(), &g, 0, |t| {
            steps += 1;
            if t.index >= 2 {
                anyhow::bail!("cap hit in superstep {}", t.index)
            }
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("cap hit in superstep 2"));
        assert_eq!(steps, 3, "run must stop at the failing superstep");
    }

    #[test]
    fn avg_gap_chain_is_one() {
        let g = csr(&generate::chain(100));
        assert!((avg_edge_gap(&g) - 1.0).abs() < 1e-9);
    }

    // --- direction-optimizing engine ---

    /// A graph whose BFS frontier goes sparse → dense → sparse: an entry
    /// chain into a K20 clique, with an exit chain out of it.
    fn chain_clique_chain() -> EdgeList {
        let mut el = EdgeList::default();
        for i in 0..9u32 {
            el.push(i, i + 1, 1.0); // chain 0..9
        }
        for i in 10..30u32 {
            for j in 10..30u32 {
                if i != j {
                    el.push(i, j, 1.0); // clique 10..29
                }
            }
        }
        el.push(9, 10, 1.0); // weld chain -> clique
        el.push(29, 30, 1.0); // weld clique -> exit chain
        for i in 30..39u32 {
            el.push(i, i + 1, 1.0); // chain 30..39
        }
        el.num_vertices = 40;
        el
    }

    #[test]
    fn adaptive_bfs_switches_push_pull_push_and_matches_reference() {
        let g = csr(&chain_clique_chain());
        let push = run_silent(&algorithms::bfs(), &g, 0);
        let csc = g.transpose();
        let deg = g.out_degrees();
        let view = EngineGraph::with_csc(&g, &csc, Some(&deg));
        let mut directions = Vec::new();
        let adaptive = run_adaptive(&algorithms::bfs(), &view, 0, |t| {
            directions.push(t.direction);
            Ok(())
        })
        .unwrap();
        assert_same_values(&push, &adaptive, "chain-clique-chain");
        assert!(adaptive.pull_supersteps > 0, "the dense clique phase must pull");
        assert!(
            adaptive.pull_supersteps < adaptive.supersteps,
            "the sparse chain phases must push"
        );
        assert_eq!(directions[0], Direction::Push, "entry chain is sparse");
        assert_eq!(*directions.last().unwrap(), Direction::Push, "exit chain is sparse");
        assert!(directions.contains(&Direction::Pull), "clique superstep pulls");
        assert_eq!(
            adaptive.pull_supersteps as usize,
            directions.iter().filter(|d| **d == Direction::Pull).count()
        );
    }

    #[test]
    fn max_depth_lands_inside_a_pull_superstep() {
        use crate::dsl::params::ParamSet;
        // depth 12 stops exactly at the superstep that drains the clique
        // frontier — the dense superstep the heuristic runs in the pull
        // direction — so the horizon and a pull superstep coincide
        let g = csr(&chain_clique_chain());
        let p = algorithms::bfs()
            .instantiate(&ParamSet::new().bind("max_depth", 12.0))
            .unwrap();
        let push = run_silent(&p, &g, 0);
        let mut last_direction = Direction::Push;
        let csc = g.transpose();
        let view = EngineGraph::with_csc(&g, &csc, None);
        let adaptive = run_with_policy(&p, &view, 0, DirectionPolicy::Adaptive, |t| {
            last_direction = t.direction;
            Ok(())
        })
        .unwrap();
        assert_same_values(&push, &adaptive, "depth-capped");
        assert!(adaptive.converged, "depth horizon is a met condition");
        assert_eq!(adaptive.supersteps, 12);
        assert_eq!(last_direction, Direction::Pull, "the horizon superstep pulled");
        assert_eq!(push.values[30], 12.0, "exit-chain head discovered at the horizon");
        assert!(push.values[31..].iter().all(|&v| v == -1.0), "beyond-horizon unvisited");
    }

    #[test]
    fn empty_graph_is_a_converged_fixpoint_on_every_path() {
        let el = EdgeList::with_vertices(0);
        let g = csr(&el);
        for program in
            [algorithms::bfs(), algorithms::sssp(), algorithms::wcc(), algorithms::pagerank()]
        {
            let push = run(&program, &g, 0, |_| {}).unwrap();
            assert!(push.converged, "{}", program.name);
            assert!(push.values.is_empty());
            let adaptive = run_adaptive_silent(&program, &g, 0, DirectionPolicy::Adaptive);
            assert!(adaptive.converged, "{}", program.name);
            assert!(adaptive.values.is_empty());
        }
    }

    #[test]
    fn out_of_range_root_is_an_error_not_a_fake_result() {
        // Regression: the root guard added for the empty-graph fix must
        // not turn a bad query into a plausible all-unreachable result.
        let g = csr(&generate::chain(10));
        let err = run(&algorithms::bfs(), &g, 99, |_| {}).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let csc = g.transpose();
        let view = EngineGraph::with_csc(&g, &csc, None);
        let err = run_adaptive(&algorithms::bfs(), &view, 99, |_| Ok(())).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // non-rooted programs ignore the root entirely
        assert!(run(&algorithms::wcc(), &g, 99, |_| {}).is_ok());
    }

    #[test]
    fn all_isolated_vertices_finish_in_one_superstep() {
        let mut el = EdgeList::default();
        el.num_vertices = 8; // no edges at all
        let g = csr(&el);
        let push = run_silent(&algorithms::bfs(), &g, 3);
        assert_eq!(push.supersteps, 1);
        assert_eq!(push.edges_traversed, 0);
        assert_eq!(push.values[3], 0.0);
        assert!(push.values.iter().enumerate().all(|(i, &v)| i == 3 || v == -1.0));
        for policy in [DirectionPolicy::Adaptive, DirectionPolicy::ForcePull] {
            let r = run_adaptive_silent(&algorithms::bfs(), &g, 3, policy);
            assert_same_values(&push, &r, "isolated");
        }
    }

    #[test]
    fn force_pull_matches_push_for_every_library_algorithm() {
        // ForcePull exercises the pull kernels even on supersteps the
        // heuristic would push — the strongest equivalence pin
        let g = csr(&generate::rmat(8, 3_000, 0.57, 0.19, 0.19, 23));
        for program in crate::dsl::algorithms::all() {
            let push = run_silent(&program, &g, 1);
            for policy in [DirectionPolicy::Adaptive, DirectionPolicy::ForcePull] {
                let r = run_adaptive_silent(&program, &g, 1, policy);
                assert_same_values(&push, &r, &format!("{} {policy:?}", program.name));
            }
        }
    }

    #[test]
    fn pagerank_pull_is_bit_identical_and_allocation_free_shape() {
        use crate::dsl::params::ParamSet;
        let g = csr(&generate::rmat(9, 8_000, 0.57, 0.19, 0.19, 31));
        let p = algorithms::pagerank()
            .instantiate(&ParamSet::new().bind("damping", 0.85).bind("tolerance", 1e-10))
            .unwrap();
        let push = run_silent(&p, &g, 0);
        let pull = run_adaptive_silent(&p, &g, 0, DirectionPolicy::Adaptive);
        assert_same_values(&push, &pull, "pagerank");
        assert_eq!(pull.pull_supersteps, pull.supersteps, "every PR superstep pulls");
        assert!(push.supersteps > 3, "tolerance tight enough to iterate");
    }

    #[test]
    fn adaptive_without_csc_degrades_to_push() {
        let g = csr(&chain_clique_chain());
        let view = EngineGraph::push_only(&g);
        let r = run_with_policy(&algorithms::bfs(), &view, 0, DirectionPolicy::Adaptive, |t| {
            assert_eq!(t.direction, Direction::Push);
            Ok(())
        })
        .unwrap();
        assert_eq!(r.pull_supersteps, 0);
        let push = run_silent(&algorithms::bfs(), &g, 0);
        assert_same_values(&push, &r, "no-csc degradation");
    }
}
