//! **CompiledPipeline** — a program translated, scheduled, admitted, and
//! (modeled) flashed, exactly once. The reusable artifact of
//! [`super::Session::compile`]: bind it to any number of graphs with
//! [`CompiledPipeline::load`], then issue cheap per-query
//! [`RunOptions`]-driven runs on the resulting
//! [`super::BoundPipeline`].

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::accel::device::DeviceModel;
use crate::comm::CommManager;
use crate::dsl::params::{ParamError, ParamSet, ParamSignature, ResolvedParams};
use crate::dsl::program::GasProgram;
use crate::graph::edgelist::EdgeList;
use crate::graph::VertexId;
use crate::prep::prepared::{PrepOptions, PreparedGraph};
use crate::runtime::KernelRegistry;
use crate::sched::{AdmittedPlan, Deadline, FaultPlan, ParallelismPlan};
use crate::translator::Design;

use super::bound::BoundPipeline;
use super::gas::DirectionPolicy;
use super::metrics::RunReport;

/// Per-query knobs — everything that may change between two queries on
/// the same bound pipeline. This is the cheap half of the old
/// `ExecutorConfig`.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Source vertex for rooted algorithms (in the prepared graph's id
    /// space when reordering was applied).
    pub root: VertexId,
    /// Legacy PageRank tolerance for programs that do **not** declare a
    /// `tolerance` parameter. Declared parameters win: prefer
    /// [`RunOptions::bind`]`("tolerance", t)`.
    pub tolerance: f64,
    /// Runtime-parameter bindings for this query, resolved against the
    /// program's declared signature (typed errors on unknown / unbound /
    /// out-of-range names). The whole point of the redesign: one
    /// compiled pipeline serves every value of these.
    pub params: ParamSet,
    /// Drive the AOT/XLA kernel for this query when the pipeline has one.
    pub use_xla: bool,
    /// Cross-check XLA against the software oracle.
    pub verify: bool,
    /// Write a per-superstep CSV trace here (None = no trace).
    pub trace_path: Option<PathBuf>,
    /// Tighten the scheduler's iteration cap for this query (None = the
    /// program's own superstep bound; values above that bound are clamped
    /// to it — the cap can only lower the limit, never raise it). Hitting
    /// the cap aborts the run with an error — the safety net against
    /// non-converging programs.
    pub max_supersteps: Option<u32>,
    /// Traversal-direction policy for this query's supersteps. The
    /// default `Adaptive` picks push or pull per superstep by the
    /// frontier-size heuristic (values are bit-identical either way —
    /// property-tested); pin `PushOnly` to model the paper's push-stream
    /// schedule, or `ForcePull` to stress the pull kernels.
    pub direction: DirectionPolicy,
    /// Worker threads for sharded execution — user partitionings *and*
    /// auto-sharded un-partitioned bindings fan their shards across
    /// `std::thread::scope` workers. `None` = one worker per shard,
    /// capped at [`crate::sched::available_workers`]; every pool
    /// (requested or default) is then leased from the process-wide
    /// [`crate::sched::WorkerBudget`], so nested parallelism
    /// (`run_batch_parallel` × shard pools) divides the cores instead of
    /// multiplying. Results are bit-identical for every worker count
    /// (property-tested) — the budget only shapes timing.
    pub shard_workers: Option<usize>,
    /// Wall-clock budget for this query. Checked cooperatively at every
    /// superstep boundary (monolithic, sharded, and auto-sharded engines)
    /// and before transfer commit; expiry aborts with a typed
    /// [`DeadlineExceeded`] carrying partial accounting instead of
    /// running forever. `None` = no deadline.
    ///
    /// [`DeadlineExceeded`]: crate::sched::DeadlineExceeded
    pub deadline: Option<Deadline>,
    /// Deterministic fault-injection schedule for chaos testing (see
    /// [`crate::sched::FaultPlan`]). `None` = no injection. Carried on
    /// the options (not process-global state) so concurrent queries and
    /// tests stay isolated.
    pub faults: Option<Arc<FaultPlan>>,
    /// Retry attempt number (0 = first try). The serve layer bumps this
    /// on retries; the exec-seam fault token folds it in, so `#root`
    /// rules fire on the first attempt only and a retry re-runs clean.
    pub attempt: u32,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            root: 0,
            tolerance: 1e-6,
            params: ParamSet::new(),
            use_xla: true,
            verify: true,
            trace_path: None,
            max_supersteps: None,
            direction: DirectionPolicy::Adaptive,
            shard_workers: None,
            deadline: None,
            faults: None,
            attempt: 0,
        }
    }
}

impl RunOptions {
    /// Default options rooted at `root` — the common multi-root sweep case.
    pub fn from_root(root: VertexId) -> Self {
        Self { root, ..Self::default() }
    }

    /// Bind a declared runtime parameter for this query
    /// (`RunOptions::from_root(r).bind("damping", 0.9)`). Resolution
    /// happens when the query runs: unknown names, unbound required
    /// parameters, and out-of-range values are typed
    /// [`ParamError`]s.
    ///
    /// [`ParamError`]: crate::dsl::params::ParamError
    pub fn bind(mut self, name: impl Into<String>, value: f64) -> Self {
        self.params.set(name, value);
        self
    }

    /// Set the legacy tolerance knob. Programs that **declare** a
    /// `tolerance` parameter resolve it from their signature instead —
    /// bind those with [`RunOptions::bind`]`("tolerance", t)`.
    pub fn with_tolerance(mut self, tolerance: f64) -> Self {
        self.tolerance = tolerance;
        self
    }

    pub fn with_trace(mut self, path: impl Into<PathBuf>) -> Self {
        self.trace_path = Some(path.into());
        self
    }

    /// Cap this query at `cap` supersteps (clamped to the program's own
    /// bound); the run errors if it has not converged by then.
    pub fn with_max_supersteps(mut self, cap: u32) -> Self {
        self.max_supersteps = Some(cap);
        self
    }

    /// Pin this query's traversal-direction policy (default:
    /// [`DirectionPolicy::Adaptive`]).
    pub fn with_direction(mut self, direction: DirectionPolicy) -> Self {
        self.direction = direction;
        self
    }

    /// Cap the worker threads a sharded query fans its shards across
    /// (default: one worker per shard, capped at the machine's worker
    /// budget).
    pub fn with_shard_workers(mut self, workers: usize) -> Self {
        self.shard_workers = Some(workers);
        self
    }

    /// Give this query a wall-clock budget; expiry aborts the run with a
    /// typed [`crate::sched::DeadlineExceeded`].
    pub fn with_deadline(mut self, deadline: Deadline) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Attach a deterministic fault-injection schedule (chaos testing).
    pub fn with_faults(mut self, faults: Arc<FaultPlan>) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Mark this query as retry attempt `attempt` (0 = first try):
    /// attempt-keyed fault rules then skip it, so a retried transient
    /// failure re-runs clean.
    pub fn with_attempt(mut self, attempt: u32) -> Self {
        self.attempt = attempt;
        self
    }
}

/// A fully-compiled, device-admitted pipeline: program + design + the
/// resolved XLA registry, plus the modeled one-time flash cost. Immutable
/// and reusable across graphs.
pub struct CompiledPipeline {
    pub(crate) program: GasProgram,
    pub(crate) design: Design,
    pub(crate) device: DeviceModel,
    pub(crate) registry: Option<Arc<KernelRegistry>>,
    /// Modeled xclbin flash time, accounted once per deployment.
    pub(crate) flash_seconds: f64,
    /// Measured wall time of the compile stage (validation + translate +
    /// artifact lookup) — the real cost `load`/`run` no longer pay.
    pub(crate) compile_wall_seconds: f64,
    /// The analyzer's fact record, derived once at compile time. Carries
    /// the [`ParallelSafety`] certificate sharded execution must check.
    ///
    /// [`ParallelSafety`]: crate::analysis::ParallelSafety
    pub(crate) facts: crate::analysis::ProgramFacts,
}

// Manual Debug: the PJRT registry handle is opaque.
impl std::fmt::Debug for CompiledPipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompiledPipeline")
            .field("program", &self.program.name)
            .field("translator", &self.design.kind)
            .field("hdl_lines", &self.design.hdl_lines)
            .field("has_xla", &self.has_xla())
            .finish_non_exhaustive()
    }
}

impl CompiledPipeline {
    pub(crate) fn from_parts(
        program: GasProgram,
        design: Design,
        device: DeviceModel,
        registry: Option<Arc<KernelRegistry>>,
        flash_seconds: f64,
        compile_wall_seconds: f64,
    ) -> Self {
        let facts = crate::analysis::analyze(&program);
        Self { program, design, device, registry, flash_seconds, compile_wall_seconds, facts }
    }

    pub fn program(&self) -> &GasProgram {
        &self.program
    }

    /// The full fact record the static analyzer derived at compile time.
    pub fn facts(&self) -> &crate::analysis::ProgramFacts {
        &self.facts
    }

    /// The parallel-scatter certificate stamped on this pipeline: future
    /// sharded/threaded execution must check it before reordering writes.
    pub fn parallel_safety(&self) -> crate::analysis::ParallelSafety {
        self.facts.parallel_safety
    }

    pub fn design(&self) -> &Design {
        &self.design
    }

    pub fn device(&self) -> &DeviceModel {
        &self.device
    }

    /// Modeled compile-period seconds (translate + synthesis), Fig. 5's
    /// compilation bar — a one-time cost under this API.
    pub fn compile_seconds(&self) -> f64 {
        self.design.compile_seconds()
    }

    /// Measured wall seconds the compile stage actually took.
    pub fn compile_wall_seconds(&self) -> f64 {
        self.compile_wall_seconds
    }

    /// Whether queries can use the AOT/XLA functional path (canonical
    /// program + artifact registry available).
    pub fn has_xla(&self) -> bool {
        self.program.kind.is_some() && self.registry.is_some()
    }

    /// The program's declared runtime-parameter signature.
    pub fn params(&self) -> &ParamSignature {
        &self.program.params
    }

    /// Typed pre-flight check of a query's bindings against the declared
    /// signature — the same resolution every query performs, surfaced for
    /// callers that want [`ParamError`]s rather than stringly run errors.
    pub fn resolve_params(&self, set: &ParamSet) -> Result<ResolvedParams, ParamError> {
        self.program.resolve_params(set)
    }

    /// The parallelism the design was scheduled with.
    pub fn plan(&self) -> ParallelismPlan {
        ParallelismPlan::new(self.design.pipeline.lanes, self.design.pipeline.pes)
    }

    /// Prepare `graph` (Reorder/Partition/Layout once) and bind it:
    /// configures the simulated shell and transports the CSR to device
    /// DDR. Queries on the result skip translate, prep, and flash.
    pub fn load(&self, graph: &EdgeList, opts: PrepOptions) -> Result<BoundPipeline<'_>> {
        let prepared = PreparedGraph::prepare(graph, &opts)?;
        self.bind(prepared)
    }

    /// Bind an already-prepared graph. Accepts an `Arc` so one prepared
    /// graph can be shared across pipelines without copying its arrays.
    ///
    /// Scheduler admission happens **here, once per binding** — the design
    /// and device cannot change between queries, so every query reuses the
    /// granted plan instead of re-validating resources.
    pub fn bind(&self, graph: impl Into<Arc<PreparedGraph>>) -> Result<BoundPipeline<'_>> {
        let graph = graph.into();
        let admitted = AdmittedPlan::admit(self.plan(), &self.design.resources, &self.device)?;
        let mut comm = CommManager::new();
        comm.shell.configure(
            &format!("{}.xclbin", self.design.program_name),
            admitted.granted.pipelines,
            admitted.granted.pes,
        )?;
        let transfer = comm.transport_graph(&graph.csr)?;
        let deploy_seconds = self.flash_seconds + transfer.seconds;
        Ok(BoundPipeline::new(self, graph, comm, admitted, deploy_seconds))
    }

    /// One-shot convenience: bind the shared graph (O(1), no array copies)
    /// and run a single query. Prefer [`Self::load`] + repeated runs for
    /// query traffic.
    pub fn run_on(&self, graph: &Arc<PreparedGraph>, opts: &RunOptions) -> Result<RunReport> {
        self.bind(graph.clone())?.run(opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::engine::session::{Session, SessionConfig};
    use crate::graph::generate;

    fn session() -> Session {
        Session::new(SessionConfig { use_xla: false, ..Default::default() })
    }

    #[test]
    fn load_binds_and_reports_deploy_cost() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(100, 800, 1);
        let bound = c.load(&g, PrepOptions::named("er")).unwrap();
        assert!(bound.deploy_seconds() >= crate::engine::executor::FLASH_SECONDS);
        assert_eq!(bound.graph().num_vertices(), 100);
    }

    #[test]
    fn pipelines_carry_the_parallel_safety_certificate() {
        use crate::analysis::ParallelSafety;
        let s = session();
        let bfs = s.compile(&algorithms::bfs()).unwrap();
        assert_eq!(bfs.parallel_safety(), ParallelSafety::BitExact);
        assert!(bfs.facts().pull_early_exit);
        let pr = s.compile(&algorithms::pagerank()).unwrap();
        assert_eq!(pr.parallel_safety(), ParallelSafety::OrderSensitive);
        assert!(pr.facts().damped_iteration);
    }

    #[test]
    fn run_on_shares_a_prepared_graph_across_pipelines() {
        let s = session();
        let g = generate::erdos_renyi(80, 500, 2);
        let prepared = Arc::new(PreparedGraph::prepare(&g, &PrepOptions::named("er")).unwrap());
        let bfs = s.compile(&algorithms::bfs()).unwrap();
        let wcc = s.compile(&algorithms::wcc()).unwrap();
        let r1 = bfs.run_on(&prepared, &RunOptions::default()).unwrap();
        let r2 = wcc.run_on(&prepared, &RunOptions::default()).unwrap();
        assert_eq!(r1.graph_name, "er");
        assert_eq!(r2.graph_name, "er");
        assert!(r1.supersteps > 0 && r2.supersteps > 0);
    }
}
