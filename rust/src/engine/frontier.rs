//! Hybrid sparse-list / bitmap frontier for the direction-optimizing GAS
//! engine. A frontier is the set of vertices that send messages this
//! superstep; the engine needs three operations on it, each fast in a
//! different representation:
//!
//! * **iterate in ascending vertex order** (push supersteps — ascending
//!   order is part of the engine's bit-exactness contract, because it
//!   fixes the accumulation order of non-associative float reductions);
//! * **O(1) membership test** (pull supersteps filter in-edges by
//!   frontier membership);
//! * **cheap set rebuild every superstep** with no steady-state heap
//!   allocation.
//!
//! The hybrid keeps a member list always and a bitmap lazily. Sealing a
//! freshly-built frontier switches strategy by occupancy: sparse
//! frontiers sort the list (`k log k`), dense frontiers build the bitmap
//! and regenerate the list from it (`n/64 + k`, cheaper than sorting once
//! `k` is a few percent of `n`). Both buffers are allocated once and
//! reused across supersteps; clearing resets only the words the previous
//! members touched.

use crate::graph::VertexId;

/// Occupancy divisor above which sealing goes through the bitmap instead
/// of sorting: with `k >= n / DENSE_DIVISOR` members, `n/64 + 2k` bitmap
/// work undercuts the `k log k` sort.
const DENSE_DIVISOR: usize = 64;

/// A reusable vertex set with list and bitmap views.
#[derive(Debug, Clone)]
pub struct Frontier {
    /// Exact member set. Ascending after [`Frontier::seal`].
    members: Vec<VertexId>,
    /// Membership bitmap; in sync with `members` iff `bits_valid`. Only
    /// bits of current members are ever set, so clearing walks the list
    /// instead of zeroing the whole array.
    bits: Vec<u64>,
    bits_valid: bool,
    /// Tracked on the fly while pushing so already-ascending builds (pull
    /// supersteps discover vertices in sweep order) skip the sort.
    sorted: bool,
}

impl Frontier {
    /// An empty frontier for a graph of `n` vertices. The only allocation
    /// this type ever performs (plus list growth up to `n`).
    pub fn new(n: usize) -> Self {
        Self {
            members: Vec::new(),
            bits: vec![0u64; n.div_ceil(64)],
            bits_valid: true,
            sorted: true,
        }
    }

    /// Remove all members, resetting only the bitmap words they occupy.
    pub fn clear(&mut self) {
        for &v in &self.members {
            self.bits[v as usize / 64] = 0;
        }
        self.members.clear();
        self.bits_valid = true;
        self.sorted = true;
    }

    /// Append a member. Callers guarantee uniqueness (the engine dedups
    /// through its `touched` flags).
    pub fn push(&mut self, v: VertexId) {
        if let Some(&last) = self.members.last() {
            if last > v {
                self.sorted = false;
            }
        }
        self.members.push(v);
        self.bits_valid = false;
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Member list; ascending once sealed.
    pub fn as_slice(&self) -> &[VertexId] {
        &self.members
    }

    /// Normalize to ascending order, choosing list-sort or bitmap
    /// round-trip by occupancy.
    pub fn seal(&mut self) {
        if self.sorted {
            return;
        }
        let n_words = self.bits.len();
        if self.members.len() >= (n_words * 64) / DENSE_DIVISOR {
            // dense: scatter into the bitmap, then regenerate the list in
            // ascending order from the set bits
            self.ensure_bits();
            self.members.clear();
            for (w, &word) in self.bits.iter().enumerate() {
                let mut rest = word;
                while rest != 0 {
                    let b = rest.trailing_zeros();
                    self.members.push((w * 64) as u32 + b);
                    rest &= rest - 1;
                }
            }
        } else {
            self.members.sort_unstable();
        }
        self.sorted = true;
    }

    /// Build the bitmap view (idempotent; O(len) when stale).
    pub fn ensure_bits(&mut self) {
        if self.bits_valid {
            return;
        }
        for &v in &self.members {
            self.bits[v as usize / 64] |= 1u64 << (v % 64);
        }
        self.bits_valid = true;
    }

    /// Membership test against the bitmap view. Call
    /// [`Frontier::ensure_bits`] after the last `push` first.
    #[inline]
    pub fn contains(&self, v: VertexId) -> bool {
        debug_assert!(self.bits_valid, "ensure_bits before membership tests");
        self.bits[v as usize / 64] & (1u64 << (v % 64)) != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_seal_iterates_ascending_sparse_and_dense() {
        for k in [5usize, 900] {
            // descending input: worst case for the sortedness tracker
            let mut f = Frontier::new(1_000);
            for v in (0..k as u32).rev() {
                f.push(v);
            }
            f.seal();
            let got: Vec<u32> = f.as_slice().to_vec();
            let want: Vec<u32> = (0..k as u32).collect();
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn ascending_builds_skip_the_sort_path() {
        let mut f = Frontier::new(128);
        for v in [3u32, 9, 40, 90] {
            f.push(v);
        }
        assert!(f.sorted, "ascending pushes must be detected");
        f.seal();
        assert_eq!(f.as_slice(), &[3, 9, 40, 90]);
    }

    #[test]
    fn membership_and_sparse_clear() {
        let mut f = Frontier::new(200);
        for v in [7u32, 64, 65, 199] {
            f.push(v);
        }
        f.ensure_bits();
        assert!(f.contains(7) && f.contains(64) && f.contains(65) && f.contains(199));
        assert!(!f.contains(8) && !f.contains(63) && !f.contains(0));
        f.clear();
        assert!(f.is_empty());
        f.ensure_bits();
        for v in 0..200 {
            assert!(!f.contains(v), "bit {v} survived clear");
        }
    }

    #[test]
    fn reuse_across_generations_is_consistent() {
        let mut f = Frontier::new(300);
        for round in 0..5u32 {
            f.clear();
            for i in 0..(50 + round * 40) {
                f.push((i * 7 + round) % 300);
            }
            // the engine dedups; emulate that here
            let mut uniq: Vec<u32> = f.as_slice().to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            f.clear();
            for &v in &uniq {
                f.push(v);
            }
            f.seal();
            f.ensure_bits();
            assert_eq!(f.len(), uniq.len());
            for &v in &uniq {
                assert!(f.contains(v), "round {round} member {v}");
            }
        }
    }

    #[test]
    fn empty_graph_frontier_is_fine() {
        let mut f = Frontier::new(0);
        assert!(f.is_empty());
        f.seal();
        f.ensure_bits();
        f.clear();
    }
}
