//! Run reports: the numbers Table V and Fig. 5 are built from, for a
//! single (program, design, graph) execution.


use crate::accel::stats::SimStats;

/// Which functional path produced the values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FunctionalPath {
    /// AOT-compiled XLA supersteps (canonical algorithms).
    Xla,
    /// Software GAS interpreter (custom programs, or XLA unavailable).
    Software,
}

/// Everything a run produces. Field groups mirror the paper's running-time
/// decomposition (Fig. 5: preparation / compilation / deployment) plus the
/// Table V columns (code lines, RT, TP).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub program: String,
    /// Effective runtime-parameter values this query ran with (declared
    /// signature resolved against the query's `ParamSet`), in register
    /// order. Empty for programs without parameters.
    pub bound_params: Vec<(String, f64)>,
    pub translator: &'static str,
    pub graph_name: String,
    pub num_vertices: usize,
    pub num_edges: usize,

    // --- Fig. 5 periods (seconds)
    /// Program preparation: graph read/generate + Layout (+ Reorder /
    /// Partition when enabled). Measured wall time.
    pub prep_seconds: f64,
    /// Compilation: measured translate + modeled synthesis.
    pub compile_seconds: f64,
    /// Deployment: modeled xclbin flash + measured-model PCIe transport.
    pub deploy_seconds: f64,

    // --- execution
    /// Simulated on-FPGA execution (cycle model, incl. launches).
    pub sim_exec_seconds: f64,
    /// Wall time of the XLA functional path (host-side PJRT execute).
    pub functional_exec_seconds: f64,
    /// Modeled result read-back DMA (PCIe) for this query. Part of the
    /// per-query cost — a query is not done until its values are back on
    /// the host.
    pub transfer_seconds: f64,
    pub functional_path: FunctionalPath,
    pub supersteps: u32,
    /// Supersteps the software oracle ran in the pull (CSC) direction —
    /// the direction-optimizing engine's per-superstep choices,
    /// aggregated. The oracle drives the cycle simulator, so these also
    /// describe the simulated workload (`sim.pull_supersteps` matches).
    /// 0 on push-only runs. `push_supersteps + pull_supersteps ==
    /// supersteps` on every path: where the XLA kernel's own superstep
    /// count diverges from the oracle's (PageRank — f32 accumulation
    /// shifts the convergence crossing), the run is uniform-direction
    /// and the split is restated over the reported total.
    pub pull_supersteps: u32,
    /// Supersteps the software oracle ran in the push (CSR) direction.
    pub push_supersteps: u32,
    pub edges_traversed: u64,

    // --- sharded execution (0 / 0 / 0.0 on monolithic runs)
    /// Shards the query executed across (partitioned bindings run the
    /// sharded engine: one shard per partition part, lockstep supersteps).
    pub shards: usize,
    /// Auto-shards an *un-partitioned* binding fanned this query's
    /// supersteps across (degree-balanced destination ranges; see
    /// `PreparedGraph::auto_sharded`). Purely an execution detail: the
    /// report keeps monolithic accounting (`shards` 0, `crossing_msgs`
    /// 0, no exchange billing). 0 when the query ran the monolithic
    /// sweep or a user partitioning.
    pub auto_shards: u32,
    /// Boundary-exchange messages: edge traversals whose source value
    /// lived on a different shard than the owning destination, summed
    /// over all supersteps.
    pub crossing_msgs: u64,
    /// Modeled seconds for the boundary-exchange traffic (priced by the
    /// peer-to-peer exchange class, committed to the shared ledger).
    /// Included in `transfer_seconds` — reported separately so the
    /// exchange cost of a partitioning is visible on its own.
    pub exchange_seconds: f64,

    // --- Table V metrics
    pub hdl_lines: usize,
    /// RT = `setup_seconds + query_seconds` (the paper's "running time
    /// includes the compilation time, the data preprocessing time and the
    /// algorithm execution time"). This identity holds on **every**
    /// functional path — software and XLA alike.
    pub rt_seconds: f64,
    /// One-time seconds (prep + compile + deploy): paid once per
    /// compile/load under the `Session` lifecycle and amortized across
    /// queries.
    pub setup_seconds: f64,
    /// Per-query seconds (simulated exec + XLA functional exec + result
    /// read-back DMA): what each additional query on a bound pipeline
    /// costs. `query_seconds = sim_exec_seconds + functional_exec_seconds
    /// + transfer_seconds`.
    pub query_seconds: f64,
    /// TP in MTEPS from the cycle model.
    pub simulated_mteps: f64,

    /// Full simulator statistics for drill-down.
    pub sim: SimStats,
    /// Max relative deviation XLA-vs-oracle (None when not cross-checked).
    pub oracle_deviation: Option<f64>,
}

impl RunReport {
    /// One-line summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "{} [{}] on {} ({}v/{}e): {} supersteps ({} pull), {:.1} MTEPS simulated, \
             RT {:.1}s (setup {:.1} = prep {:.2} + compile {:.1} + deploy {:.2}; \
             query {:.4} incl. read-back {:.6}), {} HDL lines{}{}",
            self.program,
            self.translator,
            self.graph_name,
            self.num_vertices,
            self.num_edges,
            self.supersteps,
            self.pull_supersteps,
            self.simulated_mteps,
            self.rt_seconds,
            self.setup_seconds,
            self.prep_seconds,
            self.compile_seconds,
            self.deploy_seconds,
            self.query_seconds,
            self.transfer_seconds,
            self.hdl_lines,
            match (self.shards, self.oracle_deviation) {
                (0, None) => String::new(),
                (0, Some(d)) => format!(", oracle dev {d:.2e}"),
                (k, dev) => format!(
                    ", {k} shards ({} crossing msgs, exchange {:.6}s){}",
                    self.crossing_msgs,
                    self.exchange_seconds,
                    match dev {
                        Some(d) => format!(", oracle dev {d:.2e}"),
                        None => String::new(),
                    }
                ),
            },
            if self.auto_shards > 1 {
                format!(", {} auto-shards", self.auto_shards)
            } else {
                String::new()
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_renders() {
        let r = RunReport {
            program: "bfs".into(),
            bound_params: vec![("max_depth".into(), f64::INFINITY)],
            translator: "FAgraph",
            graph_name: "email".into(),
            num_vertices: 10,
            num_edges: 20,
            prep_seconds: 0.1,
            compile_seconds: 3.0,
            deploy_seconds: 1.0,
            sim_exec_seconds: 0.001,
            functional_exec_seconds: 0.01,
            transfer_seconds: 0.0001,
            functional_path: FunctionalPath::Software,
            supersteps: 3,
            pull_supersteps: 1,
            push_supersteps: 2,
            edges_traversed: 20,
            shards: 0,
            auto_shards: 0,
            crossing_msgs: 0,
            exchange_seconds: 0.0,
            hdl_lines: 35,
            rt_seconds: 4.1111,
            setup_seconds: 4.1,
            query_seconds: 0.0111,
            simulated_mteps: 314.0,
            sim: SimStats::default(),
            oracle_deviation: Some(0.0),
        };
        let s = r.summary();
        assert!(s.contains("314.0 MTEPS"));
        assert!(s.contains("35 HDL lines"));
        assert!(!s.contains("shards"), "monolithic summary stays shard-free");
        let mut sharded = r.clone();
        sharded.shards = 4;
        sharded.crossing_msgs = 123;
        sharded.exchange_seconds = 1.5e-5;
        let s = sharded.summary();
        assert!(s.contains("4 shards"), "{s}");
        assert!(s.contains("123 crossing msgs"), "{s}");
        assert!(s.contains("oracle dev"), "{s}");
        let mut auto = r.clone();
        auto.auto_shards = 8;
        let s = auto.summary();
        assert!(s.contains("8 auto-shards"), "{s}");
        assert!(!s.contains("crossing msgs"), "auto-sharding bills no exchange: {s}");
    }
}
