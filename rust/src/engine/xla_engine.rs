//! XLA functional engine: drives the AOT-compiled supersteps (JAX + Pallas
//! lowered to HLO text, compiled via PJRT) for the five canonical
//! algorithm kinds. This is the "RTL functional model" of a translated
//! design — the numbers a real FPGA build would produce — executing with
//! zero Python on the path.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::dsl::program::EdgeOpKind;
use crate::graph::csr::Csr;
use crate::graph::VertexId;
use crate::runtime::client::ArgRef;
use crate::runtime::{Buffer, KernelRegistry};

/// Sentinels matching python/compile/kernels/ref.py.
const INF_I32: i32 = 1 << 30;
const INF_F32: f32 = 3.0e38;
/// PR iteration cap (ref.py / gas.rs parity).
const PR_MAX_ITERS: u32 = 200;
/// Damping factor baked into the AOT PR kernel (ref.py). Tolerance is a
/// runtime argument of the kernel, damping is not (yet): queries bound to
/// any other damping value take the software oracle instead.
pub const XLA_PR_DAMPING: f64 = 0.85;

/// Result of an XLA-driven run.
#[derive(Debug, Clone)]
pub struct XlaRunResult {
    /// Final vertex values, truncated to the real vertex count,
    /// f64-interpreted for comparability with the software oracle.
    pub values: Vec<f64>,
    pub supersteps: u32,
    /// Exact for BFS (the kernel counts); `edges × supersteps` sweeps for
    /// the all-active algorithms.
    pub edges_traversed: u64,
    /// Wall time spent inside PJRT `execute` (the request path).
    pub exec_seconds: f64,
    /// Bucket the registry selected.
    pub bucket: String,
}

/// Run one canonical algorithm over `graph` via the artifact registry.
pub fn run(
    registry: &KernelRegistry,
    kind: EdgeOpKind,
    graph: &Csr,
    root: VertexId,
    tolerance: f64,
) -> Result<XlaRunResult> {
    let n = graph.num_vertices();
    let m = graph.num_edges();
    let exe = registry.for_graph(kind.artifact_name(), n, m)?;
    let (n_pad, m_pad) = (exe.meta.n, exe.meta.m);
    let coo = graph.to_padded_coo(m_pad);
    let num_edges = coo.num_edges;
    // Static operands (the COO arrays + scalars) are converted to PJRT
    // literals ONCE and reused across supersteps; only the state arrays
    // are re-marshalled per iteration. §Perf: for the large bucket this
    // removes ~12 MB of copies per superstep.
    let src = Buffer::I32(coo.src);
    let dst = Buffer::I32(coo.dst);
    let w = Buffer::F32(coo.w);
    let ne = Buffer::I32(vec![num_edges as i32]);
    let bucket = exe.meta.bucket.clone();

    let mut exec_seconds = 0.0;
    let mut timed = |args: &[ArgRef<'_>]| -> Result<Vec<Buffer>> {
        let t0 = Instant::now();
        let out = exe.run_args(args)?;
        exec_seconds += t0.elapsed().as_secs_f64();
        Ok(out)
    };

    let (values, supersteps, edges_traversed) = match kind {
        EdgeOpKind::Bfs => {
            let mut levels = vec![-1i32; n_pad];
            levels[root as usize] = 0;
            let mut frontier = vec![0i32; n_pad];
            frontier[root as usize] = 1;
            let (src_lit, dst_lit, ne_lit) =
                (exe.prepare(2, &src)?, exe.prepare(3, &dst)?, exe.prepare(4, &ne)?);
            let mut levels_buf = Buffer::I32(levels);
            let mut frontier_buf = Buffer::I32(frontier);
            let mut traversed = 0u64;
            let mut steps = 0u32;
            let cap = n as u32 + 1;
            loop {
                if steps >= cap {
                    bail!("BFS did not converge within {cap} supersteps");
                }
                let lvl = Buffer::I32(vec![steps as i32]);
                let out = timed(&[
                    ArgRef::Buf(&levels_buf),
                    ArgRef::Buf(&frontier_buf),
                    ArgRef::Lit(&src_lit),
                    ArgRef::Lit(&dst_lit),
                    ArgRef::Lit(&ne_lit),
                    ArgRef::Buf(&lvl),
                ])?;
                traversed += out[3].scalar_i64()? as u64;
                let fsize = out[2].scalar_i64()?;
                let mut it = out.into_iter();
                levels_buf = it.next().unwrap();
                frontier_buf = it.next().unwrap();
                steps += 1;
                if fsize == 0 {
                    break;
                }
            }
            let levels = levels_buf.as_i32()?;
            (levels.iter().take(n).map(|&v| v as f64).collect(), steps, traversed)
        }
        EdgeOpKind::Sssp => {
            let mut dist_buf = {
                let mut dist = vec![INF_F32; n_pad];
                dist[root as usize] = 0.0;
                Buffer::F32(dist)
            };
            let (src_lit, dst_lit, w_lit, ne_lit) = (
                exe.prepare(1, &src)?,
                exe.prepare(2, &dst)?,
                exe.prepare(3, &w)?,
                exe.prepare(4, &ne)?,
            );
            let mut steps = 0u32;
            loop {
                if steps > n as u32 {
                    bail!("SSSP did not converge within {} sweeps", n + 1);
                }
                let out = timed(&[
                    ArgRef::Buf(&dist_buf),
                    ArgRef::Lit(&src_lit),
                    ArgRef::Lit(&dst_lit),
                    ArgRef::Lit(&w_lit),
                    ArgRef::Lit(&ne_lit),
                ])?;
                let changed = out[1].scalar_i64()?;
                dist_buf = out.into_iter().next().unwrap();
                steps += 1;
                if changed == 0 {
                    break;
                }
            }
            let dist = dist_buf.as_f32()?;
            (dist.iter().take(n).map(|&v| v as f64).collect(), steps, m as u64 * steps as u64)
        }
        EdgeOpKind::Wcc => {
            let mut label_buf = Buffer::I32((0..n_pad as i32).collect());
            let (src_lit, dst_lit, ne_lit) =
                (exe.prepare(1, &src)?, exe.prepare(2, &dst)?, exe.prepare(3, &ne)?);
            let mut steps = 0u32;
            loop {
                if steps > n as u32 {
                    bail!("WCC did not converge within {} sweeps", n + 1);
                }
                let out = timed(&[
                    ArgRef::Buf(&label_buf),
                    ArgRef::Lit(&src_lit),
                    ArgRef::Lit(&dst_lit),
                    ArgRef::Lit(&ne_lit),
                ])?;
                let changed = out[1].scalar_i64()?;
                label_buf = out.into_iter().next().unwrap();
                steps += 1;
                if changed == 0 {
                    break;
                }
            }
            let label = label_buf.as_i32()?;
            (label.iter().take(n).map(|&v| v as f64).collect(), steps, m as u64 * steps as u64)
        }
        EdgeOpKind::Pr => {
            let mut rank = vec![0f32; n_pad];
            for r in rank.iter_mut().take(n) {
                *r = 1.0 / n.max(1) as f32;
            }
            let out_deg: Vec<i32> = {
                let mut d = vec![0i32; n_pad];
                for (i, dv) in d.iter_mut().enumerate().take(n) {
                    *dv = graph.degree(i as u32) as i32;
                }
                d
            };
            let nv = Buffer::I32(vec![n as i32]);
            let deg = Buffer::I32(out_deg);
            let mut rank_buf = Buffer::F32(rank);
            let (deg_lit, src_lit, dst_lit, ne_lit, nv_lit) = (
                exe.prepare(1, &deg)?,
                exe.prepare(2, &src)?,
                exe.prepare(3, &dst)?,
                exe.prepare(4, &ne)?,
                exe.prepare(5, &nv)?,
            );
            let mut steps = 0u32;
            loop {
                if steps >= PR_MAX_ITERS {
                    break;
                }
                let out = timed(&[
                    ArgRef::Buf(&rank_buf),
                    ArgRef::Lit(&deg_lit),
                    ArgRef::Lit(&src_lit),
                    ArgRef::Lit(&dst_lit),
                    ArgRef::Lit(&ne_lit),
                    ArgRef::Lit(&nv_lit),
                ])?;
                let delta = out[1].scalar_f64()?;
                rank_buf = out.into_iter().next().unwrap();
                steps += 1;
                if delta < tolerance {
                    break;
                }
            }
            let rank = rank_buf.as_f32()?;
            (rank.iter().take(n).map(|&v| v as f64).collect(), steps, m as u64 * steps as u64)
        }
        EdgeOpKind::Spmv => {
            let x = Buffer::F32(vec![1.0f32; n_pad]);
            let out = timed(&[
                ArgRef::Buf(&x),
                ArgRef::Buf(&src),
                ArgRef::Buf(&dst),
                ArgRef::Buf(&w),
                ArgRef::Buf(&ne),
            ])?;
            (out[0].as_f32()?.iter().take(n).map(|&v| v as f64).collect(), 1, m as u64)
        }
    };

    Ok(XlaRunResult { values, supersteps, edges_traversed, exec_seconds, bucket })
}

/// Compare XLA values against the software oracle with sentinel-aware
/// tolerance. Returns the max relative deviation over finite pairs.
pub fn max_deviation(xla: &[f64], oracle: &[f64]) -> f64 {
    let mut worst: f64 = 0.0;
    for (&a, &b) in xla.iter().zip(oracle) {
        // map sentinels to a common representation
        let a = if a >= INF_F32 as f64 * 0.99 || a >= INF_I32 as f64 * 0.99 { f64::INFINITY } else { a };
        let b = if b.is_infinite() || b >= INF_F32 as f64 * 0.99 { f64::INFINITY } else { b };
        if a.is_infinite() && b.is_infinite() {
            continue;
        }
        let denom = b.abs().max(1e-12);
        worst = worst.max((a - b).abs() / denom);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deviation_handles_sentinels() {
        let xla = vec![0.0, 1.0, INF_F32 as f64];
        let oracle = vec![0.0, 1.0, f64::INFINITY];
        assert_eq!(max_deviation(&xla, &oracle), 0.0);
    }

    #[test]
    fn deviation_detects_mismatch() {
        let d = max_deviation(&[1.0, 2.0], &[1.0, 4.0]);
        assert!((d - 0.5).abs() < 1e-12);
    }
}
