//! **BoundPipeline** — a compiled pipeline bound to a prepared graph: the
//! cheap per-query layer of the lifecycle. Everything one-time (translate,
//! synthesis, flash, Reorder/Partition/Layout, graph transport, artifact
//! lookup) already happened; [`BoundPipeline::run`] only pays the
//! superstep loop — the paper's "tens of seconds to generate, then many
//! fast traversals" economics as an API shape.

use std::sync::Arc;

use anyhow::Result;

use crate::accel::simulator::{AccelSimulator, EdgeBatch};
use crate::comm::CommManager;
use crate::prep::prepared::PreparedGraph;
use crate::sched::{ParallelismPlan, RuntimeScheduler};

use super::compiled::{CompiledPipeline, RunOptions};
use super::executor::ORACLE_TOLERANCE;
use super::gas;
use super::metrics::{FunctionalPath, RunReport};
use super::trace::Trace;
use super::xla_engine;

/// A compiled pipeline bound to one prepared graph, ready for repeated
/// queries. Borrowing the [`CompiledPipeline`] keeps the design shared:
/// many bound graphs can coexist on one compile.
pub struct BoundPipeline<'p> {
    pipeline: &'p CompiledPipeline,
    graph: Arc<PreparedGraph>,
    comm: CommManager,
    plan: ParallelismPlan,
    /// Modeled deployment seconds (flash + graph transport), paid at bind
    /// time and reported — not re-paid — by every query.
    deploy_seconds: f64,
    queries_run: u64,
}

impl<'p> BoundPipeline<'p> {
    pub(crate) fn new(
        pipeline: &'p CompiledPipeline,
        graph: Arc<PreparedGraph>,
        comm: CommManager,
        plan: ParallelismPlan,
        deploy_seconds: f64,
    ) -> Self {
        Self { pipeline, graph, comm, plan, deploy_seconds, queries_run: 0 }
    }

    pub fn pipeline(&self) -> &CompiledPipeline {
        self.pipeline
    }

    pub fn graph(&self) -> &PreparedGraph {
        &self.graph
    }

    /// Modeled deployment seconds paid when this binding was created.
    pub fn deploy_seconds(&self) -> f64 {
        self.deploy_seconds
    }

    /// Modeled one-time seconds amortized across queries on this binding
    /// (preparation + compilation + deployment — the Fig. 5 periods).
    pub fn setup_seconds(&self) -> f64 {
        self.graph.prep_seconds + self.pipeline.compile_seconds() + self.deploy_seconds
    }

    /// Queries served by this binding so far.
    pub fn queries_run(&self) -> u64 {
        self.queries_run
    }

    /// Execute one query. Only per-query work happens here: the software
    /// oracle in lockstep with the cycle simulator, the optional AOT/XLA
    /// functional path, and the result DMA.
    pub fn run(&mut self, opts: &RunOptions) -> Result<RunReport> {
        let pipeline = self.pipeline;
        let program = &pipeline.program;
        let design = &pipeline.design;
        let csr = &self.graph.csr;

        let mut scheduler = RuntimeScheduler::admit(
            self.plan,
            &design.resources,
            &pipeline.device,
            program.max_supersteps(csr.num_vertices()).max(200),
        )?;

        // --- functional run (software oracle) in lockstep with the cycle
        //     simulator
        let mut sim = AccelSimulator::new(pipeline.device.clone(), design.pipeline);
        let mut trace_log = Trace::default();
        let want_trace = opts.trace_path.is_some();
        let bytes_per_edge = if program.uses_weights { 12 } else { 8 };
        let gap = self.graph.avg_edge_gap;
        let oracle = gas::run(program, csr, opts.root, |trace| {
            let _ = scheduler.begin_superstep(trace.active_rows as usize);
            let step = sim.superstep(&EdgeBatch {
                dsts: trace.dsts,
                active_rows: trace.active_rows,
                bytes_per_edge,
                avg_edge_gap: gap,
            });
            if want_trace {
                trace_log.record(step);
            }
            scheduler.end_superstep(trace.dsts.len());
        })?;
        scheduler.converged();
        let sim_stats = sim.finish();

        // --- AOT/XLA path for canonical programs (registry resolved at
        //     compile time; absent registry = software fallback)
        let mut functional_path = FunctionalPath::Software;
        let mut functional_exec_seconds = 0.0;
        let mut oracle_deviation = None;
        let mut edges_traversed = oracle.edges_traversed;
        let mut supersteps = oracle.supersteps;
        if opts.use_xla {
            if let (Some(kind), Some(registry)) = (program.kind, pipeline.registry.as_ref()) {
                let xla = xla_engine::run(registry, kind, csr, opts.root, opts.tolerance)?;
                functional_path = FunctionalPath::Xla;
                functional_exec_seconds = xla.exec_seconds;
                edges_traversed = xla.edges_traversed.max(edges_traversed);
                supersteps = xla.supersteps;
                if opts.verify {
                    let dev = xla_engine::max_deviation(&xla.values, &oracle.values);
                    if dev > ORACLE_TOLERANCE {
                        anyhow::bail!(
                            "XLA functional result deviates from the software \
                             oracle by {dev:.3e} (> {ORACLE_TOLERANCE:.0e})"
                        );
                    }
                    oracle_deviation = Some(dev);
                }
            }
        }

        // results DMA back (vertex values)
        self.comm.read_back(4 * csr.num_vertices() as u64);

        if let Some(path) = &opts.trace_path {
            trace_log.write_csv(path)?;
        }

        self.queries_run += 1;
        let prep_seconds = self.graph.prep_seconds;
        let compile_seconds = design.compile_seconds();
        let deploy_seconds = self.deploy_seconds;
        let sim_exec_seconds = sim_stats.exec_seconds();
        Ok(RunReport {
            program: program.name.clone(),
            translator: design.kind.label(),
            graph_name: self.graph.name.clone(),
            num_vertices: csr.num_vertices(),
            num_edges: csr.num_edges(),
            prep_seconds,
            compile_seconds,
            deploy_seconds,
            sim_exec_seconds,
            functional_exec_seconds,
            functional_path,
            supersteps,
            edges_traversed,
            hdl_lines: design.hdl_lines,
            rt_seconds: prep_seconds + compile_seconds + deploy_seconds + sim_exec_seconds,
            setup_seconds: prep_seconds + compile_seconds + deploy_seconds,
            query_seconds: sim_exec_seconds + functional_exec_seconds,
            simulated_mteps: sim_stats.mteps(),
            sim: sim_stats,
            oracle_deviation,
        })
    }

    /// Run a batch of queries (e.g. a 64-source BFS sweep) against the
    /// shared device setup, returning one report per query. Equivalent to
    /// calling [`Self::run`] sequentially — guaranteed by test — while
    /// amortizing graph transport, shell configuration, and preprocessing
    /// across the whole sweep.
    pub fn run_batch(&mut self, queries: &[RunOptions]) -> Result<Vec<RunReport>> {
        let mut reports = Vec::with_capacity(queries.len());
        for opts in queries {
            reports.push(self.run(opts)?);
        }
        Ok(reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::engine::session::{Session, SessionConfig};
    use crate::graph::generate;
    use crate::prep::prepared::PrepOptions;

    fn session() -> Session {
        Session::new(SessionConfig { use_xla: false, ..Default::default() })
    }

    #[test]
    fn second_query_reuses_setup() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(200, 2_000, 7);
        let mut bound = c.load(&g, PrepOptions::named("er")).unwrap();
        let r1 = bound.run(&RunOptions::from_root(0)).unwrap();
        let r2 = bound.run(&RunOptions::from_root(0)).unwrap();
        assert_eq!(bound.queries_run(), 2);
        // one-time periods are identical (paid once, reported unchanged)
        assert_eq!(r1.prep_seconds, r2.prep_seconds);
        assert_eq!(r1.deploy_seconds, r2.deploy_seconds);
        assert_eq!(r1.setup_seconds, r2.setup_seconds);
        // deterministic query results
        assert_eq!(r1.supersteps, r2.supersteps);
        assert_eq!(r1.edges_traversed, r2.edges_traversed);
        assert_eq!(r1.simulated_mteps, r2.simulated_mteps);
        // the setup/query split decomposes rt
        assert!((r1.setup_seconds + r1.sim_exec_seconds - r1.rt_seconds).abs() < 1e-12);
    }

    #[test]
    fn different_roots_change_the_query_not_the_setup() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::grid2d(16, 16, 3);
        let mut bound = c.load(&g, PrepOptions::named("grid")).unwrap();
        let r_corner = bound.run(&RunOptions::from_root(0)).unwrap();
        let r_center = bound.run(&RunOptions::from_root(8 * 16 + 8)).unwrap();
        assert_eq!(r_corner.setup_seconds, r_center.setup_seconds);
        // grid BFS from the corner needs more supersteps than from the
        // center (eccentricity 30 vs ~16)
        assert!(r_corner.supersteps > r_center.supersteps);
    }
}
